"""Shared hypothesis strategies for the property-based differential suite.

One place defines what a "random valid input" means — design chains,
workloads, operation mixes and hardware profiles — so every property
test (``tests/test_properties.py``) and any future fuzz harness draws
from the same distributions.  The module works against real
``hypothesis`` when installed and falls back to
:mod:`repro.testing.hypothesis_fallback` otherwise (same API slice, the
fallback's single-seed replay via ``REPRO_PROPERTY_SEED``).

Design chains are *bounded but adversarial*: depth ≤ 3 internal levels,
fanouts spanning the pow2 bucketing boundaries of the fused engine,
bloom-filter variants (the tag-only primitive path), both terminal
classes, mixed capacities.  Workload/mix draws cover the read-fraction
axis (pure reads through write-heavy) because the cost model branches
on it.  Hardware draws reuse one cached profile per name — profiles own
fitted model banks; drawing fresh ones per example would hide the
cross-example cache interactions the suite exists to catch.
"""
from __future__ import annotations

import functools
from typing import Dict, List

try:
    from hypothesis import given, seed, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # container without hypothesis: same slice
    from repro.testing.hypothesis_fallback import (   # noqa: F401
        given, seed, settings, strategies as st)
    HAVE_HYPOTHESIS = False

from repro.core import elements as el
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile, analytical_profile
from repro.core.synthesis import Workload

__all__ = [
    "HAVE_HYPOTHESIS", "given", "seed", "settings", "st",
    "design_chains", "design_specs", "workloads", "mixes",
    "hardware_names", "hardware_profiles", "profile_by_name",
]

#: fanouts straddling the fused engine's pow2 shape buckets
_FANOUTS = (2, 3, 16, 20, 64, 100, 256, 1000)
_CAPACITIES = (16, 64, 256, 1024)
_BLOOM_BITS = (1 << 10, 1 << 13, 1 << 16)
_HW_NAMES = ("hw1", "hw2", "hw3")


@functools.lru_cache(maxsize=None)
def profile_by_name(name: str) -> HardwareProfile:
    """One cached profile per name: model banks are identity-keyed, so
    every example sharing ``hw1`` exercises the same device table (the
    realistic steady-state, and the one where memo pollution can bite)."""
    return analytical_profile(name)


@st.composite
def _internal_elements(draw) -> Element:
    kind = draw(st.sampled_from(("hash", "range", "btree", "csb", "trie")))
    fanout = draw(st.sampled_from(_FANOUTS))
    if kind == "hash":
        element = el.hash_element(fanout)
        if draw(st.booleans()):
            element = element.with_values(
                bloom_filters=("on", 2, draw(st.sampled_from(_BLOOM_BITS))),
                filters_memory_layout="scatter")
        return element
    if kind == "range":
        return el.range_element(fanout)
    if kind == "btree":
        return el.btree_internal(fanout)
    if kind == "csb":
        return el.csb_internal(fanout)
    return el.trie_element(min(fanout, 256), draw(st.sampled_from((2, 4))))


@st.composite
def _terminal_elements(draw) -> Element:
    capacity = draw(st.sampled_from(_CAPACITIES))
    if draw(st.booleans()):
        return el.ordered_data_page(capacity)
    return el.unordered_data_page(capacity)


@st.composite
def design_chains(draw, max_depth: int = 3):
    """A random valid element chain: ≤ ``max_depth`` internal levels plus
    one terminal, already validated by ``DataStructureSpec``'s rules."""
    depth = draw(st.integers(min_value=0, max_value=max_depth))
    chain = tuple(draw(_internal_elements()) for _ in range(depth))
    return chain + (draw(_terminal_elements()),)


@st.composite
def design_specs(draw, max_depth: int = 3, name: str = "prop"
                 ) -> DataStructureSpec:
    return DataStructureSpec(name, draw(design_chains(max_depth)))


@st.composite
def workloads(draw) -> Workload:
    """Data sizes spanning several pow2 buckets, small enough for the
    scalar oracle to stay fast at ≥50 examples per invariant."""
    n_entries = draw(st.sampled_from(
        (256, 1000, 4096, 30_000, 1 << 17)))
    n_queries = draw(st.sampled_from((10, 100, 1000)))
    return Workload(n_entries=n_entries, n_queries=n_queries)


@st.composite
def mixes(draw) -> Dict[str, float]:
    """Read-fraction-conditioned operation mixes, ``get`` always present
    (every engine supports it) with optional range/update/bulk traffic."""
    read_fraction = draw(st.floats(min_value=0.1, max_value=1.0))
    total = 100.0
    mix = {"get": round(read_fraction * total, 3)}
    writes = total - mix["get"]
    if writes > 0.5:
        mix["update"] = round(writes, 3)
    if draw(st.booleans()):
        mix["range_get"] = float(draw(st.integers(1, 20)))
    return mix


def hardware_names():
    return st.sampled_from(_HW_NAMES)


@st.composite
def hardware_profiles(draw) -> HardwareProfile:
    return profile_by_name(draw(hardware_names()))
