"""Deterministic fault injection for the serving tier's chaos paths.

The self-healing machinery of PR 8 — shard retry and quarantine
(:mod:`repro.serving.shards`), the degraded-engine fallback chain
(:mod:`repro.serving.service` / :mod:`repro.core.devicecost`), worker
supervision, snapshot-restore accounting — only earns its keep if every
failure path can be *exercised*, on CPU CI, repeatably.  Real device
faults cannot be summoned on demand, so the production code carries
cheap named **seams** and this module decides, deterministically, when a
seam misbehaves.

Seams
-----
A seam is one line at a failure-prone boundary::

    faults.check("shards.dispatch", device.id)     # may raise / hang
    out = faults.corrupt("devicecost.fused", out)  # may NaN-poison

With no plan active both calls are a single module-global load plus a
``None`` test — the production steady state pays nothing measurable
(asserted by the fault-free arm of ``benchmarks/chaos_bench.py``: zero
recompiles, unchanged throughput).  The seams wired in this PR:

=====================  ====================================================
``shards.dispatch``    per-partition device dispatch (key: device id)
``devicecost.fused``   fused scorer output (corrupt -> NaN totals)
``devicecost.banks``   device parameter-bank build (corrupt -> NaN banks)
``memo.restore``       warm-restart snapshot load
``service.worker``     the coalescing worker loop (error -> worker crash)
=====================  ====================================================

Determinism
-----------
A :class:`FaultPlan` carries a seed and a list of :class:`FaultRule`\\ s.
Every ``check``/``corrupt`` increments a per-``(seam, key)`` occurrence
counter; a rule fires either at explicit occurrence indices (``at=``) or
when a hash of ``(seed, seam, key, occurrence)`` falls under ``rate`` —
no global RNG state, so the same plan over the same call sequence fires
identically, and per-device rules stay deterministic even when windows
interleave.  ``max_fires`` bounds a rule (e.g. "corrupt the banks once,
then let the recovery probe succeed").

Usage::

    plan = FaultPlan(seed=7, rules=[
        FaultRule("shards.dispatch", kind="error", rate=0.03),
        FaultRule("shards.dispatch", kind="hang", rate=0.02, hang_s=0.25),
        FaultRule("devicecost.fused", kind="corrupt", rate=0.05),
    ])
    with plan.activate():
        ...drive traffic...
    assert plan.fires() > 0

Exactly one plan may be active per process at a time (the seams are
process-wide by design: the serving worker, shard executor threads and
snapshot restore all cross thread boundaries).
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


class FaultInjected(RuntimeError):
    """An injected fault fired at a seam (never raised in production —
    only while a :class:`FaultPlan` is active)."""

    def __init__(self, seam: str, occurrence: int,
                 key=None) -> None:
        at = f"{seam}[{key}]" if key is not None else seam
        super().__init__(f"injected fault at seam {at} "
                         f"(occurrence {occurrence})")
        self.seam = seam
        self.occurrence = occurrence
        self.key = key


#: rule kinds: raise :class:`FaultInjected` / ``time.sleep(hang_s)`` /
#: NaN-poison the value passing through a ``corrupt`` seam
KINDS = ("error", "hang", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """When and how one seam misbehaves.

    ``rate`` fires probabilistically (seed-hashed, not RNG-stateful);
    ``at`` fires at exact per-``(seam, key)`` occurrence indices and
    overrides ``rate``.  ``key`` restricts the rule to checks carrying
    that key (e.g. one device id).  ``max_fires`` caps total fires."""

    seam: str
    kind: str = "error"
    rate: float = 0.0
    at: Optional[Tuple[int, ...]] = None
    key: Optional[object] = None
    hang_s: float = 0.05
    max_fires: Optional[int] = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind: {self.kind!r}")
        if self.at is not None:
            object.__setattr__(self, "at", tuple(int(i) for i in self.at))


def _fraction(seed: int, seam: str, key, occurrence: int) -> float:
    """A uniform-[0,1) decision hash — stateless, order-independent."""
    token = f"{seed}:{seam}:{key!r}:{occurrence}".encode()
    digest = hashlib.blake2b(token, digest_size=8).digest()
    return int.from_bytes(digest, "big") / 2.0 ** 64


class FaultPlan:
    """A seeded, deterministic schedule of injected faults (see module
    docstring).  Activate with ``with plan.activate():`` (or ``with
    plan:``); inspect what actually fired via :meth:`fires` /
    :meth:`counts`."""

    def __init__(self, seed: int = 0,
                 rules: Sequence[FaultRule] = ()) -> None:
        self.seed = int(seed)
        self.rules: Tuple[FaultRule, ...] = tuple(rules)
        self._lock = threading.Lock()
        self._occ: Dict[Tuple[str, object], int] = {}
        self._fired: Dict[str, int] = {}
        self._rule_fires: List[int] = [0] * len(self.rules)

    # -- observability ------------------------------------------------------
    def fires(self, seam: Optional[str] = None) -> int:
        """Total injected-fault count (optionally for one seam)."""
        with self._lock:
            if seam is not None:
                return self._fired.get(seam, 0)
            return sum(self._fired.values())

    def counts(self) -> Dict[str, int]:
        """Per-seam fire counts (snapshot)."""
        with self._lock:
            return dict(self._fired)

    def occurrences(self, seam: str, key=None) -> int:
        """How many times a seam (with ``key``) has been checked."""
        with self._lock:
            return self._occ.get((seam, key), 0)

    # -- the decision -------------------------------------------------------
    def _hit(self, seam: str, key, kinds: Tuple[str, ...],
             value=None):
        """One seam crossing: bump the occurrence counter, fire at most
        one matching rule.  Returns the (possibly poisoned) value."""
        hang = None
        with self._lock:
            occ = self._occ.get((seam, key), 0)
            self._occ[(seam, key)] = occ + 1
            for idx, rule in enumerate(self.rules):
                if rule.seam != seam or rule.kind not in kinds:
                    continue
                if rule.key is not None and rule.key != key:
                    continue
                if rule.max_fires is not None \
                        and self._rule_fires[idx] >= rule.max_fires:
                    continue
                if rule.at is not None:
                    fire = occ in rule.at
                else:
                    fire = _fraction(self.seed, seam, key, occ) < rule.rate
                if not fire:
                    continue
                self._rule_fires[idx] += 1
                self._fired[seam] = self._fired.get(seam, 0) + 1
                if rule.kind == "error":
                    raise FaultInjected(seam, occ, key)
                if rule.kind == "hang":
                    hang = rule.hang_s
                else:           # corrupt
                    value = _poison(value)
                break
        if hang is not None:    # sleep OUTSIDE the plan lock
            time.sleep(hang)
        return value

    # -- activation ---------------------------------------------------------
    @contextlib.contextmanager
    def activate(self) -> Iterator["FaultPlan"]:
        global _ACTIVE
        with _ACTIVATION_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError("another FaultPlan is already active")
            _ACTIVE = self
        try:
            yield self
        finally:
            with _ACTIVATION_LOCK:
                _ACTIVE = None

    def __enter__(self) -> "FaultPlan":
        self._cm = self.activate()
        return self._cm.__enter__()

    def __exit__(self, *exc) -> None:
        self._cm.__exit__(*exc)


def _poison(value):
    """NaN-fill every float leaf of ``value`` (dict / numpy / jax array),
    leaving integer banks (gather indices!) untouched so corruption shows
    up as non-finite *outputs*, not shape/index crashes."""
    if value is None:
        return None
    if isinstance(value, dict):
        return {k: _poison(v) for k, v in value.items()}
    dtype = getattr(value, "dtype", None)
    if dtype is not None and np.issubdtype(np.dtype(str(dtype)),
                                           np.floating):
        return value * np.asarray(np.nan, dtype=np.dtype(str(dtype)))
    return value


_ACTIVATION_LOCK = threading.Lock()
_ACTIVE: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    """The currently-activated plan, or ``None`` (the production state)."""
    return _ACTIVE


def check(seam: str, key=None) -> None:
    """A named error/hang seam.  No active plan: one global load plus a
    ``None`` test — effectively compiled out."""
    plan = _ACTIVE
    if plan is not None:
        plan._hit(seam, key, ("error", "hang"))


def corrupt(seam: str, value, key=None):
    """A named corruption seam: the value passes through untouched unless
    an active plan's ``corrupt`` rule fires, in which case every float
    leaf comes back NaN-poisoned (error/hang rules on the same seam fire
    here too)."""
    plan = _ACTIVE
    if plan is None:
        return value
    return plan._hit(seam, key, KINDS, value)
