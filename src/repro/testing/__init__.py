"""Test-support utilities (kept importable from the installed tree)."""
