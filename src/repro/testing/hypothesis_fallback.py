"""Minimal stand-in for ``hypothesis`` on containers without it installed.

The tier-1 suite uses a small slice of hypothesis: ``@given`` over
``integers`` / ``floats`` / ``booleans`` / ``lists`` / ``tuples`` /
``one_of`` / ``sampled_from`` / ``@composite`` strategies with
``@settings(max_examples=..., deadline=None)``.  This module implements
exactly that slice with deterministic pseudo-random draws so the
property tests still execute (as seeded random sweeps) when the real
library is unavailable.  Import pattern used by the tests:

    try:
        from hypothesis import given, seed, settings, strategies as st
    except ImportError:
        from repro.testing.hypothesis_fallback import (
            given, seed, settings, strategies as st)

No shrinking, no example database.  Reproduction instead works through
one replay seed: every example draws from its own derived seed, a
failure prints that seed, and setting ``REPRO_PROPERTY_SEED=<seed>``
re-runs exactly that one example (the property suite's differential
failures are replayed with a single environment variable, not a
hypothesis database).
"""
from __future__ import annotations

import math
import os
import random
import types
from typing import Any, Callable, List, Optional, Sequence

_SEED = 961748927  # fixed prime: deterministic across runs and workers

#: environment variable naming one derived example seed to replay
REPLAY_ENV = "REPRO_PROPERTY_SEED"


def _example_seed(base: int, example: int) -> int:
    """The derived seed of example ``example`` — printable, replayable."""
    return (base + 0x9E3779B9 * (example + 1)) % (1 << 63)


class Strategy:
    """A value generator: draw(rng) -> example."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: Optional[int] = None,
             max_value: Optional[int] = None) -> Strategy:
    lo = 0 if min_value is None else int(min_value)
    hi = lo + 1_000_000 if max_value is None else int(max_value)
    return Strategy(lambda rng: rng.randint(lo, hi))


def floats(min_value: Optional[float] = None,
           max_value: Optional[float] = None,
           allow_nan: bool = False,
           allow_infinity: bool = False, **_ignored: Any) -> Strategy:
    """Uniform floats in [min_value, max_value] (finite draws only —
    the repro property suite never asks for NaN/inf examples)."""
    lo = 0.0 if min_value is None else float(min_value)
    hi = lo + 1.0 if max_value is None else float(max_value)
    if not (math.isfinite(lo) and math.isfinite(hi)) or hi < lo:
        raise ValueError(f"bad floats bounds [{lo}, {hi}]")
    return Strategy(lambda rng: rng.uniform(lo, hi))


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements: Sequence[Any]) -> Strategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty sequence")
    return Strategy(lambda rng: pool[rng.randrange(len(pool))])


def tuples(*strats: Strategy) -> Strategy:
    """Fixed-shape tuple: one element per argument strategy, in order."""
    return Strategy(lambda rng: tuple(s.draw(rng) for s in strats))


def one_of(*strats: Strategy) -> Strategy:
    """Draw from one of the argument strategies, chosen uniformly (the
    real library biases toward earlier branches while shrinking; without
    shrinking a uniform choice covers every branch evenly)."""
    if len(strats) == 1 and isinstance(strats[0], (list, tuple)):
        strats = tuple(strats[0])
    if not strats:
        raise ValueError("one_of requires at least one strategy")
    return Strategy(
        lambda rng: strats[rng.randrange(len(strats))].draw(rng))


def lists(elements: Strategy, min_size: int = 0,
          max_size: Optional[int] = None, unique: bool = False) -> Strategy:
    cap = min_size + 10 if max_size is None else max_size

    def draw(rng: random.Random) -> List[Any]:
        target = rng.randint(min_size, cap)
        out: List[Any] = []
        seen = set()
        attempts = 0
        while len(out) < target and attempts < 20 * (target + 1):
            attempts += 1
            value = elements.draw(rng)
            if unique:
                if value in seen:
                    continue
                seen.add(value)
            out.append(value)
        if len(out) < min_size:  # mirror hypothesis: unsatisfiable strategy
            raise ValueError(
                f"could not draw {min_size} unique elements "
                f"(got {len(out)}); element domain too small?")
        return out

    return Strategy(draw)


def composite(fn: Callable[..., Any]) -> Callable[..., Strategy]:
    """``@composite``: fn(draw, *args) -> value becomes a strategy factory."""
    def builder(*args: Any, **kwargs: Any) -> Strategy:
        def draw_value(rng: random.Random) -> Any:
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)
        return Strategy(draw_value)
    builder.__name__ = getattr(fn, "__name__", "composite")
    return builder


def settings(max_examples: int = 20, deadline: Any = None,
             **_ignored: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def seed(value: int) -> Callable:
    """API parity with ``hypothesis.seed``: pin a property's base seed."""
    def deco(fn: Callable) -> Callable:
        fn._fallback_seed = int(value)
        return fn
    return deco


def given(*strategy_args: Strategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        # deliberately *not* functools.wraps: pytest must see the (*args,
        # **kwargs) signature, or it would treat the strategy-filled
        # parameters of the wrapped function as fixtures to resolve.
        def wrapper(*args: Any, **kwargs: Any) -> None:
            # settings()/seed() compose in either order with given() (as
            # with real hypothesis): outer decorators annotate `wrapper`,
            # inner ones annotate `fn` — resolve at call time, outer wins
            max_examples = getattr(
                wrapper, "_fallback_settings",
                getattr(fn, "_fallback_settings", {})
            ).get("max_examples", 20)
            base = getattr(wrapper, "_fallback_seed",
                           getattr(fn, "_fallback_seed", _SEED))
            replay = os.environ.get(REPLAY_ENV)
            if replay:
                # replay mode: exactly the one failing example, no sweep
                example_seeds = [int(replay)]
            else:
                example_seeds = [_example_seed(base, n)
                                 for n in range(max_examples)]
            for example, es in enumerate(example_seeds):
                rng = random.Random(es)
                drawn = [s.draw(rng) for s in strategy_args]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception:
                    print(f"falsifying example #{example}: {drawn!r}")
                    print(f"replay with: {REPLAY_ENV}={es}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


#: the tests import ``strategies as st`` — mirror hypothesis's layout
strategies = types.SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans, lists=lists,
    tuples=tuples, one_of=one_of, sampled_from=sampled_from,
    composite=composite)
