"""Minimal stand-in for ``hypothesis`` on containers without it installed.

The tier-1 suite uses a small slice of hypothesis: ``@given`` over
``integers`` / ``lists`` / ``sampled_from`` / ``@composite`` strategies
with ``@settings(max_examples=..., deadline=None)``.  This module
implements exactly that slice with deterministic pseudo-random draws so
the property tests still execute (as seeded random sweeps) when the real
library is unavailable.  Import pattern used by the tests:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from repro.testing.hypothesis_fallback import (
            given, settings, strategies as st)

No shrinking, no example database, no reproduction strings — failures
print the drawn arguments instead.
"""
from __future__ import annotations

import random
import types
from typing import Any, Callable, List, Optional, Sequence

_SEED = 961748927  # fixed prime: deterministic across runs and workers


class Strategy:
    """A value generator: draw(rng) -> example."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: Optional[int] = None,
             max_value: Optional[int] = None) -> Strategy:
    lo = 0 if min_value is None else int(min_value)
    hi = lo + 1_000_000 if max_value is None else int(max_value)
    return Strategy(lambda rng: rng.randint(lo, hi))


def sampled_from(elements: Sequence[Any]) -> Strategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty sequence")
    return Strategy(lambda rng: pool[rng.randrange(len(pool))])


def lists(elements: Strategy, min_size: int = 0,
          max_size: Optional[int] = None, unique: bool = False) -> Strategy:
    cap = min_size + 10 if max_size is None else max_size

    def draw(rng: random.Random) -> List[Any]:
        target = rng.randint(min_size, cap)
        out: List[Any] = []
        seen = set()
        attempts = 0
        while len(out) < target and attempts < 20 * (target + 1):
            attempts += 1
            value = elements.draw(rng)
            if unique:
                if value in seen:
                    continue
                seen.add(value)
            out.append(value)
        if len(out) < min_size:  # mirror hypothesis: unsatisfiable strategy
            raise ValueError(
                f"could not draw {min_size} unique elements "
                f"(got {len(out)}); element domain too small?")
        return out

    return Strategy(draw)


def composite(fn: Callable[..., Any]) -> Callable[..., Strategy]:
    """``@composite``: fn(draw, *args) -> value becomes a strategy factory."""
    def builder(*args: Any, **kwargs: Any) -> Strategy:
        def draw_value(rng: random.Random) -> Any:
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)
        return Strategy(draw_value)
    builder.__name__ = getattr(fn, "__name__", "composite")
    return builder


def settings(max_examples: int = 20, deadline: Any = None,
             **_ignored: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strategy_args: Strategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        max_examples = getattr(fn, "_fallback_settings",
                               {}).get("max_examples", 20)

        # deliberately *not* functools.wraps: pytest must see the (*args,
        # **kwargs) signature, or it would treat the strategy-filled
        # parameters of the wrapped function as fixtures to resolve.
        def wrapper(*args: Any, **kwargs: Any) -> None:
            rng = random.Random(_SEED)
            for example in range(max_examples):
                drawn = [s.draw(rng) for s in strategy_args]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception:
                    print(f"falsifying example #{example}: {drawn!r}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


#: the tests import ``strategies as st`` — mirror hypothesis's layout
strategies = types.SimpleNamespace(
    integers=integers, lists=lists, sampled_from=sampled_from,
    composite=composite)
