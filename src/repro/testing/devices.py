"""Forced host-device-count plumbing shared by benchmarks and tests.

JAX fixes its device list when the backend initializes, so a running
process cannot change its device count — multi-device behavior on CPU CI
is exercised by *launching a process* with
``--xla_force_host_platform_device_count=N`` in ``XLA_FLAGS`` (the
HomebrewNLP-Jax ``run.sh`` trick, see SNIPPETS.md).  Three consumers
build on the primitives here:

* ``benchmarks.common.apply_process_tuning`` re-execs the running
  benchmark with the flag appended (one simulated device per core);
* the ``devices(n)`` pytest marker (``tests/conftest.py``) re-invokes a
  test in a subprocess under exactly ``n`` forced devices, so one CI
  invocation covers 2/8/48-way sharding;
* ``benchmarks/device_scaling.py`` runs measurement children at 1 and 4
  devices and compares cells/sec.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from typing import Dict, Optional, Sequence

DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
SRC_ROOT = os.path.join(REPO_ROOT, "src")


def forced_device_count(env: Optional[Dict[str, str]] = None
                        ) -> Optional[int]:
    """The forced host device count in ``env`` (default: this process's
    environment), or ``None`` when the flag is absent."""
    flags = (os.environ if env is None else env).get("XLA_FLAGS", "")
    match = re.search(re.escape(DEVICE_COUNT_FLAG) + r"=(\d+)", flags)
    return int(match.group(1)) if match else None


def forced_device_env(n: int, base: Optional[Dict[str, str]] = None
                      ) -> Dict[str, str]:
    """A copy of ``base`` (default: ``os.environ``) whose ``XLA_FLAGS``
    force exactly ``n`` host devices, replacing any existing count."""
    env = dict(os.environ if base is None else base)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(DEVICE_COUNT_FLAG)]
    flags.append(f"{DEVICE_COUNT_FLAG}={int(n)}")
    env["XLA_FLAGS"] = " ".join(flags)
    return env


def run_under_devices(n: int, argv: Sequence[str], *,
                      timeout: float = 600.0,
                      env: Optional[Dict[str, str]] = None
                      ) -> subprocess.CompletedProcess:
    """Run ``python <argv...>`` from the repo root under ``n`` forced
    host devices, with ``src`` on ``PYTHONPATH`` and output captured.
    Returns the ``CompletedProcess`` unchecked — callers decide whether
    a nonzero exit is a failure or a measurement."""
    child_env = forced_device_env(n, env)
    extra = child_env.get("PYTHONPATH", "")
    child_env["PYTHONPATH"] = \
        SRC_ROOT + (os.pathsep + extra if extra else "")
    return subprocess.run([sys.executable] + list(argv), cwd=REPO_ROOT,
                          env=child_env, capture_output=True, text=True,
                          timeout=timeout)


def run_pytest_under_devices(n: int, nodeid: str, *,
                             timeout: float = 900.0
                             ) -> subprocess.CompletedProcess:
    """Re-invoke one pytest node under ``n`` forced host devices (the
    ``devices(n)`` marker's subprocess hop)."""
    return run_under_devices(
        n, ["-m", "pytest", "-x", "-q", "-p", "no:cacheprovider", nodeid],
        timeout=timeout)
