"""Step-atomic sharded checkpointing with async writer.

Layout:  <dir>/step_<n>/{manifest.json, arrays.npz}; a checkpoint is only
visible once its manifest exists (written last), so a crash mid-write never
corrupts restore — the fault-tolerance contract train/ft.py relies on.
Restore resharding: arrays are ``device_put`` against the *current* mesh's
shardings, so a run may restart on a different pod count (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any
_SEP = "/"


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz cannot store ml_dtypes; upcast losslessly — restore casts
            # back to the template dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def save_checkpoint(directory: str, step: int, tree: Params,
                    extra: Optional[Dict] = None) -> str:
    """Synchronous step-atomic save."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp_dir, "arrays.npz"), **flat)
    manifest = {"step": step, "time": time.time(),
                "keys": sorted(flat.keys()),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                "extra": extra or {}}
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)  # atomic publish
    return step_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name,
                                            "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Params,
                       step: Optional[int] = None,
                       shardings: Optional[Params] = None
                       ) -> Tuple[int, Params]:
    """Restore into the structure of ``template``; reshard onto the current
    mesh if ``shardings`` (same pytree structure) is given."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves: List = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        key = _SEP.join(_path_str(p) for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Async checkpointing: snapshot to host, write in a background thread.

    Keeps the last ``keep`` checkpoints; ``wait()`` drains pending writes
    (call before process exit).  A failed async write surfaces on the next
    ``save``/``wait`` call rather than being silently dropped.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Params,
             extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as exc:  # surfaced on next call
                self._error = exc

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            error, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from error

    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for old in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{old:08d}"),
                          ignore_errors=True)
