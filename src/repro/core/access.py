"""Data access primitives (paper §3 + Appendix D, Table 1).

Level-1 primitives are conceptual access patterns used by the cost
synthesizer; each resolves to one Level-2 primitive — a concrete minimal
implementation with a micro-benchmark and a learned cost model.

The benchmark implementations below follow Appendix D's pseudocode
(scalar scans, binary/interpolation search, hash and bloom probes,
quicksort, (batched) random memory access, writes).  They run live on this
container to produce the CPU hardware profile; the fitted models are then
the only thing the synthesizer touches.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Level-1 primitive names (Table 1 left column)
# ---------------------------------------------------------------------------
SCAN = "scan"
SORTED_SEARCH = "sorted_search"
HASH_PROBE = "hash_probe"
BLOOM_PROBE = "bloom_probe"
SORT = "sort"
RANDOM_ACCESS = "random_access"
BATCHED_RANDOM_ACCESS = "batched_random_access"
SERIAL_WRITE = "serial_write"
ORDERED_BATCH_WRITE = "ordered_batch_write"
SCATTERED_BATCH_WRITE = "scattered_batch_write"

LEVEL1 = (SCAN, SORTED_SEARCH, HASH_PROBE, BLOOM_PROBE, SORT, RANDOM_ACCESS,
          BATCHED_RANDOM_ACCESS, SERIAL_WRITE, ORDERED_BATCH_WRITE,
          SCATTERED_BATCH_WRITE)


@dataclasses.dataclass(frozen=True)
class Level2Primitive:
    """A concrete implementation of a Level-1 access pattern."""

    name: str              # e.g. "binary_search_columnstore"
    level1: str            # parent Level-1 primitive
    model_kind: str        # which cost model family fits it (Table 1 right)
    benchmark: Callable[[int, int], float]  # (size, reps) -> sec/op
    sizes: Tuple[int, ...] = (1 << 7, 1 << 9, 1 << 11, 1 << 13, 1 << 15,
                              1 << 17, 1 << 19, 1 << 21)
    doc: str = ""


def _time_op(fn: Callable[[], None], reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# Benchmark implementations (Appendix D pseudocode, vectorized where the
# C++ original is a tight loop — numpy IS this container's tight loop).
# ---------------------------------------------------------------------------
_rng = np.random.default_rng(1234)


def _bench_scan_row_equal(n: int, reps: int) -> float:
    arr = _rng.integers(0, n * 4, size=(n, 2)).astype(np.int64)  # kv pairs
    probes = _rng.integers(0, n * 4, size=reps).astype(np.int64)

    def op(i=[0]):
        x = probes[i[0] % reps]; i[0] += 1
        np.flatnonzero(arr[:, 0] == x)

    return _time_op(op, reps)


def _bench_scan_col_equal(n: int, reps: int) -> float:
    keys = _rng.integers(0, n * 4, size=n).astype(np.int64)
    probes = _rng.integers(0, n * 4, size=reps).astype(np.int64)

    def op(i=[0]):
        x = probes[i[0] % reps]; i[0] += 1
        np.flatnonzero(keys == x)

    return _time_op(op, reps)


def _bench_scan_col_range(n: int, reps: int) -> float:
    keys = _rng.integers(0, n * 4, size=n).astype(np.int64)
    values = _rng.integers(0, n * 4, size=n).astype(np.int64)
    probes = _rng.integers(0, n * 4, size=reps).astype(np.int64)

    def op(i=[0]):
        x = probes[i[0] % reps]; i[0] += 1
        values[keys < x]

    return _time_op(op, reps)


def _sorted_keys(n: int) -> np.ndarray:
    return np.sort(_rng.integers(0, n * 4, size=n).astype(np.int64))


def _bench_binary_search_col(n: int, reps: int) -> float:
    keys = _sorted_keys(n)
    probes = _rng.integers(0, n * 4, size=reps).astype(np.int64)

    def op(i=[0]):
        x = probes[i[0] % reps]; i[0] += 1
        lo, hi = 0, n - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if keys[mid] < x:
                lo = mid + 1
            else:
                hi = mid

    return _time_op(op, reps)


def _bench_binary_search_row(n: int, reps: int) -> float:
    arr = np.empty((n, 2), dtype=np.int64)
    arr[:, 0] = _sorted_keys(n)
    arr[:, 1] = np.arange(n)
    probes = _rng.integers(0, n * 4, size=reps).astype(np.int64)

    def op(i=[0]):
        x = probes[i[0] % reps]; i[0] += 1
        lo, hi = 0, n - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if arr[mid, 0] < x:
                lo = mid + 1
            else:
                hi = mid

    return _time_op(op, reps)


def _bench_interpolation_search(n: int, reps: int) -> float:
    keys = np.sort(_rng.integers(0, n * 8, size=n).astype(np.int64))
    probes = keys[_rng.integers(0, n, size=reps)]

    def op(i=[0]):
        x = probes[i[0] % reps]; i[0] += 1
        lo, hi = 0, n - 1
        klo, khi = int(keys[lo]), int(keys[hi])
        it = 0
        while lo < hi and klo <= x <= khi and it < 64:
            it += 1
            denom = max(khi - klo, 1)
            si = lo + int((hi - lo) * (x - klo) / denom)
            si = min(max(si, lo), hi)
            k = int(keys[si])
            if k < x:
                lo = si + 1
                klo = int(keys[lo]) if lo < n else k
            elif k == x:
                break
            else:
                hi = si
                khi = int(keys[hi])

    return _time_op(op, reps)


def _bench_hash_probe(n: int, reps: int) -> float:
    """Multiply-shift probe with serialized dependent accesses (Appendix D)."""
    k = max(n, 32)
    pa = _rng.integers(0, max(k - 20, 1), size=k).astype(np.int64)
    sa = _rng.integers(0, 20, size=reps).astype(np.int64)
    a = int(_rng.integers(1, 1 << 62)) | 1
    s = max(int(np.log2(k)), 1)

    def run():
        x = 0
        for i in range(reps):
            x = (a * (int(pa[x]) + int(sa[i]))) % (1 << 64) >> (64 - s)
            x = min(x, k - 1)
        return x

    t0 = time.perf_counter()
    run()
    return (time.perf_counter() - t0) / reps


def _bench_bloom_probe(n: int, reps: int, num_hashes: int = 2) -> float:
    bits = max(n, 64)
    s = max(int(np.log2(bits)), 3)
    bf = np.zeros(bits // 8 + 1, dtype=np.uint8)
    hashes = [(int(_rng.integers(1, 1 << 62)) | 1) for _ in range(num_hashes)]
    keys = _rng.integers(0, 1 << 40, size=reps).astype(np.int64)
    for x in keys[: reps // 2].tolist():  # half the probes hit
        for a in hashes:
            hb = (a * x) % (1 << 64) >> (64 - s)
            bf[hb >> 3] |= 1 << (hb & 7)

    def op(i=[0]):
        x = int(keys[i[0] % reps]); i[0] += 1
        for a in hashes:
            hb = (a * x) % (1 << 64) >> (64 - s)
            if not (bf[hb >> 3] >> (hb & 7)) & 1:
                return False
        return True

    return _time_op(op, reps)


def _bench_quicksort(n: int, reps: int) -> float:
    def op():
        data = _rng.integers(0, n * 4, size=n).astype(np.int64)
        np.sort(data, kind="quicksort")

    return _time_op(op, max(reps // 4, 1))


def _bench_random_access(n: int, reps: int) -> float:
    """Dependent pointer chase over a region of n int64 slots (Appendix D)."""
    k = max(n, 32)
    pa = _rng.integers(0, max(k - 20, 1), size=k).astype(np.int64)
    sa = _rng.integers(0, 20, size=reps).astype(np.int64)

    def run():
        p = 0
        for i in range(reps):
            p = int(pa[p]) + int(sa[i])
        return p

    t0 = time.perf_counter()
    run()
    return (time.perf_counter() - t0) / reps


def _bench_batched_random_access(n: int, reps: int) -> float:
    """Independent gathers — the CPU may overlap the memory requests."""
    k = max(n, 32)
    pa = _rng.integers(0, k, size=k).astype(np.int64)
    sa = _rng.integers(0, k, size=reps).astype(np.int64)

    def op():
        pa[sa].sum()

    t = _time_op(op, max(reps // 64, 1))
    return t / reps  # per access


def _bench_serial_write(n: int, reps: int) -> float:
    src = _rng.integers(0, n * 4, size=n).astype(np.int64)
    dst = np.empty_like(src)

    def op():
        np.copyto(dst, src)

    return _time_op(op, max(reps // 8, 1))


def _bench_ordered_batch_write(n: int, reps: int) -> float:
    src = np.sort(_rng.integers(0, n * 4, size=n).astype(np.int64))
    dst = np.empty_like(src)

    def op():
        np.copyto(dst, src)

    return _time_op(op, max(reps // 8, 1))


def _bench_scattered_batch_write(n: int, reps: int) -> float:
    k = max(n, 32)
    idx = _rng.permutation(k)
    src = _rng.integers(0, k, size=k).astype(np.int64)
    dst = np.empty_like(src)

    def op():
        dst[idx] = src

    return _time_op(op, max(reps // 8, 1))


# ---------------------------------------------------------------------------
# Registry (Table 1): Level-2 primitive -> (Level-1 parent, model family)
# ---------------------------------------------------------------------------
LEVEL2: Dict[str, Level2Primitive] = {p.name: p for p in [
    Level2Primitive("scalar_scan_rowstore_equal", SCAN, "linear",
                    _bench_scan_row_equal),
    Level2Primitive("scalar_scan_columnstore_equal", SCAN, "linear",
                    _bench_scan_col_equal),
    Level2Primitive("scalar_scan_columnstore_range", SCAN, "linear",
                    _bench_scan_col_range),
    Level2Primitive("binary_search_rowstore", SORTED_SEARCH, "log_linear",
                    _bench_binary_search_row),
    Level2Primitive("binary_search_columnstore", SORTED_SEARCH, "log_linear",
                    _bench_binary_search_col),
    Level2Primitive("interpolation_search_columnstore", SORTED_SEARCH,
                    "log_loglog", _bench_interpolation_search),
    Level2Primitive("hash_probe_multiply_shift", HASH_PROBE, "sigmoids",
                    _bench_hash_probe),
    Level2Primitive("bloom_probe_multiply_shift", BLOOM_PROBE, "sigmoids",
                    _bench_bloom_probe),
    Level2Primitive("quicksort", SORT, "nlogn", _bench_quicksort),
    Level2Primitive("random_memory_access", RANDOM_ACCESS, "sigmoids",
                    _bench_random_access,
                    sizes=(1 << 8, 1 << 10, 1 << 12, 1 << 14, 1 << 16,
                           1 << 18, 1 << 20, 1 << 22, 1 << 24)),
    Level2Primitive("batched_random_memory_access", BATCHED_RANDOM_ACCESS,
                    "sigmoids", _bench_batched_random_access,
                    sizes=(1 << 8, 1 << 10, 1 << 12, 1 << 14, 1 << 16,
                           1 << 18, 1 << 20, 1 << 22, 1 << 24)),
    Level2Primitive("serial_write", SERIAL_WRITE, "linear",
                    _bench_serial_write),
    Level2Primitive("ordered_batch_write", ORDERED_BATCH_WRITE, "linear",
                    _bench_ordered_batch_write),
    Level2Primitive("scattered_batch_write", SCATTERED_BATCH_WRITE,
                    "sigmoids", _bench_scattered_batch_write),
]}

#: default Level-1 -> Level-2 resolution (the synthesizer can override, e.g.
#: rowstore vs columnstore layouts select different scan/search variants).
DEFAULT_RESOLUTION: Dict[str, str] = {
    SCAN: "scalar_scan_columnstore_equal",
    SORTED_SEARCH: "binary_search_columnstore",
    HASH_PROBE: "hash_probe_multiply_shift",
    BLOOM_PROBE: "bloom_probe_multiply_shift",
    SORT: "quicksort",
    RANDOM_ACCESS: "random_memory_access",
    BATCHED_RANDOM_ACCESS: "batched_random_memory_access",
    SERIAL_WRITE: "serial_write",
    ORDERED_BATCH_WRITE: "ordered_batch_write",
    SCATTERED_BATCH_WRITE: "scattered_batch_write",
}


def resolve(level1: str, layout: str = "columnar", op: str = "equal") -> str:
    """Level-1 -> Level-2 resolution with layout/op hints (Figure 5)."""
    if level1 == SCAN:
        if layout == "row-wise":
            return "scalar_scan_rowstore_equal"
        return ("scalar_scan_columnstore_range" if op == "range"
                else "scalar_scan_columnstore_equal")
    if level1 == SORTED_SEARCH:
        return ("binary_search_rowstore" if layout == "row-wise"
                else "binary_search_columnstore")
    return DEFAULT_RESOLUTION[level1]
