"""Ground-truth data structure implementations (paper §5 baselines).

The paper validates synthesized costs against full C++ implementations of
eight access methods.  These are the equivalent implementations for this
container's hardware profile: Array, Sorted Array, Linked-list, Range
Partitioned Linked-list, Skip-list, Trie, Hash-table, B+tree (plus CSB+tree
as a contiguous-children variant).  They are deliberately written in the
same flat-array style the paper's Level-2 benchmarks measure (numpy arrays,
explicit per-node scans/searches) so that measured latencies decompose into
the same access primitives the synthesizer reasons about.
"""
from __future__ import annotations

import bisect
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class Structure:
    """Interface: bulk_load, get, range_get, update."""

    name = "abstract"

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        raise NotImplementedError

    def get(self, key: int) -> Optional[int]:
        raise NotImplementedError

    def range_get(self, lo: int, hi: int) -> List[int]:
        raise NotImplementedError

    def update(self, key: int, value: int) -> bool:
        """Paper's updates: a point query plus one write access."""
        raise NotImplementedError


class Array(Structure):
    """UDP with capacity = #puts: full scan on reads, append writes."""

    name = "array"

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        self.keys = np.ascontiguousarray(keys)
        self.values = np.ascontiguousarray(values)

    def get(self, key: int) -> Optional[int]:
        idx = np.flatnonzero(self.keys == key)
        return int(self.values[idx[0]]) if idx.size else None

    def range_get(self, lo: int, hi: int) -> List[int]:
        mask = (self.keys >= lo) & (self.keys < hi)
        return self.values[mask].tolist()

    def update(self, key: int, value: int) -> bool:
        idx = np.flatnonzero(self.keys == key)
        if not idx.size:
            return False
        self.values[idx[0]] = value
        return True


class SortedArray(Structure):
    """ODP with capacity = #puts: binary search reads, sort on load."""

    name = "sorted_array"

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        order = np.argsort(keys, kind="quicksort")
        self.keys = np.ascontiguousarray(keys[order])
        self.values = np.ascontiguousarray(values[order])

    def _locate(self, key: int) -> Optional[int]:
        idx = int(np.searchsorted(self.keys, key))
        if idx < self.keys.size and self.keys[idx] == key:
            return idx
        return None

    def get(self, key: int) -> Optional[int]:
        idx = self._locate(key)
        return int(self.values[idx]) if idx is not None else None

    def range_get(self, lo: int, hi: int) -> List[int]:
        left = int(np.searchsorted(self.keys, lo, side="left"))
        right = int(np.searchsorted(self.keys, hi, side="left"))
        return self.values[left:right].tolist()

    def update(self, key: int, value: int) -> bool:
        idx = self._locate(key)
        if idx is None:
            return False
        self.values[idx] = value
        return True


class LinkedList(Structure):
    """LL -> UDP: list of unsorted fixed-capacity pages, scanned in order."""

    name = "linked_list"

    def __init__(self, page_capacity: int = 256):
        self.page_capacity = page_capacity

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        cap = self.page_capacity
        self.pages: List[Tuple[np.ndarray, np.ndarray]] = [
            (keys[i:i + cap].copy(), values[i:i + cap].copy())
            for i in range(0, len(keys), cap)]

    def get(self, key: int) -> Optional[int]:
        for page_keys, page_values in self.pages:
            idx = np.flatnonzero(page_keys == key)
            if idx.size:
                return int(page_values[idx[0]])
        return None

    def range_get(self, lo: int, hi: int) -> List[int]:
        out: List[int] = []
        for page_keys, page_values in self.pages:
            mask = (page_keys >= lo) & (page_keys < hi)
            out.extend(page_values[mask].tolist())
        return out

    def update(self, key: int, value: int) -> bool:
        for page_keys, page_values in self.pages:
            idx = np.flatnonzero(page_keys == key)
            if idx.size:
                page_values[idx[0]] = value
                return True
        return False


class RangePartitionedLinkedList(Structure):
    """Range -> LL -> UDP: fixed range partitions, each a linked list."""

    name = "range_partitioned_linked_list"

    def __init__(self, partitions: int = 100, page_capacity: int = 256):
        self.partitions = partitions
        self.page_capacity = page_capacity

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        self.lo = int(keys.min()) if len(keys) else 0
        self.hi = int(keys.max()) + 1 if len(keys) else 1
        self.width = max((self.hi - self.lo) // self.partitions, 1)
        self.lists = [LinkedList(self.page_capacity)
                      for _ in range(self.partitions)]
        part = np.minimum((keys - self.lo) // self.width, self.partitions - 1)
        for p in range(self.partitions):
            mask = part == p
            self.lists[p].bulk_load(keys[mask], values[mask])

    def _part(self, key: int) -> int:
        return min(max((key - self.lo) // self.width, 0), self.partitions - 1)

    def get(self, key: int) -> Optional[int]:
        return self.lists[self._part(key)].get(key)

    def range_get(self, lo: int, hi: int) -> List[int]:
        out: List[int] = []
        for p in range(self._part(lo), self._part(max(hi - 1, lo)) + 1):
            out.extend(self.lists[p].range_get(lo, hi))
        return out

    def update(self, key: int, value: int) -> bool:
        return self.lists[self._part(key)].update(key, value)


class SkipList(Structure):
    """SL -> UDP: pages with zone maps and perfect skip links.

    Perfect skip links permit binary-search-style navigation over the page
    zone maps; inside the target page a binary search over sorted page keys.
    """

    name = "skip_list"

    def __init__(self, page_capacity: int = 256):
        self.page_capacity = page_capacity

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        order = np.argsort(keys, kind="quicksort")
        keys, values = keys[order], values[order]
        cap = self.page_capacity
        self.pages = [(keys[i:i + cap].copy(), values[i:i + cap].copy())
                      for i in range(0, len(keys), cap)]
        self.page_min = np.array([p[0][0] for p in self.pages]) \
            if self.pages else np.zeros(0, dtype=keys.dtype)

    def _page_for(self, key: int) -> int:
        return max(int(np.searchsorted(self.page_min, key, side="right")) - 1, 0)

    def get(self, key: int) -> Optional[int]:
        if not self.pages:
            return None
        page_keys, page_values = self.pages[self._page_for(key)]
        idx = int(np.searchsorted(page_keys, key))
        if idx < page_keys.size and page_keys[idx] == key:
            return int(page_values[idx])
        return None

    def range_get(self, lo: int, hi: int) -> List[int]:
        out: List[int] = []
        for p in range(self._page_for(lo), len(self.pages)):
            page_keys, page_values = self.pages[p]
            if page_keys[0] >= hi:
                break
            mask = (page_keys >= lo) & (page_keys < hi)
            out.extend(page_values[mask].tolist())
        return out

    def update(self, key: int, value: int) -> bool:
        if not self.pages:
            return False
        page_keys, page_values = self.pages[self._page_for(key)]
        idx = int(np.searchsorted(page_keys, key))
        if idx < page_keys.size and page_keys[idx] == key:
            page_values[idx] = value
            return True
        return False


class Trie(Structure):
    """Trie -> UDP: radix-256 partitioning on key bytes, UDP leaves."""

    name = "trie"

    def __init__(self, radix_bits: int = 8, max_depth: int = 4,
                 page_capacity: int = 256):
        self.radix_bits = radix_bits
        self.max_depth = max_depth
        self.page_capacity = page_capacity

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        self.root: Dict = {}
        shift_total = self.radix_bits * self.max_depth
        for key, value in zip(keys.tolist(), values.tolist()):
            node = self.root
            for level in range(self.max_depth - 1):
                shift = shift_total - self.radix_bits * (level + 1)
                byte = (key >> shift) & ((1 << self.radix_bits) - 1)
                node = node.setdefault(byte, {})
            byte = key & ((1 << self.radix_bits) - 1)
            node.setdefault(byte, []).append((key, value))

    def _walk(self, key: int):
        node = self.root
        shift_total = self.radix_bits * self.max_depth
        for level in range(self.max_depth - 1):
            shift = shift_total - self.radix_bits * (level + 1)
            byte = (key >> shift) & ((1 << self.radix_bits) - 1)
            node = node.get(byte)
            if node is None:
                return None
        return node.get(key & ((1 << self.radix_bits) - 1))

    def get(self, key: int) -> Optional[int]:
        leaf = self._walk(key)
        if leaf is None:
            return None
        for k, v in leaf:  # serial scan of the target page
            if k == key:
                return v
        return None

    def range_get(self, lo: int, hi: int) -> List[int]:
        out: List[int] = []

        def recurse(node, depth):
            if isinstance(node, list):
                out.extend(v for k, v in node if lo <= k < hi)
                return
            for byte in sorted(node):
                recurse(node[byte], depth + 1)

        recurse(self.root, 0)
        return out

    def update(self, key: int, value: int) -> bool:
        leaf = self._walk(key)
        if leaf is None:
            return False
        for i, (k, _) in enumerate(leaf):
            if k == key:
                leaf[i] = (key, value)
                return True
        return False


class HashTable(Structure):
    """Hash -> LL -> UDP: modulo buckets, small unsorted pages per bucket."""

    name = "hash_table"

    def __init__(self, buckets: int = 100, page_capacity: int = 5):
        self.buckets = buckets
        self.page_capacity = page_capacity

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        self.table: List[LinkedList] = [LinkedList(self.page_capacity)
                                        for _ in range(self.buckets)]
        bucket = keys % self.buckets
        for b in range(self.buckets):
            mask = bucket == b
            self.table[b].bulk_load(keys[mask], values[mask])

    def get(self, key: int) -> Optional[int]:
        return self.table[key % self.buckets].get(key)

    def range_get(self, lo: int, hi: int) -> List[int]:
        out: List[int] = []
        for ll in self.table:
            out.extend(ll.range_get(lo, hi))
        return out

    def update(self, key: int, value: int) -> bool:
        return self.table[key % self.buckets].update(key, value)


class BPlusTree(Structure):
    """B+ -> ... -> B+ -> ODP with fixed fanout and sorted leaf pages."""

    name = "btree"

    def __init__(self, fanout: int = 20, page_capacity: int = 256):
        self.fanout = fanout
        self.page_capacity = page_capacity

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        order = np.argsort(keys, kind="quicksort")
        keys, values = keys[order], values[order]
        cap = self.page_capacity
        self.leaf_keys = [keys[i:i + cap].copy()
                          for i in range(0, len(keys), cap)]
        self.leaf_values = [values[i:i + cap].copy()
                            for i in range(0, len(keys), cap)]
        # build internal levels of fences bottom-up
        fences = np.array([k[0] for k in self.leaf_keys]) \
            if self.leaf_keys else np.zeros(0, dtype=keys.dtype)
        self.levels: List[List[np.ndarray]] = []  # top level last
        level = [fences[i:i + self.fanout]
                 for i in range(0, len(fences), self.fanout)]
        while len(level) > 1:
            self.levels.append(level)
            fences = np.array([node[0] for node in level])
            level = [fences[i:i + self.fanout]
                     for i in range(0, len(fences), self.fanout)]
        self.levels.append(level)
        self.levels.reverse()  # root first

    def _leaf_for(self, key: int) -> int:
        node_idx = 0
        for level in self.levels:
            node = level[node_idx]
            # binary search through fences within the node
            child = max(int(np.searchsorted(node, key, side="right")) - 1, 0)
            node_idx = node_idx * self.fanout + child
        return min(node_idx, len(self.leaf_keys) - 1)

    def get(self, key: int) -> Optional[int]:
        if not self.leaf_keys:
            return None
        leaf = self._leaf_for(key)
        page_keys = self.leaf_keys[leaf]
        idx = int(np.searchsorted(page_keys, key))
        if idx < page_keys.size and page_keys[idx] == key:
            return int(self.leaf_values[leaf][idx])
        return None

    def range_get(self, lo: int, hi: int) -> List[int]:
        if not self.leaf_keys:
            return []
        out: List[int] = []
        for leaf in range(self._leaf_for(lo), len(self.leaf_keys)):
            page_keys = self.leaf_keys[leaf]
            if page_keys[0] >= hi:
                break
            mask = (page_keys >= lo) & (page_keys < hi)
            out.extend(self.leaf_values[leaf][mask].tolist())
        return out

    def update(self, key: int, value: int) -> bool:
        if not self.leaf_keys:
            return False
        leaf = self._leaf_for(key)
        page_keys = self.leaf_keys[leaf]
        idx = int(np.searchsorted(page_keys, key))
        if idx < page_keys.size and page_keys[idx] == key:
            self.leaf_values[leaf][idx] = value
            return True
        return False


class CSBTree(BPlusTree):
    """Cache-conscious B+tree: contiguous (BFS) children arrays.

    Fences of each level live in one contiguous array; children are found by
    arithmetic offset (no per-child pointers), the Rao & Ross "Full" design.
    """

    name = "csb_tree"

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        super().bulk_load(keys, values)
        # consolidate each level into one contiguous array + node offsets
        self.flat_levels = []
        for level in self.levels:
            flat = np.concatenate(level) if level else np.zeros(0)
            offsets = np.cumsum([0] + [len(n) for n in level])
            self.flat_levels.append((flat, offsets))

    def _leaf_for(self, key: int) -> int:
        node_idx = 0
        for flat, offsets in self.flat_levels:
            lo, hi = offsets[node_idx], offsets[node_idx + 1]
            child = max(int(np.searchsorted(flat[lo:hi], key, side="right")) - 1, 0)
            node_idx = node_idx * self.fanout + child
        return min(node_idx, len(self.leaf_keys) - 1)


ALL_STRUCTURES = {
    "array": Array,
    "sorted_array": SortedArray,
    "linked_list": LinkedList,
    "range_partitioned_linked_list": RangePartitionedLinkedList,
    "skip_list": SkipList,
    "trie": Trie,
    "hash_table": HashTable,
    "btree": BPlusTree,
    "csb_tree": CSBTree,
}


def measure_workload(structure: Structure, keys: np.ndarray,
                     values: np.ndarray, queries: Sequence[int],
                     op: str = "get") -> Dict[str, float]:
    """Bulk load then run a query workload; return per-op latencies (sec)."""
    t0 = time.perf_counter()
    structure.bulk_load(keys, values)
    t_load = time.perf_counter() - t0
    t0 = time.perf_counter()
    if op == "get":
        for q in queries:
            structure.get(int(q))
    elif op == "range":
        for q in queries:
            structure.range_get(int(q), int(q) + 1000)
    elif op == "update":
        for q in queries:
            structure.update(int(q), 0)
    else:
        raise ValueError(op)
    t_query = time.perf_counter() - t0
    return {"bulk_load_s": t_load,
            "per_query_s": t_query / max(len(queries), 1)}
