"""Learned cost models for Level-2 access primitives (paper §3, Appendix D).

Model zoo (Table 1): Linear, Log-Linear, Log+LogLog, NLogN, Sum-of-Sigmoids,
Sum-of-Sum-of-Sigmoids (2-D), Weighted k-NN.  All parametric models are
fitted **in JAX**: a non-negative least-squares solve (projected Adam with a
closed-form ridge initializer) for the linear-basis family, and jitted Adam
gradient descent with the paper's rate-of-change initialization for the
non-convex sigmoid models.

A fitted model is a (name, params) pair; ``predict`` is pure and jittable so
the cost synthesizer can evaluate thousands of designs in a batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# All model fitting happens in float64-ish scale space; latencies are tiny
# (ns..ms), so standardize y internally for stable optimization.
_EPS = 1e-12


# ---------------------------------------------------------------------------
# Linear-basis family: f(x) = w . phi(x) + y0 with w >= 0
# ---------------------------------------------------------------------------
def _basis_linear(x: Array) -> Array:
    return jnp.stack([x], axis=-1)


def _basis_loglinear(x: Array) -> Array:
    return jnp.stack([x, jnp.log(x + 1.0)], axis=-1)


def _basis_logloglog(x: Array) -> Array:
    lx = jnp.log(x + 1.0)
    return jnp.stack([x, lx, jnp.log(lx + 1.0)], axis=-1)


def _basis_nlogn(x: Array) -> Array:
    return jnp.stack([x * jnp.log(x + 1.0), x], axis=-1)


_BASES: Dict[str, Callable[[Array], Array]] = {
    "linear": _basis_linear,
    "log_linear": _basis_loglinear,
    "log_loglog": _basis_logloglog,
    "nlogn": _basis_nlogn,
}


@functools.partial(jax.jit, static_argnames=("basis", "steps"))
def _fit_nnls(x: Array, y: Array, basis: str, steps: int = 2000
              ) -> Tuple[Array, Array]:
    """Non-negative least squares via projected Adam, ridge warm start."""
    phi = _BASES[basis](x)
    scale = jnp.maximum(jnp.max(jnp.abs(phi), axis=0), _EPS)
    yscale = jnp.maximum(jnp.max(jnp.abs(y)), _EPS)
    phi_n, y_n = phi / scale, y / yscale

    # ridge warm start (may have negative entries -> projected)
    a = phi_n.T @ phi_n + 1e-6 * jnp.eye(phi.shape[-1])
    b = phi_n.T @ y_n
    w = jnp.maximum(jnp.linalg.solve(a, b), 0.0)
    y0 = jnp.maximum(jnp.mean(y_n - phi_n @ w), 0.0)

    def loss_fn(params):
        w, y0 = params
        r = phi_n @ w + y0 - y_n
        return jnp.mean(r * r)

    lr = 3e-3
    m = (jnp.zeros_like(w), jnp.zeros_like(y0))
    v = (jnp.zeros_like(w), jnp.zeros_like(y0))

    def step(carry, i):
        params, m, v = carry
        g = jax.grad(loss_fn)(params)
        m = jax.tree.map(lambda mi, gi: 0.9 * mi + 0.1 * gi, m, g)
        v = jax.tree.map(lambda vi, gi: 0.999 * vi + 0.001 * gi * gi, v, g)
        t = i + 1.0
        params = jax.tree.map(
            lambda p, mi, vi: jnp.maximum(
                p - lr * (mi / (1 - 0.9 ** t)) /
                (jnp.sqrt(vi / (1 - 0.999 ** t)) + 1e-8), 0.0),
            params, m, v)
        return (params, m, v), loss_fn(params)

    (params, _, _), _ = jax.lax.scan(step, ((w, y0), m, v),
                                     jnp.arange(steps, dtype=jnp.float32))
    w, y0 = params
    return w * (yscale / scale), y0 * yscale


def _predict_basis(params: Tuple[Array, Array], x: Array, basis: str) -> Array:
    w, y0 = params
    return _BASES[basis](x) @ w + y0


# ---------------------------------------------------------------------------
# Sum of sigmoids: f(x) = sum_i c_i / (1 + exp(-k_i (log x - x_i))) + y0
# ---------------------------------------------------------------------------
def _sigmoid_predict(params: Dict[str, Array], logx: Array) -> Array:
    c, k, x0, y0 = params["c"], params["k"], params["x0"], params["y0"]
    z = jax.nn.sigmoid(k[None, :] * (logx[:, None] - x0[None, :]))
    return z @ c + y0


def _sigmoid_init(logx: np.ndarray, y: np.ndarray, k: int) -> Dict[str, np.ndarray]:
    """Paper's initialization: local maxima of the rate of change -> x_i."""
    order = np.argsort(logx)
    lx, ys = logx[order], y[order]
    dy = np.diff(ys) / np.maximum(np.diff(lx), _EPS)
    # local maxima of |rate of change|
    mag = np.abs(dy)
    idx = np.argsort(mag)[::-1]
    centers = []
    for i in idx:
        x_candidate = 0.5 * (lx[i] + lx[i + 1])
        if all(abs(x_candidate - c) > 0.5 for c in centers):
            centers.append(float(x_candidate))
        if len(centers) == k:
            break
    while len(centers) < k:
        centers.append(float(np.median(lx)))
    rng = np.random.default_rng(0)
    return {
        "c": rng.uniform(0.1, 1.0, size=k).astype(np.float32)
             * max(float(ys.max() - ys.min()), _EPS) / k,
        "k": rng.uniform(0.5, 1.0, size=k).astype(np.float32) * 4.0,
        "x0": np.asarray(sorted(centers), dtype=np.float32),
        "y0": np.asarray(float(ys[0]), dtype=np.float32),
    }


@functools.partial(jax.jit, static_argnames=("steps",))
def _fit_sigmoids_gd(logx: Array, y: Array, init: Dict[str, Array],
                     steps: int = 4000) -> Dict[str, Array]:
    yscale = jnp.maximum(jnp.max(jnp.abs(y)), _EPS)
    y_n = y / yscale
    init = dict(init)
    init["c"] = init["c"] / yscale
    init["y0"] = init["y0"] / yscale

    def loss_fn(params):
        pred = _sigmoid_predict(params, logx)
        return jnp.mean((pred - y_n) ** 2)

    lr = 2e-2
    m = jax.tree.map(jnp.zeros_like, init)
    v = jax.tree.map(jnp.zeros_like, init)

    def step(carry, i):
        params, m, v = carry
        g = jax.grad(loss_fn)(params)
        m = jax.tree.map(lambda mi, gi: 0.9 * mi + 0.1 * gi, m, g)
        v = jax.tree.map(lambda vi, gi: 0.999 * vi + 0.001 * gi * gi, v, g)
        t = i + 1.0
        params = jax.tree.map(
            lambda p, mi, vi: p - lr * (mi / (1 - 0.9 ** t)) /
            (jnp.sqrt(vi / (1 - 0.999 ** t)) + 1e-8),
            params, m, v)
        # amplitudes and slopes stay non-negative (monotone step functions)
        params["c"] = jnp.maximum(params["c"], 0.0)
        params["k"] = jnp.maximum(params["k"], 1e-3)
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(step, (init, m, v),
                                     jnp.arange(steps, dtype=jnp.float32))
    params["c"] = params["c"] * yscale
    params["y0"] = params["y0"] * yscale
    return params


# ---------------------------------------------------------------------------
# Weighted k-NN (log-space), jittable with a fixed k=4 top-k
# ---------------------------------------------------------------------------
#: log-space marker for padded k-NN bank slots (device tables pad every
#: model's support to a common width; slots at/beyond this distance carry
#: zero weight, so a model with n real points reduces to k = min(4, n))
KNN_SENTINEL = 1e9


@jax.jit
def _knn_predict(lxs: Array, ys: Array, lx: Array) -> Array:
    """Inverse-log-distance weighted 4-NN, pure and jittable.

    ``top_k`` runs over the *weights* (monotone in -distance), so padded
    sentinel slots — weight exactly 0 — can win a top-4 slot only when
    fewer than 4 real neighbors exist, in which case they contribute 0 to
    both the numerator and the denominator: the fixed k=4 shape serves
    every support size without masking logic in the caller.
    """
    d = jnp.abs(lx[:, None] - lxs[None, :]) + 1e-6
    w = jnp.where(lxs[None, :] >= KNN_SENTINEL * 0.5, 0.0, 1.0 / d)
    wk, idx = jax.lax.top_k(w, 4)
    yk = jnp.take_along_axis(
        jnp.broadcast_to(ys[None, :], w.shape), idx, axis=1)
    return (wk * yk).sum(axis=1) / jnp.maximum(wk.sum(axis=1), 1e-30)


# ---------------------------------------------------------------------------
# Fitted model wrapper
# ---------------------------------------------------------------------------
# NOTE (supersedes the PR-1 "predict stays eager" note): per-record scalar
# evaluation and the grouped batched engine still share this eager predict —
# that is what keeps their 1e-9 scalar-equivalence contract exact.  The
# *fused* device-resident engine (repro.core.devicecost) instead evaluates
# every kind through stacked parameter banks inside one jitted call; XLA
# fuses that computation differently, so it documents a relaxed 1e-6
# relative agreement with this path (see tests/test_batchcost.py).

@dataclasses.dataclass
class FittedModel:
    """A trained Level-2 cost model: latency_seconds = predict(x).

    ``predict`` is vectorized over x; the batch cost-synthesis engine
    (:mod:`repro.core.batchcost`) leans on this to evaluate every record of
    a whole candidate frontier in one call per Level-2 model.  Parameter
    arrays are converted to device arrays once and cached — every kind is
    immutable, including ``sigmoids2d``, whose second argument now flows
    through the pure :func:`predict2d` instead of a mutated param.
    """

    kind: str                       # linear|log_linear|log_loglog|nlogn|sigmoids|knn
    params: Dict[str, np.ndarray]
    x_range: Tuple[float, float] = (1.0, 1e9)
    _device_params: Optional[Dict[str, Array]] = dataclasses.field(
        default=None, repr=False, compare=False)

    def _jnp_params(self) -> Dict[str, Array]:
        if self._device_params is None:
            dp = {k: jnp.asarray(v) for k, v in self.params.items()}
            if self.kind == "knn":
                dp["_logx"] = jnp.log(dp["x"] + 1.0)
            self._device_params = dp
        return self._device_params

    def predict(self, x) -> np.ndarray:
        x = np.atleast_1d(np.asarray(x, dtype=np.float32))
        x = np.clip(x, self.x_range[0], self.x_range[1])
        if self.kind in _BASES:
            p = self._jnp_params()
            out = _predict_basis((p["w"], p["y0"]), jnp.asarray(x), self.kind)
        elif self.kind == "sigmoids":
            out = _sigmoid_predict(self._jnp_params(),
                                   jnp.log(jnp.asarray(x) + 1.0))
        elif self.kind == "sigmoids2d":
            # f(x, m) = S1(x) + (m - 1) * S2(x); the m axis enters only via
            # the pure predict2d — plain predict is the m=1 slice, S1(x)
            p = self._jnp_params()
            out = _sigmoid_predict(
                {k: p["s1_" + k] for k in ("c", "k", "x0", "y0")},
                jnp.log(jnp.asarray(x) + 1.0))
        elif self.kind == "knn":
            xs = self.params["x"]
            ys = self.params["y"]
            lx = np.log(x + 1.0)
            lxs = np.log(xs + 1.0)
            if len(xs) >= 4:
                p = self._jnp_params()
                out = _knn_predict(p["_logx"], p["y"], jnp.asarray(lx))
                return np.maximum(np.asarray(out), 0.0)
            # numpy fallback: fewer support points than the fixed top-k
            d = np.abs(lx[:, None] - lxs[None, :]) + 1e-6
            k = min(4, len(xs))
            idx = np.argpartition(d, k - 1, axis=1)[:, :k]
            dk = np.take_along_axis(d, idx, axis=1)
            wk = 1.0 / dk
            out = (wk * ys[idx]).sum(axis=1) / wk.sum(axis=1)
            return np.maximum(np.asarray(out), 0.0)
        else:
            raise ValueError(self.kind)
        return np.maximum(np.asarray(out), 0.0)

    def predict_scalar(self, x: float) -> float:
        return float(self.predict(np.asarray([x]))[0])

    def to_json(self) -> Dict:
        return {"kind": self.kind, "x_range": list(self.x_range),
                "params": {k: np.asarray(v).tolist()
                           for k, v in self.params.items()}}

    @staticmethod
    def from_json(obj: Dict) -> "FittedModel":
        return FittedModel(
            kind=obj["kind"],
            params={k: np.asarray(v, dtype=np.float32)
                    for k, v in obj["params"].items()},
            x_range=tuple(obj["x_range"]))


def fit(kind: str, x: np.ndarray, y: np.ndarray,
        num_sigmoids: int = 3) -> FittedModel:
    """Fit one cost model of the given kind to benchmark data (x, y)."""
    x = np.asarray(x, dtype=np.float32)
    y = np.asarray(y, dtype=np.float32)
    x_range = (float(x.min()), float(x.max()))
    if kind in _BASES:
        w, y0 = _fit_nnls(jnp.asarray(x), jnp.asarray(y), kind)
        return FittedModel(kind, {"w": np.asarray(w), "y0": np.asarray(y0)},
                           x_range)
    if kind == "sigmoids":
        logx = np.log(x + 1.0)
        init = _sigmoid_init(logx, y, num_sigmoids)
        params = _fit_sigmoids_gd(jnp.asarray(logx), jnp.asarray(y),
                                  {k: jnp.asarray(v) for k, v in init.items()})
        return FittedModel(kind, {k: np.asarray(v) for k, v in params.items()},
                           x_range)
    if kind == "knn":
        return FittedModel(kind, {"x": x, "y": y}, x_range)
    raise ValueError(kind)


def fit2d_sigmoids(x: np.ndarray, m: np.ndarray, y: np.ndarray,
                   num_sigmoids: int = 3) -> FittedModel:
    """Sum-of-sum-of-sigmoids: f(x, m) = S1(x) + (m-1) S2(x) (bloom filters)."""
    x = np.asarray(x, dtype=np.float32)
    m = np.asarray(m, dtype=np.float32)
    y = np.asarray(y, dtype=np.float32)
    # fit S1 on the m == min(m) slice, then S2 on the residual slope wrt m
    m0 = float(m.min())
    base_mask = m == m0
    s1 = fit("sigmoids", x[base_mask], y[base_mask] / max(m0, 1.0),
             num_sigmoids=num_sigmoids)
    resid_mask = m > m0
    if resid_mask.any():
        slope = (y[resid_mask] - s1.predict(x[resid_mask]) * 1.0) / \
            np.maximum(m[resid_mask] - 1.0, 1.0)
        s2 = fit("sigmoids", x[resid_mask], np.maximum(slope, 0.0),
                 num_sigmoids=num_sigmoids)
    else:
        s2 = FittedModel("sigmoids", {
            "c": np.zeros(num_sigmoids, np.float32),
            "k": np.ones(num_sigmoids, np.float32),
            "x0": np.zeros(num_sigmoids, np.float32),
            "y0": np.zeros((), np.float32)})
    params = {}
    for key in ("c", "k", "x0", "y0"):
        params["s1_" + key] = s1.params[key]
        params["s2_" + key] = s2.params[key]
    fm = FittedModel("sigmoids2d", params,
                     (float(x.min()), float(x.max())))
    return fm


def predict2d(model: FittedModel, x, m) -> np.ndarray:
    """f(x, m) = S1(x) + (m - 1) S2(x), pure in (model, x, m)."""
    assert model.kind == "sigmoids2d"
    x = np.atleast_1d(np.asarray(x, dtype=np.float32))
    x = np.clip(x, model.x_range[0], model.x_range[1])
    m = np.atleast_1d(np.asarray(m, dtype=np.float32))
    p = model._jnp_params()
    logx = jnp.log(jnp.asarray(x) + 1.0)
    s1 = _sigmoid_predict(
        {k: p["s1_" + k] for k in ("c", "k", "x0", "y0")}, logx)
    s2 = _sigmoid_predict(
        {k: p["s2_" + k] for k in ("c", "k", "x0", "y0")}, logx)
    out = s1 + (jnp.asarray(m) - 1.0) * s2
    return np.maximum(np.asarray(out), 0.0)


def r2_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    ss_res = float(((y_true - y_pred) ** 2).sum())
    ss_tot = float(((y_true - y_true.mean()) ** 2).sum()) + _EPS
    return 1.0 - ss_res / ss_tot
