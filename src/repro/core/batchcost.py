"""Batch cost-synthesis engine: cost whole candidate frontiers per call.

The Data Calculator's promise (paper §4, Algorithm 1) is answering what-if
design questions in seconds by *synthesizing* cost.  The scalar path costs
one design at a time — ``AccessRecord.cost`` dispatches one
``predict_scalar`` per record, so a search over N candidates pays
N x records x models worth of per-call model-evaluation overhead.

This module compiles each synthesized :class:`CostBreakdown` into parallel
numpy arrays (Level-2 model id, size argument, weighted count) and scores
whole frontiers through one of two engines:

* ``engine="fused"`` (default): the packed frontier arrays go to
  :func:`repro.core.devicecost.score_frontier` — **one** jitted JAX call
  evaluating every record against device-resident parameter banks and
  reducing with a single ``segment_sum`` (sharded across devices for big
  frontiers).
* ``engine="grouped"``: the PR-1 reference oracle — group records of all
  candidates by model and evaluate each Level-2 model's vectorized
  :meth:`FittedModel.predict` once per call (~14 predictions per
  frontier).  It matches the scalar path to 1e-9 relative; the fused
  engine matches it to 1e-6 (see devicecost's module docstring).

Public API
----------
``cost_many(specs, workload, hw, mix, engine="fused")``
    Totals for a frontier of specs under one workload/mix — the batched
    equivalent of ``[cost_workload(s, workload, hw, mix) for s in specs]``
    (matching it to float tolerance; argmin-compatible).
``pack_frontier(specs, workload, mix)``
    The hardware-independent packed arrays of a frontier; score the same
    :class:`PackedFrontier` against many profiles (what-if hardware) with
    zero re-synthesis and zero recompilation.
``compiled_operation(op, spec, workload)``
    The cached compiled form of one operation's breakdown; synthesis runs
    once per (op, chain fingerprint, workload) and is reused across search
    calls, regions, and hardware profiles.
``clear_caches()``
    Drop all compile/instantiate memos (tests, element-library edits).

Caching layers (all keyed on hashable, frozen inputs):

1. ``instantiate`` is memoized in :mod:`repro.core.synthesis` on
   (element chain, workload) — population is simulated once per structure.
2. The per-(n_nodes, zipf_alpha) skew weight arrays of
   ``_level_popularity`` are memoized there too.
3. The compiled (model-id, size, count) arrays per (op, chain, workload)
   are memoized here, and the per-spec mix-weighted concatenation per
   (chain, workload, mix); hardware is *not* part of either key, so
   re-costing the same frontier on new hardware (the paper's what-if
   hardware questions) touches no synthesis code at all.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import devicecost
from repro.core.devicecost import _MODEL_NAMES, model_id as _model_id
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.synthesis import (CostBreakdown, Workload,
                                  clear_synthesis_caches,
                                  synthesize_operation)


@dataclasses.dataclass(frozen=True)
class CompiledBreakdown:
    """A CostBreakdown flattened into parallel arrays (one row per record)."""

    model_ids: np.ndarray    # int32  [R] — interned Level-2 model ids
    sizes: np.ndarray        # float64 [R] — primitive size arguments
    counts: np.ndarray       # float64 [R] — record weights

    @property
    def n_records(self) -> int:
        return len(self.sizes)

    def total(self, hw: HardwareProfile) -> float:
        """Scalar-equivalent total, one predict per distinct model."""
        out = 0.0
        for mid in np.unique(self.model_ids):
            mask = self.model_ids == mid
            y = _predict_padded(hw.model(_MODEL_NAMES[mid]), self.sizes[mask])
            out += float(np.dot(self.counts[mask], y))
        return out


#: largest padded predict shape; bigger inputs evaluate in _MAX_BUCKET chunks
_MAX_BUCKET = 4096


def _predict_padded(model, sizes: np.ndarray) -> np.ndarray:
    """model.predict with the input padded to a power-of-two length.

    Frontier sizes vary call to call; un-jitted jax ops compile per shape,
    so raw variable-length predicts would recompile XLA kernels on almost
    every search.  Bucketing lengths to powers of two — capped at
    ``_MAX_BUCKET``, with larger inputs evaluated in full chunks — bounds
    the shape set to ~9 shapes per model (compile once, reuse forever).
    Padding slots carry x=1.0 and are sliced off — per-record outputs are
    unchanged because every model evaluates records elementwise /
    row-independently.
    """
    n = len(sizes)
    if n == 0:
        return np.zeros(0, dtype=np.float64)
    if n > _MAX_BUCKET:
        return np.concatenate([
            _predict_padded(model, sizes[i:i + _MAX_BUCKET])
            for i in range(0, n, _MAX_BUCKET)])
    bucket = max(1 << (n - 1).bit_length(), 16)
    if bucket == n:
        padded = sizes
    else:
        padded = np.ones(bucket, dtype=sizes.dtype)
        padded[:n] = sizes
    return np.asarray(model.predict(padded)[:n], dtype=np.float64)


def compile_breakdown(cb: CostBreakdown) -> CompiledBreakdown:
    n = len(cb.records)
    model_ids = np.empty(n, dtype=np.int32)
    sizes = np.empty(n, dtype=np.float64)
    counts = np.empty(n, dtype=np.float64)
    for i, rec in enumerate(cb.records):
        model_ids[i] = _model_id(rec.level2)
        sizes[i] = rec.size
        counts[i] = rec.count
    model_ids.setflags(write=False)
    sizes.setflags(write=False)
    counts.setflags(write=False)
    return CompiledBreakdown(model_ids, sizes, counts)


@functools.lru_cache(maxsize=65536)
def _compiled_operation(op: str, chain: Tuple[Element, ...],
                        workload: Workload) -> CompiledBreakdown:
    spec = DataStructureSpec("batch", chain)
    return compile_breakdown(synthesize_operation(op, spec, workload))


def compiled_operation(op: str, spec: DataStructureSpec,
                       workload: Workload) -> CompiledBreakdown:
    """Synthesize + compile one operation, memoized on (op, chain, workload)."""
    return _compiled_operation(op, spec.chain, workload)


def clear_caches() -> None:
    _compiled_operation.cache_clear()
    _packed_spec.cache_clear()
    clear_synthesis_caches()


def cache_info() -> Dict[str, Tuple]:
    from repro.core.synthesis import _instantiate_levels, _zipf_collision_mass
    return {"compiled_operation": _compiled_operation.cache_info(),
            "packed_spec": _packed_spec.cache_info(),
            "instantiate": _instantiate_levels.cache_info(),
            "zipf_mass": _zipf_collision_mass.cache_info()}


# ---------------------------------------------------------------------------
# Frontier packing (hardware-independent)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackedFrontier:
    """A whole frontier flattened to parallel record arrays.

    Hardware never enters the packing — score the same object against any
    number of profiles (``score(hw)``); with the fused engine that is a
    pure device parameter-table swap.
    """

    ids: np.ndarray            # int32   [R] — interned Level-2 model ids
    sizes: np.ndarray          # float64 [R] — primitive size arguments
    weights: np.ndarray        # float64 [R] — count x op-mix weight
    #: design index per TILE-record tile, sorted ascending; each design's
    #: record block is padded to a TILE multiple (pad rows carry weight 0)
    tile_segments: np.ndarray  # int64 [R // TILE]
    n_segments: int

    @property
    def segments(self) -> np.ndarray:
        """Per-record design indices (expanded from the tile layout)."""
        return np.repeat(self.tile_segments, devicecost.TILE)

    def score(self, hw: HardwareProfile, engine: str = "fused",
              shard: Optional[bool] = None) -> np.ndarray:
        """Per-design totals under ``hw`` via the selected engine."""
        if engine == "fused":
            return devicecost.score_frontier(
                self.ids, self.sizes, self.weights, self.tile_segments,
                self.n_segments, hw, shard=shard)
        if engine != "grouped":
            raise ValueError(f"unknown engine: {engine!r}")
        segments = self.segments
        totals = np.zeros(self.n_segments, dtype=np.float64)
        for mid in np.unique(self.ids):
            mask = self.ids == mid
            y = _predict_padded(hw.model(_MODEL_NAMES[mid]),
                                self.sizes[mask])
            contrib = self.weights[mask] * y
            totals += np.bincount(segments[mask], weights=contrib,
                                  minlength=self.n_segments)
        return totals


@functools.lru_cache(maxsize=65536)
def _packed_spec(chain: Tuple[Element, ...], workload: Workload,
                 mix_items: Tuple[Tuple[str, float], ...]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One spec's mix-weighted (ids, sizes, weights), concatenated over the
    operation mix and padded to a TILE multiple (pad rows carry weight 0,
    contributing exactly nothing) — the memo that turns repeated frontier
    packing into one cache hit per (chain, workload, mix)."""
    parts = [_compiled_operation(op, chain, workload) for op, _ in mix_items]
    n = sum(c.n_records for c in parts)
    padded = -n % devicecost.TILE
    # pad rows reuse the block's own first model id: an arbitrary id (e.g.
    # 0) could name a model another profile interned, tripping the scoring
    # engines' model-availability checks on records that weigh nothing
    real_ids = np.concatenate([c.model_ids for c in parts]) if parts else \
        np.zeros(0, np.int32)
    pad_id = real_ids[0] if n else 0
    ids = np.concatenate([real_ids, np.full(padded, pad_id, np.int32)])
    sizes = np.concatenate([c.sizes for c in parts] +
                           [np.ones(padded, np.float64)])
    weights = np.concatenate([c.counts * float(w)
                              for c, (_, w) in zip(parts, mix_items)] +
                             [np.zeros(padded, np.float64)])
    for arr in (ids, sizes, weights):
        arr.setflags(write=False)
    return ids, sizes, weights


def pack_frontier(specs: Sequence[DataStructureSpec], workload: Workload,
                  mix: Optional[Dict[str, float]] = None) -> PackedFrontier:
    """Flatten a frontier into parallel record arrays (no hardware)."""
    mix = mix or {"get": float(workload.n_queries)}
    mix_items = tuple(mix.items())
    per_spec = [_packed_spec(spec.chain, workload, mix_items)
                for spec in specs]
    if not per_spec:
        empty = np.zeros(0)
        return PackedFrontier(empty.astype(np.int32), empty, empty,
                              empty.astype(np.int64), 0)
    tile_segments = np.repeat(
        np.arange(len(per_spec), dtype=np.int64),
        [len(ids) // devicecost.TILE for ids, _, _ in per_spec])
    return PackedFrontier(
        np.concatenate([p[0] for p in per_spec]),
        np.concatenate([p[1] for p in per_spec]),
        np.concatenate([p[2] for p in per_spec]),
        tile_segments, len(per_spec))


# ---------------------------------------------------------------------------
# Frontier evaluation
# ---------------------------------------------------------------------------
def cost_many(specs: Sequence[DataStructureSpec], workload: Workload,
              hw: HardwareProfile,
              mix: Optional[Dict[str, float]] = None,
              engine: str = "fused") -> np.ndarray:
    """Workload cost for every spec in one batched evaluation.

    Equivalent to ``[cost_workload(s, workload, hw, mix) for s in specs]``.
    The default fused engine scores the packed frontier in one jitted JAX
    call (totals within 1e-6 relative of the scalar path — float32 banked
    evaluation, see :mod:`repro.core.devicecost`); ``engine="grouped"``
    keeps the PR-1 per-model grouped oracle, whose per-record predictions
    are bit-identical to the scalar path (same model code, same float32
    inputs) so totals agree to float64 accumulation tolerance (~1e-12
    relative) and argmins coincide exactly.
    """
    return pack_frontier(specs, workload, mix).score(hw, engine=engine)


def cost_one(op: str, spec: DataStructureSpec, workload: Workload,
             hw: HardwareProfile) -> float:
    """Batched-path cost of a single operation (compiled + memoized)."""
    return compiled_operation(op, spec, workload).total(hw)


def cost_workload_batched(spec: DataStructureSpec, workload: Workload,
                          hw: HardwareProfile,
                          mix: Optional[Dict[str, float]] = None,
                          engine: str = "fused") -> float:
    """Drop-in batched equivalent of :func:`repro.core.synthesis.cost_workload`."""
    return float(cost_many([spec], workload, hw, mix, engine=engine)[0])
