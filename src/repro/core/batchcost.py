"""Batch cost-synthesis engine: cost whole candidate frontiers per call.

The Data Calculator's promise (paper §4, Algorithm 1) is answering what-if
design questions in seconds by *synthesizing* cost.  The scalar path costs
one design at a time — ``AccessRecord.cost`` dispatches one
``predict_scalar`` per record, so a search over N candidates pays
N x records x models worth of per-call model-evaluation overhead.

This module compiles each synthesized :class:`CostBreakdown` into parallel
numpy arrays (Level-2 model id, size argument, weighted count), groups the
records of *all* candidates by model, and evaluates each Level-2 model's
already-vectorized :meth:`FittedModel.predict` exactly once per call —
turning a frontier evaluation into ~14 vectorized predictions regardless
of how many designs are on the frontier.

Public API
----------
``cost_many(specs, workload, hw, mix)``
    Totals for a frontier of specs under one workload/mix — the batched
    equivalent of ``[cost_workload(s, workload, hw, mix) for s in specs]``
    (matching it to float tolerance; argmin-compatible).
``compiled_operation(op, spec, workload)``
    The cached compiled form of one operation's breakdown; synthesis runs
    once per (op, chain fingerprint, workload) and is reused across search
    calls, regions, and hardware profiles.
``clear_caches()``
    Drop all compile/instantiate memos (tests, element-library edits).

Caching layers (all keyed on hashable, frozen inputs):

1. ``instantiate`` is memoized in :mod:`repro.core.synthesis` on
   (element chain, workload) — population is simulated once per structure.
2. The per-(n_nodes, zipf_alpha) skew weight arrays of
   ``_level_popularity`` are memoized there too.
3. The compiled (model-id, size, count) arrays per (op, chain, workload)
   are memoized here; hardware is *not* part of the key, so re-costing the
   same frontier on new hardware (the paper's what-if hardware questions)
   touches no synthesis code at all.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.synthesis import (CostBreakdown, Workload,
                                  clear_synthesis_caches,
                                  synthesize_operation)

# ---------------------------------------------------------------------------
# Level-2 model-name interning: compiled records refer to models by id
# ---------------------------------------------------------------------------
_MODEL_IDS: Dict[str, int] = {}
_MODEL_NAMES: List[str] = []


def _model_id(name: str) -> int:
    mid = _MODEL_IDS.get(name)
    if mid is None:
        mid = len(_MODEL_NAMES)
        _MODEL_IDS[name] = mid
        _MODEL_NAMES.append(name)
    return mid


@dataclasses.dataclass(frozen=True)
class CompiledBreakdown:
    """A CostBreakdown flattened into parallel arrays (one row per record)."""

    model_ids: np.ndarray    # int32  [R] — interned Level-2 model ids
    sizes: np.ndarray        # float64 [R] — primitive size arguments
    counts: np.ndarray       # float64 [R] — record weights

    @property
    def n_records(self) -> int:
        return len(self.sizes)

    def total(self, hw: HardwareProfile) -> float:
        """Scalar-equivalent total, one predict per distinct model."""
        out = 0.0
        for mid in np.unique(self.model_ids):
            mask = self.model_ids == mid
            y = _predict_padded(hw.model(_MODEL_NAMES[mid]), self.sizes[mask])
            out += float(np.dot(self.counts[mask], y))
        return out


#: largest padded predict shape; bigger inputs evaluate in _MAX_BUCKET chunks
_MAX_BUCKET = 4096


def _predict_padded(model, sizes: np.ndarray) -> np.ndarray:
    """model.predict with the input padded to a power-of-two length.

    Frontier sizes vary call to call; un-jitted jax ops compile per shape,
    so raw variable-length predicts would recompile XLA kernels on almost
    every search.  Bucketing lengths to powers of two — capped at
    ``_MAX_BUCKET``, with larger inputs evaluated in full chunks — bounds
    the shape set to ~9 shapes per model (compile once, reuse forever).
    Padding slots carry x=1.0 and are sliced off — per-record outputs are
    unchanged because every model evaluates records elementwise /
    row-independently.
    """
    n = len(sizes)
    if n == 0:
        return np.zeros(0, dtype=np.float64)
    if n > _MAX_BUCKET:
        return np.concatenate([
            _predict_padded(model, sizes[i:i + _MAX_BUCKET])
            for i in range(0, n, _MAX_BUCKET)])
    bucket = max(1 << (n - 1).bit_length(), 16)
    if bucket == n:
        padded = sizes
    else:
        padded = np.ones(bucket, dtype=sizes.dtype)
        padded[:n] = sizes
    return np.asarray(model.predict(padded)[:n], dtype=np.float64)


def compile_breakdown(cb: CostBreakdown) -> CompiledBreakdown:
    n = len(cb.records)
    model_ids = np.empty(n, dtype=np.int32)
    sizes = np.empty(n, dtype=np.float64)
    counts = np.empty(n, dtype=np.float64)
    for i, rec in enumerate(cb.records):
        model_ids[i] = _model_id(rec.level2)
        sizes[i] = rec.size
        counts[i] = rec.count
    model_ids.setflags(write=False)
    sizes.setflags(write=False)
    counts.setflags(write=False)
    return CompiledBreakdown(model_ids, sizes, counts)


@functools.lru_cache(maxsize=65536)
def _compiled_operation(op: str, chain: Tuple[Element, ...],
                        workload: Workload) -> CompiledBreakdown:
    spec = DataStructureSpec("batch", chain)
    return compile_breakdown(synthesize_operation(op, spec, workload))


def compiled_operation(op: str, spec: DataStructureSpec,
                       workload: Workload) -> CompiledBreakdown:
    """Synthesize + compile one operation, memoized on (op, chain, workload)."""
    return _compiled_operation(op, spec.chain, workload)


def clear_caches() -> None:
    _compiled_operation.cache_clear()
    clear_synthesis_caches()


def cache_info() -> Dict[str, Tuple]:
    from repro.core.synthesis import _instantiate_levels, _zipf_collision_mass
    return {"compiled_operation": _compiled_operation.cache_info(),
            "instantiate": _instantiate_levels.cache_info(),
            "zipf_mass": _zipf_collision_mass.cache_info()}


# ---------------------------------------------------------------------------
# Frontier evaluation
# ---------------------------------------------------------------------------
def cost_many(specs: Sequence[DataStructureSpec], workload: Workload,
              hw: HardwareProfile,
              mix: Optional[Dict[str, float]] = None) -> np.ndarray:
    """Workload cost for every spec in one grouped evaluation.

    Equivalent to ``[cost_workload(s, workload, hw, mix) for s in specs]``
    but with one ``FittedModel.predict`` call per distinct Level-2 model
    across the *entire* frontier.  Per-record predictions are identical to
    the scalar path (same model code, same float32 inputs); only the
    summation order differs, so totals agree to float64 accumulation
    tolerance (~1e-12 relative) and argmins coincide.
    """
    mix = mix or {"get": float(workload.n_queries)}
    n = len(specs)
    if n == 0:
        return np.zeros(0, dtype=np.float64)

    ids_parts: List[np.ndarray] = []
    sizes_parts: List[np.ndarray] = []
    weight_parts: List[np.ndarray] = []
    seg_parts: List[np.ndarray] = []
    for i, spec in enumerate(specs):
        for op, op_weight in mix.items():
            comp = compiled_operation(op, spec, workload)
            ids_parts.append(comp.model_ids)
            sizes_parts.append(comp.sizes)
            weight_parts.append(comp.counts * float(op_weight))
            seg_parts.append(np.full(comp.n_records, i, dtype=np.int64))

    ids = np.concatenate(ids_parts)
    sizes = np.concatenate(sizes_parts)
    weights = np.concatenate(weight_parts)
    segments = np.concatenate(seg_parts)

    totals = np.zeros(n, dtype=np.float64)
    for mid in np.unique(ids):
        mask = ids == mid
        y = _predict_padded(hw.model(_MODEL_NAMES[mid]), sizes[mask])
        contrib = weights[mask] * y
        totals += np.bincount(segments[mask], weights=contrib, minlength=n)
    return totals


def cost_one(op: str, spec: DataStructureSpec, workload: Workload,
             hw: HardwareProfile) -> float:
    """Batched-path cost of a single operation (compiled + memoized)."""
    return compiled_operation(op, spec, workload).total(hw)


def cost_workload_batched(spec: DataStructureSpec, workload: Workload,
                          hw: HardwareProfile,
                          mix: Optional[Dict[str, float]] = None) -> float:
    """Drop-in batched equivalent of :func:`repro.core.synthesis.cost_workload`."""
    return float(cost_many([spec], workload, hw, mix)[0])
