"""Batch cost-synthesis engine: cost whole candidate frontiers per call.

The Data Calculator's promise (paper §4, Algorithm 1) is answering what-if
design questions in seconds by *synthesizing* cost.  The scalar path costs
one design at a time — ``AccessRecord.cost`` dispatches one
``predict_scalar`` per record, so a search over N candidates pays
N x records x models worth of per-call model-evaluation overhead.

This module compiles each synthesized :class:`CostBreakdown` into parallel
numpy arrays (Level-2 model id, size argument, weighted count) and scores
whole frontiers through one of two engines:

* ``engine="fused"`` (default): the packed frontier arrays go to
  :func:`repro.core.devicecost.score_frontier` — **one** jitted JAX call
  evaluating every record against device-resident parameter banks and
  reducing with a single ``segment_sum`` (sharded across devices for big
  frontiers).
* ``engine="grouped"``: the PR-1 reference oracle — group records of all
  candidates by model and evaluate each Level-2 model's vectorized
  :meth:`FittedModel.predict` once per call (~14 predictions per
  frontier).  It matches the scalar path to 1e-9 relative; the fused
  engine matches it to 1e-6 (see devicecost's module docstring).

Public API
----------
``cost_many(specs, workload, hw, mix, engine="fused")``
    Totals for a frontier of specs under one workload/mix — the batched
    equivalent of ``[cost_workload(s, workload, hw, mix) for s in specs]``
    (matching it to float tolerance; argmin-compatible).
``pack_frontier(specs, workload, mix)``
    The hardware-independent packed arrays of a frontier; score the same
    :class:`PackedFrontier` against many profiles (what-if hardware) with
    zero re-synthesis and zero recompilation.  Construction is
    **template-vectorized** (:mod:`repro.core.templatecost`): chains never
    seen before are grouped by structural template and synthesized as
    batched numpy column ops — no per-design Python walk — while chains
    packed earlier splice their cached per-spec segments straight in
    (*incremental packing*; a re-packed identical frontier is one cache
    hit).
``concat_frontiers(parts)``
    Splice already-packed frontiers into one — hill-climb/beam rounds and
    ``whatif`` baseline+variant pairs compose retained frontiers instead
    of re-packing every design.
``pack_sweep(specs, workloads, mixes)`` / ``cost_sweep(...)``
    The **workload-sweep engine** (PR 5): a (designs x workloads) grid —
    a read/write-ratio, skew, selectivity or data-size continuum — packed
    by splicing the shared workload-free template statics with
    per-workload geometry columns (:func:`repro.core.templatecost.
    pack_points`) and scored in ONE fused call
    (:func:`repro.core.devicecost.score_sweep`: bank gathers issued once
    for all workloads).  ``cost_sweep`` returns the ``[W, D]`` totals
    grid; hardware stays a pure parameter-table swap.
    ``concat_sweeps(parts)`` splices sweeps over the same points along
    the design axis (the serving coalescing primitive).
``compiled_operation(op, spec, workload)``
    The cached compiled form of one operation's breakdown through the
    *scalar* expert system — the per-design oracle the vectorized packer
    is tested against (and the ``cost_one`` fast path).
``clear_caches()``
    Drop every memo in the synthesis/packing stack (tests,
    element-library edits) — including the template, segment, frontier
    and sweep caches, and any cache registered via :func:`register_cache`.

All memo layers are thread-safe: the insertable dict caches (and the
interning/device-table state in :mod:`repro.core.devicecost`) share the
single re-entrant lock of :mod:`repro.core.memo`, so concurrent scoring
threads — the :mod:`repro.serving` access pattern — cannot corrupt
hit/miss accounting, and ``clear_caches()``/``cache_info()`` drain and
snapshot every layer atomically.  Misses still compute outside the lock
(two racing threads may redundantly pack one frontier; both store equal
values).

Caching layers (all keyed on hashable, frozen inputs — hardware is *not*
part of any key, so re-costing a frontier on new hardware touches no
synthesis code at all; the full memo map lives in
``docs/cost_pipeline.md`` and the key invariants are asserted by
``tests/test_cache_keys.py``):

1. ``chain_statics`` / ``segment_statics`` in
   :mod:`repro.core.templatecost` — the workload-FREE template half of
   every segment (level structure, regions, record model-ids), keyed on
   (chain, depth signature) and (template, ops); a workload sweep
   re-derives only numeric columns.  ``chain_geometry`` layers one
   workload's numerics on top, and the scalar ``instantiate`` twin lives
   in :mod:`repro.core.synthesis`.
2. The per-(n_nodes, zipf_alpha) skew weights and per-template
   ``symbolic_breakdown`` schemas, memoized in synthesis.
3. The *segment cache* here: each spec's mix-weighted, tile-padded
   (ids, sizes, weights) arrays per (chain, workload, mix) — populated in
   batch by the vectorized packer, reused record-for-record by later
   frontiers containing the same chain.
4. The *frontier cache*: whole packed frontiers per (chains, workload,
   mix) — and the *sweep cache*: whole (designs x workloads) grids per
   (chains, points) — the steady-state what-if-serving hit paths.
5. ``compiled_operation`` per (op, chain, workload) — scalar-oracle path
   only.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import devicecost, memo as memo_module, templatecost
from repro.core.devicecost import _MODEL_NAMES, model_id as _model_id
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.memo import MEMO_LOCK, CacheInfo, DictCache as _DictCache
from repro.core.synthesis import (CostBreakdown, Workload,
                                  clear_synthesis_caches,
                                  synthesize_operation)


@dataclasses.dataclass(frozen=True)
class CompiledBreakdown:
    """A CostBreakdown flattened into parallel arrays (one row per record)."""

    model_ids: np.ndarray    # int32  [R] — interned Level-2 model ids
    sizes: np.ndarray        # float64 [R] — primitive size arguments
    counts: np.ndarray       # float64 [R] — record weights

    @property
    def n_records(self) -> int:
        return len(self.sizes)

    def total(self, hw: HardwareProfile) -> float:
        """Scalar-equivalent total, one predict per distinct model."""
        out = 0.0
        for mid in np.unique(self.model_ids):
            mask = self.model_ids == mid
            y = _predict_padded(hw.model(_MODEL_NAMES[mid]), self.sizes[mask])
            out += float(np.dot(self.counts[mask], y))
        return out


#: largest padded predict shape; bigger inputs evaluate in _MAX_BUCKET chunks
_MAX_BUCKET = 4096


def _predict_padded(model, sizes: np.ndarray) -> np.ndarray:
    """model.predict with the input padded to a power-of-two length.

    Frontier sizes vary call to call; un-jitted jax ops compile per shape,
    so raw variable-length predicts would recompile XLA kernels on almost
    every search.  Bucketing lengths to powers of two — capped at
    ``_MAX_BUCKET``, with larger inputs evaluated in full chunks — bounds
    the shape set to ~9 shapes per model (compile once, reuse forever).
    Padding slots carry x=1.0 and are sliced off — per-record outputs are
    unchanged because every model evaluates records elementwise /
    row-independently.
    """
    n = len(sizes)
    if n == 0:
        return np.zeros(0, dtype=np.float64)
    if n > _MAX_BUCKET:
        return np.concatenate([
            _predict_padded(model, sizes[i:i + _MAX_BUCKET])
            for i in range(0, n, _MAX_BUCKET)])
    bucket = max(1 << (n - 1).bit_length(), 16)
    if bucket == n:
        padded = sizes
    else:
        padded = np.ones(bucket, dtype=sizes.dtype)
        padded[:n] = sizes
    return np.asarray(model.predict(padded)[:n], dtype=np.float64)


def compile_breakdown(cb: CostBreakdown) -> CompiledBreakdown:
    n = len(cb.records)
    model_ids = np.empty(n, dtype=np.int32)
    sizes = np.empty(n, dtype=np.float64)
    counts = np.empty(n, dtype=np.float64)
    for i, rec in enumerate(cb.records):
        model_ids[i] = _model_id(rec.level2)
        sizes[i] = rec.size
        counts[i] = rec.count
    model_ids.setflags(write=False)
    sizes.setflags(write=False)
    counts.setflags(write=False)
    return CompiledBreakdown(model_ids, sizes, counts)


@functools.lru_cache(maxsize=65536)
def _compiled_operation(op: str, chain: Tuple[Element, ...],
                        workload: Workload) -> CompiledBreakdown:
    spec = DataStructureSpec("batch", chain)
    return compile_breakdown(synthesize_operation(op, spec, workload))


def compiled_operation(op: str, spec: DataStructureSpec,
                       workload: Workload) -> CompiledBreakdown:
    """Synthesize + compile one operation, memoized on (op, chain, workload)."""
    return _compiled_operation(op, spec.chain, workload)


#: per-spec packed segments — (chain, workload, mix) -> (ids, sizes, weights);
#: snapshot-enabled: segments are the expensive hardware-free synthesis
#: product a warm-restarted service wants back first
_segment_cache = _DictCache(maxsize=65536, name="packed_spec",
                            snapshot=True)
#: whole packed frontiers — (chains, workload, mix) -> PackedFrontier;
#: snapshot-enabled so a warm-restarted service answers its retained
#: questions without even the resplice (values are stripped of their
#: live-only ``__dict__`` memos at capture time)
_frontier_cache = _DictCache(maxsize=16, name="frontier", snapshot=True)
#: whole packed sweeps — (chains, points) -> PackedSweep; snapshot-enabled
#: (capture strips the device-resident ``_f32`` stack)
_sweep_cache = _DictCache(maxsize=8, name="sweep", snapshot=True)


def _restore_segment(value, env):
    """Remap a snapshotted (ids, sizes, weights) segment onto the live
    model-id interning (see :func:`repro.core.memo.restore_caches`)."""
    ids, sizes, weights = value
    remap = env["model_ids"]
    ids = np.ascontiguousarray(remap[np.asarray(ids, dtype=np.int64)])
    ids.setflags(write=False)
    return (ids, sizes, weights)


def _remap_ids(ids, remap, shared: Dict[int, np.ndarray]) -> np.ndarray:
    """Remap one interned-ids array, preserving object sharing (rectangular
    sweeps alias a single ids array across all their per-point frontiers —
    ``PackedSweep.rectangular`` leans on that identity)."""
    key = id(ids)
    if key not in shared:
        out = np.ascontiguousarray(
            remap[np.asarray(ids, dtype=np.int64)].astype(np.int32))
        out.setflags(write=False)
        shared[key] = out
    return shared[key]


def _strip_frontier(f: "PackedFrontier") -> "PackedFrontier":
    """A clean copy without the cached ``_f32`` views (capture transform)."""
    return PackedFrontier(f.ids, f.sizes, f.weights, f.tile_segments,
                          f.n_segments)


def _restore_frontier(value, env, shared=None):
    f = value
    ids = _remap_ids(f.ids, env["model_ids"],
                     shared if shared is not None else {})
    return PackedFrontier(ids, f.sizes, f.weights, f.tile_segments,
                          f.n_segments)


def _strip_sweep(s: "PackedSweep") -> "PackedSweep":
    """Capture transform: drop the device-resident ``_f32`` stack and the
    ``_rect`` memo (both rebuild lazily), and canonicalize a rectangular
    sweep's equal per-point ids arrays onto ONE shared object — the
    pickle then stores a single ids array per sweep (not ``n_points``
    equal copies) and :func:`_remap_ids`' sharing-preserving restore
    keeps the alias, so ``rectangular`` short-circuits on identity."""
    frontiers = tuple(_strip_frontier(f) for f in s.frontiers)
    if frontiers and s.rectangular:
        ids0 = frontiers[0].ids
        frontiers = frontiers[:1] + tuple(
            PackedFrontier(ids0, f.sizes, f.weights, f.tile_segments,
                           f.n_segments) for f in frontiers[1:])
    return PackedSweep(s.points, s.n_designs, frontiers)


def _restore_sweep(value, env):
    shared: Dict[int, np.ndarray] = {}
    frontiers = tuple(_restore_frontier(f, env, shared)
                      for f in value.frontiers)
    return PackedSweep(value.points, value.n_designs, frontiers)


memo_module.register_restore_transform("packed_spec", _restore_segment)
memo_module.register_capture_transform("frontier", _strip_frontier)
memo_module.register_restore_transform("frontier", _restore_frontier)
memo_module.register_capture_transform("sweep", _strip_sweep)
memo_module.register_restore_transform("sweep", _restore_sweep)

#: caches owned by other modules (e.g. autocomplete's frontier
#: enumeration memo) that must drain with ours: name -> (info_fn, clear_fn)
_EXTERNAL_CACHES: Dict[str, Tuple[Callable, Callable]] = {}


def register_cache(name: str, info_fn: Callable[[], Tuple],
                   clear_fn: Callable[[], None]) -> None:
    """Hook an external memo into :func:`clear_caches`/:func:`cache_info`
    (keeps 'clear everything' a single call as the cache stack grows)."""
    _EXTERNAL_CACHES[name] = (info_fn, clear_fn)


def clear_caches() -> None:
    # MEMO_LOCK makes the drain atomic with respect to concurrent scorers:
    # no thread can repopulate one layer while a later layer is still being
    # cleared (every DictCache put/get takes the same re-entrant lock).
    with MEMO_LOCK:
        _compiled_operation.cache_clear()
        _segment_cache.clear()
        _frontier_cache.clear()
        _sweep_cache.clear()
        templatecost.clear_template_caches()
        clear_synthesis_caches()
        for _, clear_fn in _EXTERNAL_CACHES.values():
            clear_fn()


def cache_info() -> Dict[str, Tuple]:
    from repro.core.synthesis import (_instantiate_levels,
                                      _zipf_collision_mass,
                                      symbolic_breakdown)
    with MEMO_LOCK:
        info = {"compiled_operation": _compiled_operation.cache_info(),
                "packed_spec": _segment_cache.info(),
                "frontier": _frontier_cache.info(),
                "sweep": _sweep_cache.info(),
                "instantiate": _instantiate_levels.cache_info(),
                "zipf_mass": _zipf_collision_mass.cache_info(),
                "symbolic_breakdown": symbolic_breakdown.cache_info()}
        info.update(templatecost.cache_info())
        for name, (info_fn, _) in _EXTERNAL_CACHES.items():
            info[name] = info_fn()
        return info


# ---------------------------------------------------------------------------
# Frontier packing (hardware-independent)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackedFrontier:
    """A whole frontier flattened to parallel record arrays.

    Hardware never enters the packing — score the same object against any
    number of profiles (``score(hw)``); with the fused engine that is a
    pure device parameter-table swap.
    """

    ids: np.ndarray            # int32   [R] — interned Level-2 model ids
    sizes: np.ndarray          # float64 [R] — primitive size arguments
    weights: np.ndarray        # float64 [R] — count x op-mix weight
    #: design index per TILE-record tile, sorted ascending; each design's
    #: record block is padded to a TILE multiple (pad rows carry weight 0)
    tile_segments: np.ndarray  # int64 [R // TILE]
    n_segments: int

    @property
    def segments(self) -> np.ndarray:
        """Per-record design indices (expanded from the tile layout)."""
        return np.repeat(self.tile_segments, devicecost.TILE)

    def _fused_arrays(self) -> Tuple[np.ndarray, ...]:
        """Device-dtype views for the fused scorer, converted once.

        Steady-state what-if serving scores the same retained frontier
        over and over; caching the float32/int32 conversions here (the
        instance is frozen — the memo rides its ``__dict__``) keeps each
        repeat score a pure device call instead of three array copies.
        """
        cached = self.__dict__.get("_f32")
        if cached is None:
            cached = (np.asarray(self.ids, np.int32),
                      np.asarray(self.sizes, np.float32),
                      np.asarray(self.weights, np.float32),
                      np.asarray(self.tile_segments, np.int32))
            object.__setattr__(self, "_f32", cached)
        return cached

    def split(self, n_parts: int) -> List["PackedFrontier"]:
        """Segment-contiguous sub-frontiers on tile-aligned cuts (via
        :func:`repro.core.templatecost.segment_ranges`) — the serving
        shard pool's partition primitive.  Concatenating the parts'
        ``score`` outputs reproduces ``score`` on the whole frontier
        bit for bit: every design's records land wholly in one part."""
        n_parts = max(min(n_parts, self.n_segments), 1)
        if n_parts <= 1:
            return [self]
        seg_cuts, tile_cuts = templatecost.segment_ranges(
            self.tile_segments, self.n_segments, n_parts)
        tile = devicecost.TILE
        return [PackedFrontier(
            self.ids[tile_cuts[d] * tile:tile_cuts[d + 1] * tile],
            self.sizes[tile_cuts[d] * tile:tile_cuts[d + 1] * tile],
            self.weights[tile_cuts[d] * tile:tile_cuts[d + 1] * tile],
            self.tile_segments[tile_cuts[d]:tile_cuts[d + 1]]
            - seg_cuts[d],
            int(seg_cuts[d + 1] - seg_cuts[d]))
            for d in range(n_parts)]

    def score(self, hw: HardwareProfile, engine: str = "fused",
              shard: Optional[bool] = None, device=None) -> np.ndarray:
        """Per-design totals under ``hw`` via the selected engine.
        ``shard``/``device`` pass through to
        :func:`repro.core.devicecost.score_frontier` (fused only)."""
        if engine == "fused":
            ids, sizes, weights, tiles = self._fused_arrays()
            return devicecost.score_frontier(
                ids, sizes, weights, tiles,
                self.n_segments, hw, shard=shard, device=device)
        if engine != "grouped":
            raise ValueError(f"unknown engine: {engine!r}")
        segments = self.segments
        totals = np.zeros(self.n_segments, dtype=np.float64)
        for mid in np.unique(self.ids):
            mask = self.ids == mid
            y = _predict_padded(hw.model(_MODEL_NAMES[mid]),
                                self.sizes[mask])
            contrib = self.weights[mask] * y
            totals += np.bincount(segments[mask], weights=contrib,
                                  minlength=self.n_segments)
        return totals


def _assemble_frontier(per_spec: List[Tuple[np.ndarray, ...]]
                       ) -> PackedFrontier:
    if not per_spec:
        empty = np.zeros(0)
        return PackedFrontier(empty.astype(np.int32), empty, empty,
                              empty.astype(np.int64), 0)
    tile_segments = np.repeat(
        np.arange(len(per_spec), dtype=np.int64),
        [len(ids) // devicecost.TILE for ids, _, _ in per_spec])
    return PackedFrontier(
        np.concatenate([p[0] for p in per_spec]),
        np.concatenate([p[1] for p in per_spec]),
        np.concatenate([p[2] for p in per_spec]),
        tile_segments, len(per_spec))


def pack_frontier(specs: Sequence[DataStructureSpec], workload: Workload,
                  mix: Optional[Dict[str, float]] = None) -> PackedFrontier:
    """Flatten a frontier into parallel record arrays (no hardware).

    Incremental by construction: per-spec segments live in the segment
    cache keyed on the chain hash, so only never-seen chains reach the
    template-vectorized synthesizer (:func:`templatecost.pack_specs` —
    batched numpy ops, no per-design Python); everything else splices its
    retained segment back in.  A frontier packed with identical (chains,
    workload, mix) is returned whole from the frontier cache — the
    steady-state what-if-serving path.
    """
    mix = mix or {"get": float(workload.n_queries)}
    mix_items = tuple(mix.items())
    if not specs:
        return _assemble_frontier([])
    chains = tuple(spec.chain for spec in specs)
    frontier_key = (chains, workload, mix_items)
    packed = _frontier_cache.get(frontier_key)
    if packed is not None:
        return packed
    per_spec: List[Optional[Tuple[np.ndarray, ...]]] = []
    missing: Dict[Tuple[Element, ...], List[int]] = {}
    for i, chain in enumerate(chains):
        seg = _segment_cache.get((chain, workload, mix_items))
        per_spec.append(seg)
        if seg is None:
            missing.setdefault(chain, []).append(i)
    if missing:
        new_chains = list(missing)
        for chain, seg in zip(new_chains, templatecost.pack_specs(
                new_chains, workload, mix_items)):
            _segment_cache.put((chain, workload, mix_items), seg)
            for i in missing[chain]:
                per_spec[i] = seg
    packed = _assemble_frontier(per_spec)
    _frontier_cache.put(frontier_key, packed)
    return packed


def concat_frontiers(parts: Sequence[PackedFrontier]) -> PackedFrontier:
    """Splice packed frontiers into one (designs keep their order).

    The composition primitive behind incremental search: hill-climb/beam
    rounds pack only newly-mutated designs and splice them onto retained
    frontiers, and ``whatif.what_if_design`` scores baseline+variant as
    one spliced two-design frontier.  Scoring the result is identical to
    packing the concatenated spec list from scratch — segments are
    reused byte-for-byte, only the design numbering shifts.
    """
    parts = [p for p in parts if p.n_segments]
    if not parts:
        return _assemble_frontier([])
    if len(parts) == 1:
        return parts[0]
    offsets = np.cumsum([0] + [p.n_segments for p in parts[:-1]])
    return PackedFrontier(
        np.concatenate([p.ids for p in parts]),
        np.concatenate([p.sizes for p in parts]),
        np.concatenate([p.weights for p in parts]),
        np.concatenate([p.tile_segments + off
                        for p, off in zip(parts, offsets)]),
        sum(p.n_segments for p in parts))


# ---------------------------------------------------------------------------
# Workload sweeps: (designs x workloads) grids as one scoring product
# ---------------------------------------------------------------------------
#: one sweep point: (workload, frozen mix items)
SweepPoint = Tuple[Workload, Tuple[Tuple[str, float], ...]]


def normalize_points(workloads: Sequence[Workload],
                     mixes=None) -> Tuple[SweepPoint, ...]:
    """Canonical (workload, mix_items) points of a sweep.

    ``mixes`` may be ``None`` (each workload's default get-only mix), one
    mix dict applied to every point, or a sequence of per-point mix
    dicts (a read/write-ratio sweep varies the mix, not the workload).
    """
    workloads = tuple(workloads)
    if not workloads:
        raise ValueError("a sweep needs at least one workload point")
    if mixes is None or isinstance(mixes, dict):
        mixes = [mixes] * len(workloads)
    else:
        mixes = list(mixes)
        if len(mixes) != len(workloads):
            raise ValueError(f"{len(mixes)} mixes for "
                             f"{len(workloads)} workloads")
    return tuple(
        (w, tuple((mix or {"get": float(w.n_queries)}).items()))
        for w, mix in zip(workloads, mixes))


@dataclasses.dataclass(frozen=True)
class PackedSweep:
    """A (designs x workloads) grid packed for fused scoring.

    One :class:`PackedFrontier` per sweep point over the same designs.
    When the grid is *rectangular* — every point shares the record layout
    (same template statics; the common case for read/write-ratio, skew,
    selectivity or query-count sweeps at a fixed data size) — the frozen
    per-point frontiers share one interned ids array, and ``score``
    issues ONE :func:`repro.core.devicecost.score_sweep` call whose bank
    gathers are amortized across every workload.  Non-rectangular sweeps
    (``n_entries`` changing a chain's level structure) degrade gracefully
    to one spliced flat fused call.

    Hardware never enters the packing: scoring the same sweep against
    another profile is a pure parameter-table swap (zero recompilation,
    asserted in ``tests/test_sweep.py``).
    """

    points: Tuple[SweepPoint, ...]
    n_designs: int
    frontiers: Tuple[PackedFrontier, ...]   # one per point

    @property
    def n_points(self) -> int:
        return len(self.points)

    @property
    def rectangular(self) -> bool:
        cached = self.__dict__.get("_rect")
        if cached is None:
            f0 = self.frontiers[0] if self.frontiers else None
            cached = all(
                f.ids is f0.ids or np.array_equal(f.ids, f0.ids)
                for f in self.frontiers[1:])
            object.__setattr__(self, "_rect", cached)
        return cached

    def _sweep_arrays(self) -> Tuple:
        """(host ids, device-committed arrays), built once per sweep.

        Steady-state serving re-scores the same retained sweep; caching
        the padded float32 stack — resident on device when it fits one
        fused chunk (:func:`repro.core.devicecost.to_device_sweep`) —
        makes each repeat score a pure fused dispatch: no padding, no
        dtype conversion, no copies in either direction (the host-side
        ids stay cached for the scorer's availability check).
        """
        cached = self.__dict__.get("_f32")
        if cached is None:
            f0 = self.frontiers[0]
            bucket = devicecost._pow2(len(f0.ids), 16)
            padded = devicecost.pad_sweep(
                np.asarray(f0.ids, np.int32),
                np.stack([f.sizes for f in self.frontiers]),
                np.stack([f.weights for f in self.frontiers]),
                np.asarray(f0.tile_segments, np.int32), bucket)
            cached = (padded[0], devicecost.to_device_sweep(*padded))
            object.__setattr__(self, "_f32", cached)
        return cached

    def split(self, n_parts: int) -> List["PackedSweep"]:
        """Design-contiguous sub-sweeps (the serving shard pool's
        partition primitive): every point's frontier splits on the same
        design cuts, so stacking the parts' grids along axis 1
        reproduces ``score`` bit for bit.  Rectangular sweeps stay
        rectangular — each cut's ids slice is shared across points by
        object identity, exactly like the parent's interned ids."""
        n_parts = max(min(n_parts, self.n_designs), 1)
        if n_parts <= 1:
            return [self]
        shared_ids: Dict[int, List[np.ndarray]] = {}
        per_point: List[List[PackedFrontier]] = []
        for f in self.frontiers:
            parts = f.split(n_parts)
            cached = shared_ids.get(id(f.ids))
            if cached is None:
                shared_ids[id(f.ids)] = [p.ids for p in parts]
            else:
                parts = [PackedFrontier(cached[d], p.sizes, p.weights,
                                        p.tile_segments, p.n_segments)
                         for d, p in enumerate(parts)]
            per_point.append(parts)
        return [PackedSweep(self.points, per_point[0][d].n_segments,
                            tuple(row[d] for row in per_point))
                for d in range(n_parts)]

    def _sharded_arrays(self, shard: Optional[bool]):
        """The retained :func:`repro.core.devicecost.shard_sweep` product
        for this sweep, or ``None`` when the flat path should serve it.

        Built once per shard count and memoized on the frozen instance
        (like ``_sweep_arrays``): repeat scores of a retained sweep are
        pure pmap dispatches against device-committed shards — zero
        host->device copies, zero recompiles across hardware swaps.
        """
        host_ids, _ = self._sweep_arrays()
        n_dev = devicecost.sweep_shard_count(self.n_points, len(host_ids),
                                             shard)
        if (n_dev <= 1 and shard is not True) or self.n_points <= 1:
            return None   # single-row sweeps: score_sweep's flat fallback
        cache = self.__dict__.get("_f32_sh")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_f32_sh", cache)
        state = cache.get(n_dev)
        if state is None:
            f0 = self.frontiers[0]
            bucket = devicecost._pow2(len(f0.ids), 16)
            if bucket > devicecost.sweep_chunk(-(-self.n_points // n_dev)):
                state = False   # exceeds one fused chunk: chunked path
            else:
                state = devicecost.shard_sweep(*devicecost.pad_sweep(
                    np.asarray(f0.ids, np.int32),
                    np.stack([f.sizes for f in self.frontiers]),
                    np.stack([f.weights for f in self.frontiers]),
                    np.asarray(f0.tile_segments, np.int32), bucket),
                    n_dev)
            cache[n_dev] = state
        return state or None

    def score(self, hw: HardwareProfile, engine: str = "fused",
              shard: Optional[bool] = None, device=None) -> np.ndarray:
        """The ``[n_points, n_designs]`` totals grid under ``hw``.

        ``engine="grouped"`` scores each point's frontier through the
        PR-1 grouped oracle — bit-identical to looping ``cost_many(...,
        engine="grouped")`` per workload.  ``shard`` splits the fused
        grid across local devices along workload rows
        (:func:`repro.core.devicecost.sweep_shard_count` decides; the
        shard product is retained on the instance); ``device`` routes
        the flat fused call onto one specific device and implies
        ``shard=False``.
        """
        if self.n_designs == 0 or not self.points:
            return np.zeros((self.n_points, self.n_designs))
        if engine == "fused":
            if self.rectangular:
                host_ids, (ids, sizes, weights, tiles) = \
                    self._sweep_arrays()
                if device is None and shard is not False:
                    state = self._sharded_arrays(shard)
                    if state is not None:
                        return devicecost.score_sweep_sharded(
                            state, self.n_designs, hw, host_ids)
                    if shard is True and self.n_points == 1:
                        # single-row grid: segment-range pmap fallback
                        return self.frontiers[0].score(hw, shard=True)[None]
                return devicecost.score_sweep(ids, sizes, weights, tiles,
                                              self.n_designs, hw,
                                              host_ids=host_ids,
                                              shard=shard, device=device)
            # non-rectangular: one spliced flat fused call over the
            # whole grid (point-major), not one dispatch per point
            flat = concat_frontiers(list(self.frontiers))
            return flat.score(hw, shard=shard, device=device).reshape(
                self.n_points, self.n_designs)
        if engine != "grouped":
            raise ValueError(f"unknown engine: {engine!r}")
        return np.stack([f.score(hw, engine=engine)
                         for f in self.frontiers])


def pack_sweep(specs: Sequence[DataStructureSpec],
               workloads: Sequence[Workload],
               mixes=None) -> PackedSweep:
    """Pack a (designs x workloads) grid, splicing shared template
    statics with per-workload geometry columns.

    Incremental like :func:`pack_frontier`: per-(spec, point) segments
    come from the segment cache when present; only genuinely new
    (chain, point) cells reach the workload-axis packer
    (:func:`repro.core.templatecost.pack_points` — statics and record
    layout computed once per structural group, numerics batched over the
    workload axis).  Each point's frontier also lands in the frontier
    cache, so a later single-workload ``cost_many`` against any sweep
    point is a pure cache hit — and vice versa.  A repeated identical
    sweep is one sweep-cache hit.
    """
    points = normalize_points(workloads, mixes)
    specs = list(specs)
    chains = tuple(spec.chain for spec in specs)
    sweep_key = (chains, points)
    cached = _sweep_cache.get(sweep_key)
    if cached is not None:
        return cached
    per_point: List[List[Optional[Tuple[np.ndarray, ...]]]] = []
    #: missing-point pattern -> ordered unique chains missing exactly there
    missing: Dict[Tuple[int, ...], List[Tuple[Element, ...]]] = {}
    missing_pts: Dict[Tuple[Element, ...], List[int]] = {}
    for pi, (workload, mix_items) in enumerate(points):
        row: List[Optional[Tuple[np.ndarray, ...]]] = []
        for chain in chains:
            seg = _segment_cache.get((chain, workload, mix_items))
            row.append(seg)
            if seg is None:
                pts = missing_pts.setdefault(chain, [])
                if not pts or pts[-1] != pi:   # dedupe repeated chains
                    pts.append(pi)
        per_point.append(row)
    for chain, pts in missing_pts.items():
        missing.setdefault(tuple(pts), []).append(chain)
    # only genuinely new (chain, point) cells reach the packer: chains
    # already cached for SOME points re-pack only the points they miss
    for pts, group_chains in missing.items():
        packed = templatecost.pack_points(
            group_chains, [points[pi] for pi in pts])
        pos_of = {chain: i for i, chain in enumerate(group_chains)}
        for li, pi in enumerate(pts):
            workload, mix_items = points[pi]
            for ci, chain in enumerate(chains):
                if per_point[pi][ci] is None and chain in pos_of:
                    seg = packed[li][pos_of[chain]]
                    _segment_cache.put((chain, workload, mix_items), seg)
                    per_point[pi][ci] = seg
    frontiers = []
    for (workload, mix_items), row in zip(points, per_point):
        frontier = _assemble_frontier(row)
        if chains:
            _frontier_cache.put((chains, workload, mix_items), frontier)
        frontiers.append(frontier)
    sweep = PackedSweep(points, len(specs), tuple(frontiers))
    _sweep_cache.put(sweep_key, sweep)
    return sweep


def concat_sweeps(parts: Sequence["PackedSweep"]) -> PackedSweep:
    """Splice sweeps over the SAME points along the design axis.

    The serving coalescing primitive: concurrent sweep requests sharing
    a workload-point axis combine into one grid and one fused call, like
    PR-4's ``concat_frontiers`` window batching for flat questions.
    """
    parts = list(parts)
    if not parts:
        raise ValueError("concat_sweeps needs at least one sweep")
    points = parts[0].points
    for p in parts[1:]:
        if p.points != points:
            raise ValueError("cannot splice sweeps over different "
                             "workload points")
    if len(parts) == 1:
        return parts[0]
    frontiers = tuple(
        concat_frontiers([p.frontiers[w] for p in parts])
        for w in range(len(points)))
    return PackedSweep(points, sum(p.n_designs for p in parts), frontiers)


def cost_sweep(specs: Sequence[DataStructureSpec],
               workloads: Sequence[Workload], hw: HardwareProfile,
               mixes=None, engine: str = "fused") -> np.ndarray:
    """Workload cost for every (workload, design) cell, as one grid.

    Equivalent to stacking ``cost_many(specs, w, hw, mix)`` per sweep
    point (grouped engine: bit-identical; fused: one
    :func:`~repro.core.devicecost.score_sweep` call whose totals match
    the scalar oracle to the documented 1e-6).  Returns shape
    ``[len(workloads), len(specs)]``.
    """
    return pack_sweep(specs, workloads, mixes).score(hw, engine=engine)


# ---------------------------------------------------------------------------
# Frontier evaluation
# ---------------------------------------------------------------------------
def cost_many(specs: Sequence[DataStructureSpec], workload: Workload,
              hw: HardwareProfile,
              mix: Optional[Dict[str, float]] = None,
              engine: str = "fused") -> np.ndarray:
    """Workload cost for every spec in one batched evaluation.

    Equivalent to ``[cost_workload(s, workload, hw, mix) for s in specs]``.
    The default fused engine scores the packed frontier in one jitted JAX
    call (totals within 1e-6 relative of the scalar path — float32 banked
    evaluation, see :mod:`repro.core.devicecost`); ``engine="grouped"``
    keeps the PR-1 per-model grouped oracle, whose per-record predictions
    are bit-identical to the scalar path (same model code, same float32
    inputs) so totals agree to float64 accumulation tolerance (~1e-12
    relative) and argmins coincide exactly.
    """
    return pack_frontier(specs, workload, mix).score(hw, engine=engine)


def cost_one(op: str, spec: DataStructureSpec, workload: Workload,
             hw: HardwareProfile) -> float:
    """Batched-path cost of a single operation (compiled + memoized)."""
    return compiled_operation(op, spec, workload).total(hw)


def cost_workload_batched(spec: DataStructureSpec, workload: Workload,
                          hw: HardwareProfile,
                          mix: Optional[Dict[str, float]] = None,
                          engine: str = "fused") -> float:
    """Drop-in batched equivalent of :func:`repro.core.synthesis.cost_workload`."""
    return float(cost_many([spec], workload, hw, mix, engine=engine)[0])
