"""Operation and cost synthesis (paper §3, Fig. 5, Appendix E).

Given a data structure specification, a workload and a hardware profile,
the synthesizer:

1. simulates populating the structure (recursive block division) to obtain
   node counts / sizes / height — :class:`StructureInstance`;
2. walks the expert system per node, emitting a sequence of Level-1 access
   primitive invocations (the paper's abstract syntax tree), cache-aware:
   every random access carries the *path-so-far region size*, so nodes high
   in a hierarchy cost less than leaves (the §3 B-tree walk-through is
   reproduced verbatim by ``test_paper_btree_example``);
3. resolves Level-1 calls to Level-2 learned models and sums latencies.

Workload skew follows §3: node popularity p = count/total reweights the
region size of repeated accesses with w = 1/(p * sid).

This scalar expert system is the repo's **1e-9 oracle**.  The hot path —
packing whole search frontiers — runs through the template-vectorized
twin in :mod:`repro.core.templatecost`: chains are grouped by *structural
template* (the per-level :func:`element_class` sequence plus the
terminal's emission flags) and this module emits each template's record
schema **once** (:func:`symbolic_breakdown`); templatecost then evaluates
all per-chain numeric sizes/counts as batched numpy column ops.  The
vectorized skew weights (:func:`skew_multipliers`) live here so the skew
model has a single home.  Record-level parity between the two paths is
asserted in ``tests/test_templatecost.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import access
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile

PTR_BYTES = 8
FENCE_BYTES = 8


@dataclasses.dataclass(frozen=True)
class Workload:
    """Data + query profile (paper's 'workload' input)."""

    n_entries: int
    n_queries: int = 100
    key_bytes: int = 8
    value_bytes: int = 8
    #: 0.0 = uniform; else Zipf alpha over the key space (Fig. 8b)
    zipf_alpha: float = 0.0
    #: range query selectivity (fraction of the key space per range op)
    selectivity: float = 0.001

    @property
    def pair_bytes(self) -> int:
        return self.key_bytes + self.value_bytes


@dataclasses.dataclass
class AccessRecord:
    """One Level-1 invocation: primitive(size) x count (weighted)."""

    level1: str
    level2: str
    size: float              # primitive-specific size argument (bytes or n)
    count: float = 1.0
    note: str = ""

    def cost(self, hw: HardwareProfile) -> float:
        return self.count * hw.model(self.level2).predict_scalar(self.size)


@dataclasses.dataclass
class CostBreakdown:
    records: List[AccessRecord] = dataclasses.field(default_factory=list)

    def add(self, level1: str, size: float, *, count: float = 1.0,
            layout: str = "columnar", op: str = "equal",
            note: str = "") -> None:
        level2 = access.resolve(level1, layout=layout, op=op)
        self.records.append(AccessRecord(level1, level2, max(size, 1.0),
                                         count, note))

    def extend(self, other: "CostBreakdown", scale: float = 1.0) -> None:
        for rec in other.records:
            self.records.append(dataclasses.replace(
                rec, count=rec.count * scale))

    def total(self, hw: HardwareProfile) -> float:
        return float(sum(rec.cost(hw) for rec in self.records))

    def format(self) -> str:
        """Paper Appendix G.1 style: P(782)+6P(200974)+5S(256)+..."""
        sym = {access.RANDOM_ACCESS: "P", access.SCAN: "S",
               access.SORTED_SEARCH: "B", access.HASH_PROBE: "H",
               access.BLOOM_PROBE: "F", access.SORT: "Q",
               access.SERIAL_WRITE: "W", access.ORDERED_BATCH_WRITE: "W",
               access.SCATTERED_BATCH_WRITE: "W",
               access.BATCHED_RANDOM_ACCESS: "P*"}
        parts = []
        for rec in self.records:
            prefix = "" if abs(rec.count - 1.0) < 1e-9 else \
                f"{rec.count:.3g}"
            parts.append(f"{prefix}{sym.get(rec.level1, '?')}({rec.size:.0f})")
        return "+".join(parts)


# ---------------------------------------------------------------------------
# Structure instantiation (recursive block division, §2 "blocks")
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LevelInfo:
    element: Element
    n_nodes: int                 # nodes at this level
    node_bytes: float            # bytes of one node (layout-aware)
    entries_per_node: float      # data entries routed through one node
    region_bytes: float = 0.0    # cache region: path-so-far (set later)


@dataclasses.dataclass
class StructureInstance:
    spec: DataStructureSpec
    workload: Workload
    levels: List[LevelInfo]

    @property
    def terminal(self) -> LevelInfo:
        return self.levels[-1]

    @property
    def total_bytes(self) -> float:
        return sum(l.n_nodes * l.node_bytes for l in self.levels)


def _node_bytes(element: Element, fanout: int, workload: Workload) -> float:
    """Bytes of one *internal* node given its layout primitives."""
    ptr = 0.0
    loc = element.tag("sub_block_physical_location")
    layout = element.tag("sub_block_physical_layout")
    if loc == "pointed":
        ptr = fanout * PTR_BYTES
    elif loc == "double-pointed":
        ptr = 2 * fanout * PTR_BYTES
    if layout in ("BFS", "BFS-layer") and loc != "inline":
        ptr = PTR_BYTES  # CSB+: children contiguous, one pointer suffices
    if loc == "inline" and layout in ("BFS", "BFS-layer"):
        ptr = 0.0        # FAST: offsets computed, pointers eliminated
    fences = 0.0
    zm = element.tag("zone_map_filters")
    if zm in ("min", "max", "exact"):
        fences = (fanout - 1) * FENCE_BYTES
    elif zm == "both":
        fences = 2 * (fanout - 1) * FENCE_BYTES
    bloom = 0.0
    bf = element.get("bloom_filters")
    if isinstance(bf, tuple) and bf[0] == "on":
        bloom = fanout * bf[2] / 8.0
    links = 0.0
    if element.tag("immediate_node_links") != "none":
        links += fanout * PTR_BYTES
    if element.tag("skip_node_links") != "none":
        links += fanout * PTR_BYTES  # one skip pointer per sub-block (perfect
        # links share the zone-map array, costed via filters)
    return ptr + fences + bloom + links


def instantiate(spec: DataStructureSpec, workload: Workload
                ) -> StructureInstance:
    """Simulate populating the structure: blocks -> node counts and sizes.

    Memoized on (element chain, workload): the chain is the structural
    fingerprint (the spec *name* does not affect population), so the four
    ``synthesize_*`` operations and every candidate in a batched design
    search share one simulation instead of re-running it per call.  A new
    workload is a new key — the cache invalidates by construction.  The
    returned LevelInfos are copies: callers may tweak them (what-if
    experiments) without poisoning the cache.
    """
    levels = _instantiate_levels(spec.chain, workload)
    return StructureInstance(spec, workload,
                             [dataclasses.replace(l) for l in levels])


def clear_synthesis_caches() -> None:
    """Drop the instantiate / skew-weight / schema memos (tests, profile
    reloads)."""
    _instantiate_levels.cache_clear()
    _zipf_collision_mass.cache_clear()
    symbolic_breakdown.cache_clear()


@functools.lru_cache(maxsize=8192)
def _instantiate_levels(chain: Tuple[Element, ...], workload: Workload
                        ) -> Tuple[LevelInfo, ...]:
    spec = DataStructureSpec("instantiate", chain)
    levels: List[LevelInfo] = []
    n = max(workload.n_entries, 1)
    terminal = spec.terminal
    capacity = terminal.capacity or 256
    n_leaves = max(math.ceil(n / capacity), 1)

    # walk non-terminal chain, dividing blocks
    blocks = 1              # logical blocks at the current frontier
    entries = float(n)
    for element in spec.chain[:-1]:
        fanout = element.fanout
        if fanout is None and element.tag("fanout") == "unlimited":
            # linked-list style: sub-blocks are the terminal pages themselves;
            # the element is a "without data" model (paper §2) — one header
            levels.append(LevelInfo(element, blocks, PTR_BYTES * 2,
                                    entries / max(blocks, 1)))
            continue
        fanout = fanout or 2
        recursion = element.tag("recursion")
        if recursion == "yes":
            # recurse until blocks of terminal capacity (B+tree / trie)
            depth = 0
            rec_arg = element.get("recursion")
            max_depth = rec_arg[1] if isinstance(rec_arg, tuple) and \
                isinstance(rec_arg[1], int) else 64
            while blocks * fanout < n_leaves and depth < max_depth - 1:
                levels.append(LevelInfo(
                    element, blocks, _node_bytes(element, fanout, workload),
                    entries / blocks if blocks else entries))
                blocks *= fanout
                depth += 1
            levels.append(LevelInfo(
                element, blocks, _node_bytes(element, fanout, workload),
                entries / blocks))
            blocks *= fanout
        else:
            levels.append(LevelInfo(
                element, blocks, _node_bytes(element, fanout, workload),
                entries / blocks))
            blocks *= fanout

    # terminal level
    n_term = max(n_leaves, blocks if spec.chain[:-1] and
                 spec.chain[-2].tag("fanout") != "unlimited" else n_leaves)
    # partitioned structures keep at least one page per partition
    term_bytes = min(capacity, n / max(n_term, 1)) * workload.pair_bytes
    levels.append(LevelInfo(terminal, int(n_term),
                            max(term_bytes, workload.pair_bytes),
                            entries / max(n_term, 1)))

    # cache regions: cumulative path-so-far (paper §3 example)
    cumulative = 0.0
    for level in levels:
        cumulative += level.n_nodes * level.node_bytes
        level.region_bytes = cumulative
        layout = level.element.tag("sub_block_physical_layout")
        if layout in ("BFS", "BFS-layer"):
            # cache-conscious: children contiguous with the parent — the
            # random access resolves within the parent's child group
            fanout = level.element.fanout or 2
            group = fanout * level.node_bytes
            level.region_bytes = min(cumulative, max(group, level.node_bytes))
    return tuple(levels)


# ---------------------------------------------------------------------------
# Skew (paper §3 "Workload Skew and Caching Effects")
# ---------------------------------------------------------------------------
def _skew_region_multiplier(popularity: float, n_queries: int) -> float:
    """E_sid[min(1, 1/(p * sid))] — averaged weight w = 1/(p*sid) over the
    workload, clamped to 1 (a cold first access costs the full region)."""
    if popularity <= 0.0 or n_queries <= 1:
        return 1.0
    s0 = min(max(1.0 / popularity, 1.0), n_queries)
    # sum_{sid<=s0} 1 + sum_{sid>s0} 1/(p*sid)  ~ s0 + (ln S - ln s0)/p
    total = s0 + (math.log(n_queries) - math.log(s0)) / popularity
    return min(total / n_queries, 1.0)


def _zipf_top_mass(alpha: float, n_items: int, rank: int = 1) -> float:
    """Probability mass of the rank-th most popular item under Zipf(alpha)."""
    if alpha <= 0.0 or n_items <= 1:
        return 1.0 / max(n_items, 1)
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    weights = ranks ** (-alpha)
    return float(weights[rank - 1] / weights.sum())


@functools.lru_cache(maxsize=4096)
def _zipf_collision_mass(n_items: int, alpha: float) -> float:
    """sum_r mass_r^2 under Zipf(alpha) — memoized: a design search asks for
    the same (n_nodes, alpha) pair for every candidate sharing a level
    geometry, and the 4096-element weight array is costly to rebuild."""
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    weights = ranks ** (-alpha)
    weights /= weights.sum()
    return float((weights ** 2).sum())


def _level_popularity(level: LevelInfo, workload: Workload) -> float:
    """Expected popularity of the node a query visits at this level."""
    n = max(level.n_nodes, 1)
    if workload.zipf_alpha <= 0.0:
        return 1.0 / n
    # under skew a query visits the popular node with its zipf mass; use the
    # mean mass of the visited node = sum_r mass_r^2 (collision probability)
    return _zipf_collision_mass(min(n, 4096), workload.zipf_alpha)


def skew_multipliers(n_nodes: np.ndarray, workload: Workload) -> np.ndarray:
    """Vectorized twin of ``_skew_region_multiplier(_level_popularity(..))``.

    Takes the per-record node counts of the levels being accessed and
    returns the §3 skew region multipliers as one array — the zipf
    collision masses are served from the same ``_zipf_collision_mass``
    memo the scalar path uses, so the two paths share one weight table.
    Matches the scalar composition to float tolerance (same op sequence;
    ``np.log`` vs ``math.log`` differ by at most ~1 ulp).
    """
    n_nodes = np.asarray(n_nodes, dtype=np.float64)
    if workload.zipf_alpha <= 0.0 or workload.n_queries <= 1 or \
            len(n_nodes) == 0:
        return np.ones(len(n_nodes))
    n = np.minimum(np.maximum(n_nodes, 1.0), 4096.0).astype(np.int64)
    uniq, inv = np.unique(n, return_inverse=True)
    masses = np.asarray([_zipf_collision_mass(int(u), workload.zipf_alpha)
                         for u in uniq])
    p = masses[inv]
    s = workload.n_queries
    s0 = np.minimum(np.maximum(1.0 / p, 1.0), float(s))
    total = s0 + (math.log(s) - np.log(s0)) / p
    return np.minimum(total / s, 1.0)


def _random_access(cb: CostBreakdown, level: LevelInfo, workload: Workload,
                   note: str) -> None:
    # Skew reweighting (§3) applies only to skewed workloads; the uniform
    # case logs the raw path-so-far region, matching the paper's example.
    mult = 1.0
    if workload.zipf_alpha > 0.0:
        mult = _skew_region_multiplier(_level_popularity(level, workload),
                                       workload.n_queries)
    cb.add(access.RANDOM_ACCESS, level.region_bytes * mult, note=note)


# ---------------------------------------------------------------------------
# Get synthesis (Fig. 5 / Appendix E expert system)
# ---------------------------------------------------------------------------
def synthesize_get(spec: DataStructureSpec, workload: Workload
                   ) -> CostBreakdown:
    cb = CostBreakdown()
    inst = instantiate(spec, workload)
    for level in inst.levels[:-1]:
        el = level.element
        part = el.tag("key_partitioning")
        fanout = el.fanout
        if el.tag("fanout") == "unlimited":
            # linked-list navigation: expected half the sibling pages visited
            if el.tag("skip_node_links") == "perfect":
                # skip-list: binary-search-style navigation over page minima
                # (the terminal step below adds the target-page probe)
                cb.add(access.SORTED_SEARCH,
                       max(level.entries_per_node /
                           (inst.terminal.element.capacity or 256), 1.0) *
                       FENCE_BYTES, note="skip links")
                continue
            pages = max(level.entries_per_node /
                        (inst.terminal.element.capacity or 256), 1.0)
            visited = (pages + 1) / 2.0
            _random_access(cb, inst.terminal, workload, "ll head")
            if visited > 1:
                cb.records.append(AccessRecord(
                    access.RANDOM_ACCESS,
                    access.resolve(access.RANDOM_ACCESS),
                    inst.terminal.region_bytes, visited - 1, "ll page hops"))
                # full scans of the pages before the hit
                cap = inst.terminal.element.capacity or 256
                cb.records.append(AccessRecord(
                    access.SCAN, access.resolve(access.SCAN),
                    cap * workload.key_bytes, visited - 1, "ll page scans"))
            continue
        if part == "data-ind":
            kind = el.get("key_partitioning")
            _random_access(cb, level, workload, f"{el.name} node")
            if kind[1] == "func":        # hash partitioning
                cb.add(access.HASH_PROBE, level.n_nodes * (fanout or 1) *
                       PTR_BYTES, note="hash bucket probe")
            # range/radix partitioning: offset computation, no extra probe
            continue
        if part == "data-dep":
            # sorted fences: random access to node + sorted search over fences
            _random_access(cb, level, workload, f"{el.name} node")
            fences = max((fanout or 2) - 1, 1)
            layout = "row-wise"  # fences+pointers paired within the node
            cb.add(access.SORTED_SEARCH, fences * FENCE_BYTES,
                   layout=layout, note=f"{el.name} fences")
            if el.tag("bloom_filters") == "on":
                bf = el.get("bloom_filters")
                cb.add(access.BLOOM_PROBE, bf[2] / 8.0, note="bloom")
            continue
        # append/temporal partitioning at internal level: scan sub-blocks
        _random_access(cb, level, workload, f"{el.name} node")
        cb.add(access.SCAN, (fanout or 2) * FENCE_BYTES, note="append scan")

    # terminal node
    term = inst.terminal
    el = term.element
    entries = max(term.entries_per_node, 1.0)
    _random_access(cb, term, workload, "leaf")
    if el.tag("bloom_filters") == "on":
        bf = el.get("bloom_filters")
        cb.add(access.BLOOM_PROBE, bf[2] / 8.0, note="leaf bloom")
    layout = el.tag("key_value_layout")
    if el.sorted_keys:
        cb.add(access.SORTED_SEARCH, entries * workload.key_bytes,
               layout=layout, note="leaf search")
    else:
        # expected half scan on a hit
        cb.records.append(AccessRecord(
            access.SCAN, access.resolve(access.SCAN, layout=layout),
            entries * workload.key_bytes / 2, 1.0, "leaf scan"))
    if layout != "row-wise" and el.retains_values:
        cb.add(access.RANDOM_ACCESS, entries * workload.value_bytes,
               note="value fetch")
    return cb


def synthesize_range_get(spec: DataStructureSpec, workload: Workload
                         ) -> CostBreakdown:
    """Fig. 10: descend to the low key, then sweep qualifying leaves."""
    cb = synthesize_get(spec, workload)  # locate the first qualifying leaf
    inst = instantiate(spec, workload)
    term = inst.terminal
    frac = max(workload.selectivity, 0.0)
    n_pages = max(math.ceil(frac * term.n_nodes), 1)
    el = term.element
    layout = el.tag("key_value_layout")
    cap = max(term.entries_per_node, 1.0)
    if el.tag("area_links") != "none" or term.n_nodes == 1:
        hop_region = term.region_bytes
    else:
        # re-descend through the parent for each page (no leaf links)
        hop_region = inst.total_bytes
    if n_pages > 1:
        cb.records.append(AccessRecord(
            access.RANDOM_ACCESS, access.resolve(access.RANDOM_ACCESS),
            hop_region, n_pages - 1, "range page hops"))
    cb.records.append(AccessRecord(
        access.SCAN, access.resolve(access.SCAN, layout=layout, op="range"),
        cap * workload.key_bytes, float(n_pages), "range scans"))
    return cb


def synthesize_bulk_load(spec: DataStructureSpec, workload: Workload
                         ) -> CostBreakdown:
    """Fig. 10: optional sort, then partition + write per level."""
    cb = CostBreakdown()
    inst = instantiate(spec, workload)
    n = workload.n_entries
    data_bytes = n * workload.pair_bytes
    if inst.terminal.element.sorted_keys:
        cb.add(access.SORT, n, note="sort input")
        cb.add(access.ORDERED_BATCH_WRITE, data_bytes, note="write leaves")
    else:
        cb.add(access.SERIAL_WRITE, data_bytes, note="write pages")
    for level in inst.levels[:-1]:
        el = level.element
        part = el.tag("key_partitioning")
        level_bytes = level.n_nodes * level.node_bytes
        if part == "data-ind":
            # one partitioning pass over the data + scattered writes
            cb.add(access.SCAN, data_bytes, note="partition pass")
            cb.add(access.SCATTERED_BATCH_WRITE, max(level_bytes, 1.0),
                   note=f"write {el.name} level")
        else:
            cb.add(access.ORDERED_BATCH_WRITE, max(level_bytes, 1.0),
                   note=f"write {el.name} level")
    return cb


def synthesize_update(spec: DataStructureSpec, workload: Workload
                      ) -> CostBreakdown:
    """Paper §5: value update = point query + one write access."""
    cb = synthesize_get(spec, workload)
    inst = instantiate(spec, workload)
    cb.add(access.SERIAL_WRITE, workload.value_bytes, note="write value")
    return cb


OPERATIONS = {
    "get": synthesize_get,
    "range_get": synthesize_range_get,
    "bulk_load": synthesize_bulk_load,
    "update": synthesize_update,
}


# ---------------------------------------------------------------------------
# Structural templates: the symbolic form of the expert system above.
# ---------------------------------------------------------------------------
#: emission classes — which record sequence an internal level contributes
#: to a synthesized operation (the per-level coordinate of a chain's
#: structural template; see repro.core.templatecost)
(CLS_SKIP, CLS_LL, CLS_IND_FUNC, CLS_IND, CLS_DEP, CLS_APPEND,
 CLS_DEP_BLOOM) = range(7)


def element_class(element: Element) -> int:
    """The emission class of one element — the branch the ``synthesize_*``
    walkers take for its levels, as data."""
    if element.tag("fanout") == "unlimited":
        return CLS_SKIP if element.tag("skip_node_links") == "perfect" \
            else CLS_LL
    part = element.tag("key_partitioning")
    if part == "data-ind":
        return CLS_IND_FUNC if element.get("key_partitioning")[1] == "func" \
            else CLS_IND
    if part == "data-dep":
        return CLS_DEP_BLOOM if element.tag("bloom_filters") == "on" \
            else CLS_DEP
    return CLS_APPEND


@functools.lru_cache(maxsize=4096)
def symbolic_breakdown(op: str, template: Tuple
                       ) -> Tuple[Tuple[str, str], ...]:
    """One operation's record schema for a structural template.

    ``template`` is ``(per-level class tuple, (sorted, bloom, layout,
    value_fetch, area_links))`` as produced by
    :func:`repro.core.templatecost.chain_geometry`.  The schema — the
    ordered (Level-1, Level-2) pairs the expert system emits — is
    synthesized **once per template**; every chain sharing the template
    shares this layout, and :mod:`repro.core.templatecost` evaluates the
    per-chain numeric sizes/counts as batched array ops (slots the scalar
    walker would skip, e.g. linked-list page hops when a single page is
    visited, carry count 0).
    """
    classes, (sorted_, bloom, layout, value_fetch, _area) = template
    p_rec = (access.RANDOM_ACCESS, access.resolve(access.RANDOM_ACCESS))
    recs: List[Tuple[str, str]] = []
    if op in ("get", "range_get", "update"):
        for cls in classes:
            if cls == CLS_SKIP:
                recs.append((access.SORTED_SEARCH,
                             access.resolve(access.SORTED_SEARCH)))
            elif cls == CLS_LL:
                recs += [p_rec, p_rec,
                         (access.SCAN, access.resolve(access.SCAN))]
            elif cls == CLS_IND_FUNC:
                recs += [p_rec, (access.HASH_PROBE,
                                 access.resolve(access.HASH_PROBE))]
            elif cls == CLS_IND:
                recs.append(p_rec)
            elif cls in (CLS_DEP, CLS_DEP_BLOOM):
                recs += [p_rec, (access.SORTED_SEARCH, access.resolve(
                    access.SORTED_SEARCH, layout="row-wise"))]
                if cls == CLS_DEP_BLOOM:
                    recs.append((access.BLOOM_PROBE,
                                 access.resolve(access.BLOOM_PROBE)))
            else:
                recs += [p_rec, (access.SCAN, access.resolve(access.SCAN))]
        recs.append(p_rec)                       # leaf descent
        if bloom:
            recs.append((access.BLOOM_PROBE,
                         access.resolve(access.BLOOM_PROBE)))
        if sorted_:
            recs.append((access.SORTED_SEARCH,
                         access.resolve(access.SORTED_SEARCH,
                                        layout=layout)))
        else:
            recs.append((access.SCAN, access.resolve(access.SCAN,
                                                     layout=layout)))
        if value_fetch:
            recs.append(p_rec)
        if op == "range_get":
            recs += [p_rec, (access.SCAN, access.resolve(
                access.SCAN, layout=layout, op="range"))]
        elif op == "update":
            recs.append((access.SERIAL_WRITE,
                         access.resolve(access.SERIAL_WRITE)))
    elif op == "bulk_load":
        if sorted_:
            recs += [(access.SORT, access.resolve(access.SORT)),
                     (access.ORDERED_BATCH_WRITE,
                      access.resolve(access.ORDERED_BATCH_WRITE))]
        else:
            recs.append((access.SERIAL_WRITE,
                         access.resolve(access.SERIAL_WRITE)))
        for cls in classes:
            if cls in (CLS_IND, CLS_IND_FUNC):
                recs += [(access.SCAN, access.resolve(access.SCAN)),
                         (access.SCATTERED_BATCH_WRITE,
                          access.resolve(access.SCATTERED_BATCH_WRITE))]
            else:
                recs.append((access.ORDERED_BATCH_WRITE,
                             access.resolve(access.ORDERED_BATCH_WRITE)))
    else:
        raise KeyError(op)
    return tuple(recs)


def synthesize_operation(op: str, spec: DataStructureSpec,
                         workload: Workload) -> CostBreakdown:
    return OPERATIONS[op](spec, workload)


def cost(op: str, spec: DataStructureSpec, workload: Workload,
         hw: HardwareProfile) -> float:
    """Latency (seconds) of one operation — the Calculator's main output."""
    return synthesize_operation(op, spec, workload).total(hw)


def cost_workload(spec: DataStructureSpec, workload: Workload,
                  hw: HardwareProfile,
                  mix: Optional[Dict[str, float]] = None) -> float:
    """Sets of operations in a single pass (§3): weighted operation mix."""
    mix = mix or {"get": float(workload.n_queries)}
    total = 0.0
    for op, count in mix.items():
        total += count * cost(op, spec, workload, hw)
    return total
