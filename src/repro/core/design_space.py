"""Design-space cardinality accounting — paper §2, Equations 1–4.

Reproduces the paper's headline numbers: |E| ~ 1e16 valid node elements,
~1e32 standard two-element structures, >1e100 polymorphic designs for 1e15
keys, and the comparisons against fixed-library synthesis in Appendix B.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.core.primitives import PRIMITIVES

#: the paper excludes ~60 invalid combinations in Figure 11's accounting and
#: reports the total as ``> 10^18 / 60 invalid combinations ~ 10^16``.
INVALID_COMBINATION_FACTOR = 60


def element_cardinality() -> float:
    """|E| per Equation 1 over the full (Figure 11) primitive domains."""
    total = 1.0
    for prim in PRIMITIVES.values():
        total *= prim.cardinality
    return total / INVALID_COMBINATION_FACTOR


def standard_design_cardinality(num_elements: int = 2) -> float:
    """Equation 4: |E|^k for structures built from k distinct elements."""
    return element_cardinality() ** num_elements


def polymorphic_design_cardinality(num_keys: float, page_size: int = 4096,
                                   fanout: int = 20) -> float:
    """Equation 3: |E| * (f * |E|)^ceil(log_f N) (log-domain to avoid overflow).

    Returns log10 of the count (the count itself overflows floats for the
    paper's 1e15-key example).
    """
    card = element_cardinality()
    pages = max(math.ceil(num_keys / page_size), 1)
    height = max(math.ceil(math.log(pages, fanout)), 1)
    log10 = math.log10(card) + height * (math.log10(fanout) + math.log10(card))
    return log10


def fixed_library_cardinality(library_size: int, num_elements: int = 2) -> int:
    """Appendix B comparison: designs from a fixed library of k structures."""
    return library_size ** num_elements


def summary() -> Dict[str, float]:
    return {
        "element_cardinality_log10": math.log10(element_cardinality()),
        "standard_two_element_log10": math.log10(standard_design_cardinality(2)),
        "standard_three_element_log10": math.log10(standard_design_cardinality(3)),
        "polymorphic_1e15_keys_log10": polymorphic_design_cardinality(1e15),
        "polymorphic_10m_4k_pages_log10": polymorphic_design_cardinality(1e7),
        "fixed_library_5_two_element": fixed_library_cardinality(5, 2),
    }
