"""The Distributed Data Calculator: the paper's paradigm applied to the
distributed-layout design space of a training/serving step on TPU pods.

Mapping (DESIGN.md §2):

* layout primitives  -> per-tensor sharding decisions (TP/FSDP/EP/SP axes)
  with invalidation rules = divisibility + mesh-axis reuse;
* access primitives  -> MXU compute, HBM read/write, ICI collectives, each
  with a parametric cost model over (bytes, axis size, bandwidth);
* cost synthesis     -> the three roofline terms per (arch x shape x mesh x
  strategy), computed without compiling anything;
* what-if            -> re-cost under a different mesh/strategy/hardware;
* auto-completion    -> Algorithm-1-style search completing a partial
  sharding strategy, ranking by synthesized step time.

The multi-pod dry-run validates these predictions against XLA's compiled
artifacts (EXPERIMENTS.md §Roofline), mirroring the paper's Fig. 6
predicted-vs-implemented methodology.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.hardware import TPUProfile, TPU_V5E


# ---------------------------------------------------------------------------
# Sharding strategy = the "element" of the distributed design space
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Strategy:
    """One point in the distributed-layout space (per arch x mesh)."""

    tp: int = 16          # model-axis ways used for tensor parallelism
    fsdp: bool = True     # ZeRO-3 params over the data axis (within pod)
    ep: bool = True       # expert parallelism over the model axis (MoE)
    sp: bool = False      # sequence(context) parallelism for caches
    remat: bool = True    # full activation rematerialization
    microbatches: int = 1

    def describe(self) -> str:
        bits = [f"tp{self.tp}", "fsdp" if self.fsdp else "dp",
                "remat" if self.remat else "norem"]
        if self.ep:
            bits.append("ep")
        if self.sp:
            bits.append("sp")
        if self.microbatches > 1:
            bits.append(f"mb{self.microbatches}")
        return "+".join(bits)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def chips(self) -> int:
        return self.data * self.model * self.pods


def invalid_reasons(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshSpec,
                    strategy: Strategy) -> List[str]:
    """Invalidation rules (the distributed analogue of Figure 11's rules)."""
    errors = []
    if strategy.tp > mesh.model:
        errors.append(f"tp={strategy.tp} exceeds model axis {mesh.model}")
    if strategy.tp > 1:
        hd = cfg.resolved_head_dim
        if cfg.n_heads % strategy.tp and hd % strategy.tp and \
                (cfg.d_ff % strategy.tp if cfg.d_ff else True):
            errors.append("no shardable attention/mlp dim for tp")
    if strategy.ep and not cfg.moe:
        errors.append("ep requires MoE")
    if strategy.ep and cfg.moe and cfg.moe.n_experts % mesh.model:
        errors.append("experts not divisible by model axis")
    dp = mesh.data * mesh.pods
    if shape.kind == "train" and shape.global_batch % \
            (dp * max(strategy.microbatches, 1)):
        errors.append("global batch not divisible by dp x microbatches")
    return errors


# ---------------------------------------------------------------------------
# Access-primitive cost synthesis (per training/serving step)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        # perfect overlap bound: the step cannot run faster than max(term)
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute seconds / bound = how close to the compute roof."""
        if self.step_seconds <= 0:
            return 0.0
        return self.compute_s / self.step_seconds

    def to_json(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s,
                "flops_per_chip": self.flops_per_chip,
                "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
                "collective_bytes_per_chip": self.collective_bytes_per_chip,
                "model_flops": self.model_flops,
                "dominant": self.dominant,
                "step_seconds": self.step_seconds}


def _dtype_bytes(cfg: ArchConfig) -> Tuple[int, int]:
    pb = 2 if cfg.param_dtype == "bfloat16" else 4
    cb = 2 if cfg.compute_dtype == "bfloat16" else 4
    return pb, cb


def _attention_flops(cfg: ArchConfig, tokens: float, context: float) -> float:
    """Per-layer attention FLOPs for `tokens` queries over `context` keys."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    proj = 2 * tokens * d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + \
        2 * tokens * cfg.n_heads * hd * d
    scores = 4 * tokens * context * cfg.n_heads * hd
    return proj + scores


def _ffn_flops(cfg: ArchConfig, tokens: float) -> float:
    if cfg.moe:
        return 2 * tokens * cfg.moe.top_k * 3 * cfg.d_model * cfg.d_ff + \
            2 * tokens * cfg.d_model * cfg.moe.n_experts
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        # up/down projections + state update ~ 2*d_in*state per token
        return 2 * tokens * (3 * cfg.d_model * d_in +
                             d_in * max(cfg.ssm_state, 256))
    return 2 * tokens * 3 * cfg.d_model * cfg.d_ff


def _ssm_flops(cfg: ArchConfig, tokens: float) -> float:
    d_in = cfg.ssm_expand * cfg.d_model
    n = max(cfg.ssm_state, 64)
    cl = cfg.ssm_chunk
    # intra-chunk quadratic + state build/apply (chunked SSD)
    return 2 * tokens * (2 * cfg.d_model * d_in + d_in * cfg.d_model) + \
        2 * tokens * cl * (d_in + 2 * n) + 4 * tokens * d_in * n


def forward_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Forward FLOPs for one step of this shape (whole cluster)."""
    if shape.kind == "decode":
        tokens = float(shape.global_batch)          # one token per sequence
        context = float(shape.seq_len)
    else:
        tokens = float(shape.global_batch * shape.seq_len)
        context = float(shape.seq_len) / 2          # causal average
    per_layer = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        per_layer = _attention_flops(cfg, tokens, context) + \
            _ffn_flops(cfg, tokens)
        total = cfg.n_layers * per_layer
    elif cfg.family == "ssm":
        total = cfg.n_layers * _ssm_flops(cfg, tokens)
    elif cfg.family == "hybrid":
        n_attn = (cfg.n_layers + cfg.shared_attn_every - 1) // \
            max(cfg.shared_attn_every, 1)
        total = cfg.n_layers * _ssm_flops(cfg, tokens) + \
            n_attn * (_attention_flops(cfg, tokens, context) +
                      _ffn_flops(cfg, tokens))
    elif cfg.family == "audio":
        src_tokens = tokens if shape.kind != "decode" else \
            float(shape.global_batch * 4096)
        enc = cfg.n_encoder_layers * (
            _attention_flops(cfg, src_tokens, context) +
            _ffn_flops(cfg, src_tokens))
        dec = cfg.n_layers * (
            _attention_flops(cfg, tokens, context) * 2 +   # self + cross
            _ffn_flops(cfg, tokens))
        if shape.kind == "decode":
            enc = 0.0  # encoder output cached
        total = enc + dec
    else:
        raise ValueError(cfg.family)
    # unembedding
    total += 2 * tokens * cfg.d_model * cfg.vocab_size
    return total


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) — the §Roofline 'useful' FLOPs."""
    if shape.kind == "decode":
        tokens = float(shape.global_batch)
    else:
        tokens = float(shape.global_batch * shape.seq_len)
    n = cfg.n_active_params()
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * tokens


def synthesize(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshSpec,
               strategy: Strategy, tpu: TPUProfile = TPU_V5E
               ) -> RooflineTerms:
    """Cost synthesis: the three roofline terms for one step."""
    chips = mesh.chips
    pb, cb = _dtype_bytes(cfg)
    fwd = forward_flops(cfg, shape)
    flops = fwd * (3.0 if shape.kind == "train" else 1.0)
    if shape.kind == "train" and strategy.remat:
        flops += fwd  # recompute forward during backward
    flops_per_chip = flops / chips

    # ---- HBM traffic -------------------------------------------------------
    n_params = cfg.n_params()
    dp = mesh.data * mesh.pods
    param_shard = n_params / (dp if strategy.fsdp else 1) / strategy.tp
    tokens = (shape.global_batch if shape.kind == "decode"
              else shape.global_batch * shape.seq_len)
    act_bytes_per_chip = tokens * cfg.d_model * cb * \
        (12 if shape.kind == "train" else 2) / chips
    if shape.kind == "train":
        # params: fwd read + bwd read + update rw; grads w+r; moments 2r+2w
        hbm = n_params / strategy.tp / (dp if strategy.fsdp else 1) * (
            3 * pb + 2 * pb + 4 * 4)
        hbm = hbm + act_bytes_per_chip
        # gathered FSDP params stream through HBM once per layer pass
        if strategy.fsdp:
            hbm += 2 * n_params / strategy.tp * pb / mesh.data
    else:
        hbm = n_params / chips * pb if strategy.fsdp else \
            n_params / strategy.tp * pb / (1 if shape.kind == "decode"
                                           else 1)
        # KV/state cache read+write
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            kv = (cfg.n_layers * 2 * shape.seq_len * shape.global_batch *
                  cfg.n_kv_heads * cfg.resolved_head_dim * cb)
            hbm += (2 * kv if shape.kind == "decode" else kv) / chips
        else:
            d_in = cfg.ssm_expand * cfg.d_model
            state = cfg.n_layers * shape.global_batch * d_in * \
                max(cfg.ssm_state, d_in // max(cfg.n_heads, 1)) * 4
            hbm += 2 * state / chips
        hbm += act_bytes_per_chip
    hbm_per_chip = hbm

    # ---- collectives -------------------------------------------------------
    coll = 0.0
    if shape.kind == "train":
        if strategy.fsdp:
            # all-gather params fwd + bwd, reduce-scatter grads (per chip,
            # ring: bytes ~ full shard-group size)
            coll += 3 * (n_params / strategy.tp) * pb / mesh.data * \
                (mesh.data - 1)
        else:
            coll += 2 * (n_params / strategy.tp) * pb  # grad all-reduce
        if mesh.pods > 1:
            coll += 2 * (n_params / strategy.tp / mesh.data) * pb
        if strategy.tp > 1:
            # Megatron: 2 all-reduces per block per microbatch pass x3 passes
            blocks = cfg.n_layers * (2 if cfg.family != "ssm" else 1)
            coll += 3 * 2 * blocks * tokens * cfg.d_model * cb / \
                (chips / strategy.tp) * 2 / strategy.tp * (strategy.tp - 1)
        if cfg.moe and strategy.ep:
            coll += 3 * 2 * cfg.n_layers * tokens * cfg.moe.top_k * \
                cfg.d_model * cb / chips
    else:
        if strategy.tp > 1:
            blocks = cfg.n_layers * (2 if cfg.family != "ssm" else 1)
            coll += 2 * blocks * tokens * cfg.d_model * cb / \
                (chips / strategy.tp) * 2 / strategy.tp * (strategy.tp - 1)
        if cfg.moe and strategy.ep:
            coll += 2 * cfg.n_layers * tokens * cfg.moe.top_k * \
                cfg.d_model * cb / chips
        if strategy.fsdp:
            coll += n_params / strategy.tp * pb / mesh.data * \
                (mesh.data - 1) / max(tokens / shape.global_batch, 1)
    coll_per_chip = coll

    return RooflineTerms(
        compute_s=flops_per_chip / tpu.peak_flops_bf16,
        memory_s=hbm_per_chip / tpu.hbm_bw,
        collective_s=coll_per_chip / tpu.ici_bw,
        flops_per_chip=flops_per_chip,
        hbm_bytes_per_chip=hbm_per_chip,
        collective_bytes_per_chip=coll_per_chip,
        model_flops=model_flops(cfg, shape))


# ---------------------------------------------------------------------------
# What-if + auto-completion over strategies (paper §4 transferred)
# ---------------------------------------------------------------------------
def candidate_strategies(cfg: ArchConfig, shape: ShapeConfig,
                         mesh: MeshSpec) -> List[Strategy]:
    out = []
    for tp, fsdp, remat in itertools.product(
            (1, mesh.model), (False, True), (False, True)):
        s = Strategy(tp=tp, fsdp=fsdp, ep=bool(cfg.moe), remat=remat,
                     sp=shape.name == "long_500k")
        if not invalid_reasons(cfg, shape, mesh, s):
            out.append(s)
    return out


def fits_memory(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshSpec,
                strategy: Strategy, tpu: TPUProfile = TPU_V5E) -> bool:
    pb, cb = _dtype_bytes(cfg)
    n_params = cfg.n_params()
    dp = mesh.data * mesh.pods
    shard = n_params / strategy.tp / (dp if strategy.fsdp else 1)
    resident = shard * (pb + (pb + 8 if shape.kind == "train" else 0))
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len / mesh.chips
        act = tokens * cfg.d_model * cb * \
            (2 * cfg.n_layers if not strategy.remat else 4)
        resident += act
    else:
        kv = (cfg.n_layers * 2 * shape.seq_len * shape.global_batch *
              cfg.n_kv_heads * cfg.resolved_head_dim * cb) / mesh.chips
        resident += kv
    return resident < 0.9 * tpu.hbm_bytes


def complete_strategy(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshSpec,
                      partial: Optional[Dict] = None,
                      tpu: TPUProfile = TPU_V5E
                      ) -> Tuple[Strategy, RooflineTerms]:
    """Algorithm-1 analogue: fix the fields in ``partial``, search the rest,
    rank by synthesized step time subject to the memory-fit rule."""
    partial = partial or {}
    best: Optional[Tuple[Strategy, RooflineTerms]] = None
    for strat in candidate_strategies(cfg, shape, mesh):
        if any(getattr(strat, k) != v for k, v in partial.items()):
            continue
        if not fits_memory(cfg, shape, mesh, strat, tpu):
            continue
        terms = synthesize(cfg, shape, mesh, strat, tpu)
        if best is None or terms.step_seconds < best[1].step_seconds:
            best = (strat, terms)
    if best is None:  # nothing fits: fall back to max sharding
        strat = Strategy(tp=mesh.model, fsdp=True, ep=bool(cfg.moe))
        best = (strat, synthesize(cfg, shape, mesh, strat, tpu))
    return best


def what_if_mesh(cfg: ArchConfig, shape: ShapeConfig, base: MeshSpec,
                 variant: MeshSpec) -> Dict[str, float]:
    """E.g. 'what if we double the pods?' without touching a TPU."""
    _, t0 = complete_strategy(cfg, shape, base)
    _, t1 = complete_strategy(cfg, shape, variant)
    return {"base_step_s": t0.step_seconds, "variant_step_s": t1.step_seconds,
            "speedup": t0.step_seconds / max(t1.step_seconds, 1e-12)}
