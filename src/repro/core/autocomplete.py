"""Design auto-completion (paper §4, Algorithm 1) and hybrid design search.

``complete_design`` fills in the missing suffix of a partial element chain,
ranking candidates by synthesized workload cost, with memoization (the
paper's ``cachedSolution``).  ``design_hybrid`` reproduces the Fig. 9
scenarios: the workload is split into domain regions with different
read/write/range mixes and each region's sub-design is auto-completed
independently under a shared partitioning root — yielding the paper's
"hash over {log, B+tree}" style hybrids.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import elements as el
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.synthesis import Workload, cost_workload


def default_candidates() -> List[Element]:
    """The element pool offered to the search (right side of Fig. 3)."""
    return [
        el.hash_element(100),
        el.range_element(100),
        el.btree_internal(20),
        el.csb_internal(20),
        el.linked_list_element(256),
        el.skip_list_element(256),
        el.trie_element(256, 4),
    ]


def default_terminals() -> List[Element]:
    return [el.unordered_data_page(256), el.ordered_data_page(256)]


@dataclasses.dataclass
class SearchResult:
    spec: DataStructureSpec
    cost_seconds: float
    explored: int
    elapsed_seconds: float

    def summary(self) -> str:
        return (f"{self.spec.describe()}  cost={self.cost_seconds:.3e}s  "
                f"explored={self.explored} designs in "
                f"{self.elapsed_seconds:.2f}s")


def _meaningful(chain: Sequence[Element]) -> bool:
    """Prune meaningless paths (Algorithm 1 ``meaningfulPath``)."""
    seen_partitioners = 0
    for i, element in enumerate(chain[:-1] if chain and chain[-1].terminal
                                else chain):
        if element.tag("fanout") == "unlimited" and i > 0 and \
                chain[i - 1].tag("fanout") == "unlimited":
            return False  # LL of LL adds nothing
        if element.tag("key_partitioning") == "data-ind":
            seen_partitioners += 1
            if seen_partitioners > 2:
                return False
    return True


def complete_design(partial: Sequence[Element], workload: Workload,
                    hw: HardwareProfile,
                    candidates: Optional[Sequence[Element]] = None,
                    terminals: Optional[Sequence[Element]] = None,
                    mix: Optional[Dict[str, float]] = None,
                    max_depth: int = 3,
                    name: str = "auto") -> SearchResult:
    """Algorithm 1: complete a partial layout spec for (workload, hardware).

    ``partial`` is the known prefix of the element chain (may be empty).
    The search extends it with up to ``max_depth`` non-terminal candidates
    plus one terminal, memoizing (level, prefix-class) costs.
    """
    candidates = list(candidates or default_candidates())
    terminals = list(terminals or default_terminals())
    cache: Dict[Tuple, Tuple[float, Tuple[Element, ...]]] = {}
    explored = 0
    t0 = time.perf_counter()

    def best_completion(prefix: Tuple[Element, ...], depth: int
                        ) -> Tuple[float, Optional[Tuple[Element, ...]]]:
        nonlocal explored
        key = (tuple(e.name for e in prefix), depth)
        if key in cache:
            return cache[key]
        best: Tuple[float, Optional[Tuple[Element, ...]]] = (math.inf, None)
        # option 1: terminate here
        for term in terminals:
            chain = prefix + (term,)
            if not _meaningful(chain):
                continue
            try:
                spec = DataStructureSpec(name, chain)
            except ValueError:
                continue
            explored += 1
            c = cost_workload(spec, workload, hw, mix)
            if c < best[0]:
                best = (c, chain)
        # option 2: extend with one more non-terminal
        if depth < max_depth:
            for cand in candidates:
                chain = prefix + (cand,)
                if not _meaningful(chain):
                    continue
                sub_cost, sub_chain = best_completion(chain, depth + 1)
                if sub_chain is not None and sub_cost < best[0]:
                    best = (sub_cost, sub_chain)
        cache[key] = best
        return best

    cost_s, chain = best_completion(tuple(partial), len(tuple(partial)))
    if chain is None:
        raise RuntimeError("no valid completion found")
    return SearchResult(DataStructureSpec(name, chain), cost_s, explored,
                        time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Hybrid (Fig. 9) design synthesis
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DomainRegion:
    """A contiguous fraction of the key domain with its own operation mix."""

    name: str
    fraction: float                     # of the key domain
    mix: Dict[str, float]              # op -> count


@dataclasses.dataclass
class HybridDesign:
    root: Element
    regions: List[Tuple[DomainRegion, SearchResult]]
    cost_seconds: float
    elapsed_seconds: float

    def describe(self) -> str:
        parts = ", ".join(
            f"{region.name}: {result.spec.describe()}"
            for region, result in self.regions)
        return f"{self.root.name} -> {{{parts}}}"


def design_hybrid(workload: Workload, regions: Sequence[DomainRegion],
                  hw: HardwareProfile,
                  candidates: Optional[Sequence[Element]] = None,
                  root: Optional[Element] = None,
                  max_depth: int = 2) -> HybridDesign:
    """Reproduce the paper's Fig. 9 search: per-region auto-completion under
    a shared partitioning root, costed on each region's own sub-workload."""
    t0 = time.perf_counter()
    root = root or el.hash_element(100)
    results: List[Tuple[DomainRegion, SearchResult]] = []
    total = 0.0
    for region in regions:
        sub_workload = dataclasses.replace(
            workload,
            n_entries=max(int(workload.n_entries * region.fraction), 1))
        result = complete_design((), sub_workload, hw,
                                 candidates=candidates, mix=region.mix,
                                 max_depth=max_depth,
                                 name=f"hybrid-{region.name}")
        results.append((region, result))
        total += result.cost_seconds
    # root routing cost: one probe per operation through the partitioner
    ops = sum(sum(r.mix.values()) for r in regions)
    from repro.core import access
    from repro.core.synthesis import AccessRecord, CostBreakdown
    cb = CostBreakdown()
    fanout = root.fanout or 100
    cb.add(access.HASH_PROBE if
           root.get("key_partitioning", ("x",))[1] == "func" else
           access.RANDOM_ACCESS, fanout * 8, count=float(ops),
           note="root routing")
    total += cb.total(hw)
    return HybridDesign(root, results, total, time.perf_counter() - t0)
