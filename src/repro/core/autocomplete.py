"""Design auto-completion (paper §4, Algorithm 1) and hybrid design search.

``complete_design`` fills in the missing suffix of a partial element chain,
ranking candidates by synthesized workload cost.  The search is *batched*:
the candidate frontier is enumerated up front (deduplicated by element-name
class — the paper's ``cachedSolution`` memoization, which collapses
duplicate pool entries) and every surviving chain is costed in one
:func:`repro.core.batchcost.cost_many` call — by default the *fused*
device-resident engine (one jitted JAX call per frontier,
:mod:`repro.core.devicecost`); ``engine="grouped"`` selects the PR-1
grouped-numpy oracle (one vectorized prediction per Level-2 model).  Pass
``batched=False`` to fall back to the scalar per-design path (same
enumeration, same argmin — used by the before/after search benchmark).

``design_hybrid`` reproduces the Fig. 9 scenarios: the workload is split
into domain regions with different read/write/range mixes and each
region's sub-design is auto-completed independently under a shared
partitioning root — yielding the paper's "hash over {log, B+tree}" style
hybrids.

Search is *incremental* end to end (PR 3): enumeration is memoized (it is
purely structural), frontier construction is template-vectorized with
per-spec segment reuse (:mod:`repro.core.batchcost` /
:mod:`repro.core.templatecost`), and the local searches
(``design_hillclimb``, ``design_beam``) keep a seen-set keyed on the
cached element-chain hashes so a chain costed in an earlier round is
never packed or scored again — ``explored``/``designs_costed`` count
unique designs.

``design_continuum`` (PR 5) runs one auto-completion frontier against a
whole *workload axis* — a read/write-ratio or skew sweep — in a single
fused (designs x workloads) scoring call via
:func:`repro.core.batchcost.cost_sweep`, returning the best design per
sweep point (the continuum curves of *Learning Key-Value Store Design*).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import batchcost, elements as el
from repro.core.batchcost import cost_many
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.search import BudgetExhausted, SearchBudget
from repro.core.synthesis import Workload, cost_workload


def default_candidates() -> List[Element]:
    """The element pool offered to the search (right side of Fig. 3)."""
    return [
        el.hash_element(100),
        el.range_element(100),
        el.btree_internal(20),
        el.csb_internal(20),
        el.linked_list_element(256),
        el.skip_list_element(256),
        el.trie_element(256, 4),
    ]


def default_terminals() -> List[Element]:
    return [el.unordered_data_page(256), el.ordered_data_page(256)]


@dataclasses.dataclass
class SearchResult:
    spec: DataStructureSpec
    cost_seconds: float
    explored: int
    elapsed_seconds: float
    #: the scoring engine that produced the costs (the serving tier
    #: retags when a degraded-engine fallback served the completion)
    engine: str = "fused"

    def summary(self) -> str:
        return (f"{self.spec.describe()}  cost={self.cost_seconds:.3e}s  "
                f"explored={self.explored} designs in "
                f"{self.elapsed_seconds:.2f}s")


def _meaningful(chain: Sequence[Element]) -> bool:
    """Prune meaningless paths (Algorithm 1 ``meaningfulPath``)."""
    seen_partitioners = 0
    for i, element in enumerate(chain[:-1] if chain and chain[-1].terminal
                                else chain):
        if element.tag("fanout") == "unlimited" and i > 0 and \
                chain[i - 1].tag("fanout") == "unlimited":
            return False  # LL of LL adds nothing
        if element.tag("key_partitioning") == "data-ind":
            seen_partitioners += 1
            if seen_partitioners > 2:
                return False
    return True


def _dedup_by_name(pool: Sequence[Element]) -> List[Element]:
    """Collapse duplicate pool entries (Algorithm 1's cachedSolution keys
    sub-searches by element-name class, so duplicates add no exploration)."""
    seen = set()
    out: List[Element] = []
    for e in pool:
        if e.name not in seen:
            seen.add(e.name)
            out.append(e)
    return out


def enumerate_completions(partial: Sequence[Element],
                          candidates: Sequence[Element],
                          terminals: Sequence[Element],
                          max_depth: int,
                          name: str = "auto") -> List[DataStructureSpec]:
    """All valid full chains reachable from ``partial``, in the depth-first
    order Algorithm 1 visits them (terminals first at each prefix, then
    each candidate extension in pool order) — the frontier to be costed."""
    candidates = _dedup_by_name(candidates)
    terminals = _dedup_by_name(terminals)
    frontier: List[DataStructureSpec] = []

    def extend(prefix: Tuple[Element, ...], depth: int) -> None:
        for term in terminals:
            chain = prefix + (term,)
            if not _meaningful(chain):
                continue
            try:
                frontier.append(DataStructureSpec(name, chain))
            except ValueError:
                continue
        if depth < max_depth:
            for cand in candidates:
                chain = prefix + (cand,)
                if not _meaningful(chain):
                    continue
                extend(chain, depth + 1)

    extend(tuple(partial), len(tuple(partial)))
    return frontier


@functools.lru_cache(maxsize=256)
def _enumerate_cached(partial: Tuple[Element, ...],
                      candidates: Tuple[Element, ...],
                      terminals: Tuple[Element, ...],
                      max_depth: int, name: str
                      ) -> Tuple[DataStructureSpec, ...]:
    """Enumeration is purely structural (no workload/hardware), so repeat
    searches over one pool reuse the frontier — in steady state the whole
    search pipeline is then cache-hit enumeration + cache-hit packing +
    one fused scoring call.  Registered with batchcost.clear_caches()."""
    return tuple(enumerate_completions(partial, candidates, terminals,
                                       max_depth, name))


batchcost.register_cache("enumerate", _enumerate_cached.cache_info,
                         _enumerate_cached.cache_clear)


def enumerate_frontier(partial: Sequence[Element],
                       candidates: Optional[Sequence[Element]] = None,
                       terminals: Optional[Sequence[Element]] = None,
                       max_depth: int = 3,
                       name: str = "auto") -> Tuple[DataStructureSpec, ...]:
    """The memoized candidate frontier of a completion question.

    Public entry point for callers that separate enumeration from scoring
    — :mod:`repro.serving` enumerates each auto-completion request's
    frontier up front so a whole coalescing window of requests can splice
    into one fused scoring call.  ``lru_cache`` keeps this thread-safe."""
    return _enumerate_cached(
        tuple(partial), tuple(candidates or default_candidates()),
        tuple(terminals or default_terminals()), max_depth, name)


def complete_design(partial: Sequence[Element], workload: Workload,
                    hw: HardwareProfile,
                    candidates: Optional[Sequence[Element]] = None,
                    terminals: Optional[Sequence[Element]] = None,
                    mix: Optional[Dict[str, float]] = None,
                    max_depth: int = 3,
                    name: str = "auto",
                    batched: bool = True,
                    engine: str = "fused") -> SearchResult:
    """Algorithm 1: complete a partial layout spec for (workload, hardware).

    ``partial`` is the known prefix of the element chain (may be empty).
    The search extends it with up to ``max_depth`` non-terminal candidates
    plus one terminal.  The whole frontier is costed in one batched call —
    fused by default, ``engine="grouped"`` for the PR-1 oracle
    (``batched=False`` re-costs it design-by-design through the scalar
    ``cost_workload`` path; all paths return the identical argmin design,
    to 1e-9 totals for grouped/scalar and 1e-6 for fused).
    """
    t0 = time.perf_counter()
    frontier = list(enumerate_frontier(partial, candidates, terminals,
                                       max_depth, name))
    if not frontier:
        raise RuntimeError("no valid completion found")
    if batched:
        totals = cost_many(frontier, workload, hw, mix, engine=engine)
    else:
        totals = np.asarray([cost_workload(spec, workload, hw, mix)
                             for spec in frontier])
    best = int(np.argmin(totals))  # first minimum — Algorithm 1's strict <
    return SearchResult(frontier[best], float(totals[best]), len(frontier),
                        time.perf_counter() - t0, engine=engine)


def complete_design_sweep(partial: Sequence[Element],
                          workloads: Sequence[Workload],
                          hw: HardwareProfile,
                          candidates: Optional[Sequence[Element]] = None,
                          terminals: Optional[Sequence[Element]] = None,
                          mixes=None,
                          max_depth: int = 3,
                          name: str = "auto",
                          engine: str = "fused") -> List[SearchResult]:
    """Algorithm 1 across a whole workload axis: one enumeration, one
    (designs x workloads) fused scoring call, one best design per point.

    The sweep twin of :func:`complete_design`: ``workloads`` (plus
    optional per-point ``mixes`` — see
    :func:`repro.core.batchcost.normalize_points`) define the sweep
    axis; the returned list holds each point's winning design.  Each
    per-point result is identical to calling ``complete_design`` with
    that point's (workload, mix) — asserted in ``tests/test_sweep.py``.
    """
    t0 = time.perf_counter()
    frontier = list(enumerate_frontier(partial, candidates, terminals,
                                       max_depth, name))
    if not frontier:
        raise RuntimeError("no valid completion found")
    grid = batchcost.cost_sweep(frontier, workloads, hw, mixes,
                                engine=engine)
    elapsed = time.perf_counter() - t0
    results = []
    for row in grid:
        best = int(np.argmin(row))   # first minimum — Algorithm 1's strict <
        results.append(SearchResult(frontier[best], float(row[best]),
                                    len(frontier), elapsed, engine=engine))
    return results


#: the paper-facing name: the best-design-vs-workload continuum curve
design_continuum = complete_design_sweep


# ---------------------------------------------------------------------------
# Greedy local search (hill climbing) over the design space
# ---------------------------------------------------------------------------
def design_neighbors(chain: Tuple[Element, ...],
                     candidates: Sequence[Element],
                     terminals: Sequence[Element]
                     ) -> List[DataStructureSpec]:
    """One-mutation neighborhood: fanout/capacity doublings and halvings,
    element swaps, terminal swaps, level drops.  Deterministic order."""
    neighbors = []
    for i, e in enumerate(chain):
        f = e.get("fanout")
        if isinstance(f, tuple) and f[0] == "fixed":
            for nf in (max(int(f[1]) // 2, 2), int(f[1]) * 2):
                if nf != f[1]:
                    neighbors.append(
                        chain[:i] + (e.with_values(fanout=("fixed", nf)),) +
                        chain[i + 1:])
        elif isinstance(f, tuple) and f[0] == "terminal":
            for nc in (max(int(f[1]) // 2, 16), min(int(f[1]) * 2, 1 << 16)):
                if nc != f[1]:
                    neighbors.append(
                        chain[:i] +
                        (e.with_values(fanout=("terminal", nc)),) +
                        chain[i + 1:])
    for i in range(len(chain) - 1):
        for cand in candidates:
            if cand.name != chain[i].name:
                neighbors.append(chain[:i] + (cand,) + chain[i + 1:])
        neighbors.append(chain[:i] + chain[i + 1:])  # drop level i
    for term in terminals:
        if term.name != chain[-1].name:
            neighbors.append(chain[:-1] + (term,))

    valid, seen = [], set()
    for nb in neighbors:
        key = tuple((e.name, e.get("fanout")) for e in nb)
        if key in seen or not _meaningful(nb):
            continue
        try:
            valid.append(DataStructureSpec("climb", nb))
        except ValueError:
            continue
        seen.add(key)
    return valid


def _cost_new_designs(frontier: Sequence[DataStructureSpec],
                      costs: Dict[Tuple[Element, ...], float],
                      workload: Workload, hw: HardwareProfile,
                      mix: Optional[Dict[str, float]], batched: bool,
                      engine: str,
                      budget: Optional[SearchBudget] = None) -> int:
    """Cost only the chains not in ``costs`` (one batched call) and fold
    them in; returns how many new designs were costed.  The seen-set is
    keyed on the cached ``Element`` chain hashes, so successive search
    rounds never re-pack or re-score a design costed earlier — and
    ``explored``/``designs_costed`` counts unique designs.  Deduped
    within the call too: beam rounds can reach one chain through several
    members' mutations.  A :class:`repro.core.search.SearchBudget`
    truncates the batch to its remaining grant (budget accounting is
    designs-costed, shared with ``population_search`` so equal-budget
    comparisons are exact) — a zero grant folds in nothing."""
    new: List[DataStructureSpec] = []
    batch: set = set()
    for s in frontier:
        if s.chain not in costs and s.chain not in batch:
            batch.add(s.chain)
            new.append(s)
    if not new:
        return 0
    if budget is not None:
        try:
            new = new[:budget.charge(len(new))]
        except BudgetExhausted:
            return 0
        if not new:
            return 0
    if batched:
        totals = cost_many(new, workload, hw, mix, engine=engine)
    else:
        totals = [cost_workload(s, workload, hw, mix) for s in new]
    for s, total in zip(new, totals):
        costs[s.chain] = float(total)
    return len(new)


def design_hillclimb(workload: Workload, hw: HardwareProfile,
                     mix: Optional[Dict[str, float]] = None,
                     start: Optional[DataStructureSpec] = None,
                     max_steps: int = 30, batched: bool = True,
                     engine: str = "fused",
                     budget: Optional[SearchBudget] = None) -> Dict:
    """Greedy local search; each step packs and costs only the
    never-seen part of the neighbor frontier in one batched call (or a
    scalar loop with ``batched=False`` — the climb path and result are
    identical), reusing cached costs for neighbors revisited across
    rounds.  An optional :class:`repro.core.search.SearchBudget` caps
    designs costed (the climb stops when the grant runs dry).  Returns
    a result dict."""
    candidates = default_candidates()
    terminals = default_terminals()
    spec = start or el.spec_btree()
    costs: Dict[Tuple[Element, ...], float] = {}
    t0 = time.perf_counter()
    _cost_new_designs([spec], costs, workload, hw, mix, batched, engine,
                      budget)
    if spec.chain not in costs:
        raise BudgetExhausted("budget too small to cost the start design")
    current = costs[spec.chain]
    for _ in range(max_steps):
        frontier = design_neighbors(spec.chain, candidates, terminals)
        if not frontier:
            break
        _cost_new_designs(frontier, costs, workload, hw, mix, batched,
                          engine, budget)
        totals = np.asarray([costs.get(s.chain, np.inf) for s in frontier])
        best = int(np.argmin(totals))
        # accept only improvements beyond the documented fused/scalar
        # agreement tolerance (1e-6 relative), so every costing path takes
        # the identical climb regardless of float-noise-level differences
        if totals[best] >= current * (1.0 - 1e-6):
            break
        spec, current = frontier[best], float(totals[best])
    elapsed = time.perf_counter() - t0
    return {"design": spec.describe(),
            "fanouts": [e.get("fanout") for e in spec.chain],
            "cost_s": current, "designs_costed": len(costs),
            "elapsed_s": elapsed,
            "designs_per_s": len(costs) / max(elapsed, 1e-12)}


def design_beam(workload: Workload, hw: HardwareProfile,
                mix: Optional[Dict[str, float]] = None,
                start: Optional[Sequence[DataStructureSpec]] = None,
                beam_width: int = 4, max_rounds: int = 12,
                batched: bool = True, engine: str = "fused",
                budget: Optional[SearchBudget] = None) -> Dict:
    """Beam search over the mutation neighborhood.

    Each round mutates every beam member and costs the union of
    never-seen neighbors in **one** batched call — the segment cache
    splices previously-packed designs, so round N+1 pays only for
    genuinely new chains (incremental frontier packing).  Stops when a
    round improves nothing, or when the optional
    :class:`repro.core.search.SearchBudget` stops granting designs.
    Wider exploration than the greedy climb at the same per-round cost
    profile."""
    candidates = default_candidates()
    terminals = default_terminals()
    seeds = list(start) if start else [el.spec_btree()]
    costs: Dict[Tuple[Element, ...], float] = {}
    by_chain: Dict[Tuple[Element, ...], DataStructureSpec] = {}
    t0 = time.perf_counter()

    def admit(specs: Sequence[DataStructureSpec]) -> int:
        costed = _cost_new_designs(specs, costs, workload, hw, mix,
                                   batched, engine, budget)
        for s in specs:       # only scored chains compete for the beam
            if s.chain in costs:
                by_chain.setdefault(s.chain, s)
        return costed

    admit(seeds)
    beam = sorted(by_chain, key=lambda c: costs[c])[:beam_width]
    if not beam:
        raise BudgetExhausted("budget too small to cost any seed design")
    rounds = 0
    for _ in range(max_rounds):
        rounds += 1
        best_before = costs[beam[0]]
        neighbors: List[DataStructureSpec] = []
        for chain in beam:
            neighbors.extend(design_neighbors(chain, candidates, terminals))
        costed = admit(neighbors)
        beam = sorted(by_chain, key=lambda c: costs[c])[:beam_width]
        if costs[beam[0]] >= best_before * (1.0 - 1e-6) or \
                (budget is not None and costed == 0):
            break
    spec = by_chain[beam[0]]
    elapsed = time.perf_counter() - t0
    return {"design": spec.describe(),
            "fanouts": [e.get("fanout") for e in spec.chain],
            "cost_s": costs[beam[0]], "designs_costed": len(costs),
            "rounds": rounds, "elapsed_s": elapsed,
            "designs_per_s": len(costs) / max(elapsed, 1e-12)}


# ---------------------------------------------------------------------------
# Hybrid (Fig. 9) design synthesis
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DomainRegion:
    """A contiguous fraction of the key domain with its own operation mix."""

    name: str
    fraction: float                     # of the key domain
    mix: Dict[str, float]              # op -> count


@dataclasses.dataclass
class HybridDesign:
    root: Element
    regions: List[Tuple[DomainRegion, SearchResult]]
    cost_seconds: float
    elapsed_seconds: float

    def describe(self) -> str:
        parts = ", ".join(
            f"{region.name}: {result.spec.describe()}"
            for region, result in self.regions)
        return f"{self.root.name} -> {{{parts}}}"


def design_hybrid(workload: Workload, regions: Sequence[DomainRegion],
                  hw: HardwareProfile,
                  candidates: Optional[Sequence[Element]] = None,
                  root: Optional[Element] = None,
                  max_depth: int = 2,
                  batched: bool = True,
                  engine: str = "fused") -> HybridDesign:
    """Reproduce the paper's Fig. 9 search: per-region auto-completion under
    a shared partitioning root, costed on each region's own sub-workload.
    Each region's frontier is evaluated in one batched cost_many call."""
    t0 = time.perf_counter()
    root = root or el.hash_element(100)
    results: List[Tuple[DomainRegion, SearchResult]] = []
    total = 0.0
    for region in regions:
        sub_workload = dataclasses.replace(
            workload,
            n_entries=max(int(workload.n_entries * region.fraction), 1))
        result = complete_design((), sub_workload, hw,
                                 candidates=candidates, mix=region.mix,
                                 max_depth=max_depth,
                                 name=f"hybrid-{region.name}",
                                 batched=batched, engine=engine)
        results.append((region, result))
        total += result.cost_seconds
    # root routing cost: one probe per operation through the partitioner
    ops = sum(sum(r.mix.values()) for r in regions)
    from repro.core import access
    from repro.core.synthesis import AccessRecord, CostBreakdown
    cb = CostBreakdown()
    fanout = root.fanout or 100
    cb.add(access.HASH_PROBE if
           root.get("key_partitioning", ("x",))[1] == "func" else
           access.RANDOM_ACCESS, fanout * 8, count=float(ops),
           note="root routing")
    total += cb.total(hw)
    return HybridDesign(root, results, total, time.perf_counter() - t0)
