"""Continuous relaxation of design knobs for gradient-guided search.

The fused engine (:mod:`repro.core.devicecost`) scores designs through
*differentiable* parameter banks — the linear-basis and sigmoid Level-2
model families are smooth in their size argument.  This module exploits
that: a discrete element chain is re-parameterized as a
:class:`RelaxedDesign` — a structural :class:`RelaxTemplate` (which
element class sits at each level) plus a continuous knob vector ``theta``
in log2 space (per-level fanout / partition count, terminal capacity,
optional bloom bits) — and a smooth surrogate of the chain's per-query
cost is evaluated against the profile's *real* bank rows via
:func:`repro.core.devicecost.bank_predict`.  ``jax.grad`` through that
surrogate plus :mod:`repro.optim.adamw` gives :func:`refine`: a few
optimizer steps that walk a knob vector downhill.

The surrogate is a *proposer*, not an oracle: it shares the fitted bank
rows with the fused engine but simplifies the geometry (smooth level
depths, uniform partitioning, no cache-line effects beyond what the
sigmoid rows encode).  :mod:`repro.core.search` therefore only ever uses
gradients to propose knob updates; every decoded discrete design is
scored by the real fused engine and winners are re-verified against the
scalar oracle (``repro.core.synthesis.cost_workload``) — see
``docs/design_search.md`` for the contract.

The objective is conditioned on the workload's read fraction (an
``update`` in the mix pays the get path plus a serial write), so a
read-fraction axis relaxes into the same knob space — the
"read-fraction-conditioned split" of a hybrid design is a per-point
argmin over the relaxed continuum.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import devicecost, elements as el
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.optim.adamw import adamw_init, adamw_update, apply_updates

# ---------------------------------------------------------------------------
# Templates: the discrete skeleton the knobs hang off.
# ---------------------------------------------------------------------------
#: internal element classes with a tunable ("fixed", n) fanout knob
INTERNAL_NAMES = ("Hash", "Range", "B+", "CSB+", "Trie")
#: terminal element classes with a tunable ("terminal", c) capacity knob
TERMINAL_NAMES = ("UDP", "ODP")

#: log2 knob bounds: fanouts/partition counts in [2, 65536]
FANOUT_LO, FANOUT_HI = 1.0, 16.0
#: terminal capacities in [16, 65536] (the hill-climb mutation range)
CAPACITY_LO, CAPACITY_HI = 4.0, 16.0
#: bloom filter bits in [1024, 1048576]
BLOOM_LO, BLOOM_HI = 10.0, 20.0

_INTERNAL_BUILDERS = {
    "Hash": lambda n: el.hash_element(n),
    "Range": lambda n: el.range_element(n),
    "B+": lambda n: el.btree_internal(n),
    "CSB+": lambda n: el.csb_internal(n),
    "Trie": lambda n: el.trie_element(n, 4),
}
_TERMINAL_BUILDERS = {
    "UDP": lambda c: el.unordered_data_page(c),
    "ODP": lambda c: el.ordered_data_page(c),
}


@dataclasses.dataclass(frozen=True)
class RelaxTemplate:
    """The structural skeleton of a relaxed design.

    ``levels`` holds the internal element-class names root-first with the
    terminal class last; ``bloom`` adds a per-sub-block bloom filter (and
    its bits knob) to the root level, valid only when the root is a Hash.
    The knob vector of a template has one log2 entry per level plus one
    trailing bloom-bits entry when ``bloom`` is set.
    """

    levels: Tuple[str, ...]
    bloom: bool = False

    def __post_init__(self) -> None:
        if len(self.levels) < 1:
            raise ValueError("template needs at least a terminal level")
        if self.levels[-1] not in TERMINAL_NAMES:
            raise ValueError(f"unknown terminal class: {self.levels[-1]!r}")
        for name in self.levels[:-1]:
            if name not in INTERNAL_NAMES:
                raise ValueError(f"unknown internal class: {name!r}")
        if self.bloom and (len(self.levels) < 2
                           or self.levels[0] != "Hash"):
            raise ValueError("bloom knob requires a Hash root level")

    @property
    def n_knobs(self) -> int:
        return len(self.levels) + (1 if self.bloom else 0)

    def knob_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-knob (lo, hi) log2 bounds, aligned with ``theta``."""
        lo = [FANOUT_LO] * (len(self.levels) - 1) + [CAPACITY_LO]
        hi = [FANOUT_HI] * (len(self.levels) - 1) + [CAPACITY_HI]
        if self.bloom:
            lo.append(BLOOM_LO)
            hi.append(BLOOM_HI)
        return np.asarray(lo), np.asarray(hi)

    def describe(self) -> str:
        tag = "+BF" if self.bloom else ""
        return " -> ".join(self.levels) + tag


@dataclasses.dataclass(frozen=True)
class RelaxedDesign:
    """One point of the relaxed continuum: a template plus log2 knobs."""

    template: RelaxTemplate
    theta: Tuple[float, ...]

    def __post_init__(self) -> None:
        if len(self.theta) != self.template.n_knobs:
            raise ValueError(
                f"{len(self.theta)} knobs for a "
                f"{self.template.n_knobs}-knob template "
                f"{self.template.describe()!r}")

    def clipped(self) -> "RelaxedDesign":
        lo, hi = self.template.knob_bounds()
        return RelaxedDesign(
            self.template,
            tuple(float(v) for v in np.clip(self.theta, lo, hi)))


def decode(design: RelaxedDesign, name: str = "relaxed"
           ) -> DataStructureSpec:
    """Round a relaxed design back to a discrete, valid specification.

    Knobs round to the nearest integer in linear space (clipped to the
    template's bounds first), so two designs within half an integer knob
    step decode identically — the discretization the search's seen-set
    dedups on.
    """
    design = design.clipped()
    template = design.template
    theta = design.theta
    chain = []
    for i, level in enumerate(template.levels[:-1]):
        fanout = max(int(round(2.0 ** theta[i])), 2)
        element = _INTERNAL_BUILDERS[level](fanout)
        if i == 0 and template.bloom:
            bits = max(int(round(2.0 ** theta[-1])), 8)
            element = element.with_values(
                bloom_filters=("on", 2, bits),
                filters_memory_layout="scatter")
        chain.append(element)
    capacity = max(int(round(2.0 ** theta[len(template.levels) - 1])), 16)
    chain.append(_TERMINAL_BUILDERS[template.levels[-1]](capacity))
    return DataStructureSpec(name, tuple(chain))


def encode(spec: DataStructureSpec) -> Optional[RelaxedDesign]:
    """The inverse of :func:`decode` where one exists.

    Returns ``None`` for chains outside the relaxable family (unlimited
    fanouts, unknown element classes, non-knob primitive settings), so
    callers can seed a population from discrete search results without
    special-casing."""
    levels = []
    theta = []
    bloom = False
    for i, element in enumerate(spec.chain[:-1]):
        if element.name not in INTERNAL_NAMES:
            return None
        fanout = element.fanout
        if fanout is None:
            return None
        levels.append(element.name)
        theta.append(float(np.log2(fanout)))
        bf = element.get("bloom_filters")
        if isinstance(bf, tuple) and bf[0] == "on":
            if i != 0 or element.name != "Hash":
                return None
            bloom = True
            bloom_theta = float(np.log2(bf[2]))
    terminal = spec.chain[-1]
    if terminal.name not in TERMINAL_NAMES or terminal.capacity is None:
        return None
    levels.append(terminal.name)
    theta.append(float(np.log2(terminal.capacity)))
    if bloom:
        theta.append(bloom_theta)
    try:
        template = RelaxTemplate(tuple(levels), bloom)
    except ValueError:
        return None
    return RelaxedDesign(template, tuple(theta)).clipped()


# ---------------------------------------------------------------------------
# The smooth surrogate: real bank rows, relaxed geometry.
# ---------------------------------------------------------------------------
#: Level-2 model name used per surrogate term
_SORTED_SEARCH = "binary_search_columnstore"
_HASH_PROBE = "hash_probe_multiply_shift"
_BLOOM_PROBE = "bloom_probe_multiply_shift"
_RANDOM_ACCESS = "random_memory_access"
_SCAN = "scalar_scan_columnstore_equal"
_SERIAL_WRITE = "serial_write"

_SURROGATE_MODELS = (_SORTED_SEARCH, _HASH_PROBE, _BLOOM_PROBE,
                     _RANDOM_ACCESS, _SCAN, _SERIAL_WRITE)


def _surrogate_rows() -> Dict[str, int]:
    """Interned bank-row ids of the surrogate's model zoo (process-wide,
    shared with the fused engine's frontier records)."""
    return {name: devicecost.model_id(name) for name in _SURROGATE_MODELS}


@functools.lru_cache(maxsize=512)
def _surrogate_fn(template: RelaxTemplate):
    """The jitted ``(cost, grad_theta)`` function of one template.

    The template's level structure is baked in statically (a bounded set
    of templates appears in any search run, so the compile set is
    bounded); banks, data size and read fraction stay traced inputs —
    a hardware swap reuses the compiled surrogate exactly like the fused
    scorer reuses its executable.
    """
    rows = _surrogate_rows()
    levels = template.levels
    bloom = template.bloom

    def cost(theta, banks, n_entries, read_fraction, value_bytes):
        cap = 2.0 ** theta[len(levels) - 1]
        xs = []          # model input sizes, one per surrogate term
        ids = []         # bank rows, aligned with xs
        weights = []     # smooth visit counts, aligned with xs
        n = n_entries
        for i, level in enumerate(levels[:-1]):
            fanout = 2.0 ** theta[i]
            log_f = jnp.log(jnp.maximum(fanout, 2.0))
            if level in ("B+", "CSB+"):
                # recursive sorted level: height to reach leaves of the
                # terminal's capacity, one bounded search per node
                depth = jnp.maximum(
                    jnp.log(jnp.maximum(n / cap, 2.0)) / log_f, 1.0)
                ids.append(rows[_SORTED_SEARCH])
                xs.append(fanout)
                weights.append(depth)
                n = cap
            elif level == "Range":
                ids.append(rows[_SORTED_SEARCH])
                xs.append(fanout)
                weights.append(jnp.asarray(1.0))
                n = n / fanout
            elif level == "Hash":
                if i == 0 and bloom:
                    ids.append(rows[_BLOOM_PROBE])
                    xs.append(2.0 ** theta[-1] / 8.0)
                    weights.append(jnp.asarray(1.0))
                ids.append(rows[_HASH_PROBE])
                xs.append(fanout)
                weights.append(jnp.asarray(1.0))
                ids.append(rows[_RANDOM_ACCESS])
                xs.append(jnp.maximum(n, 1.0))
                weights.append(jnp.asarray(1.0))
                n = n / fanout
            else:      # Trie: radix descent, one random access per hop
                depth = jnp.minimum(
                    jnp.log(jnp.maximum(n, 2.0)) / log_f, 4.0)
                ids.append(rows[_RANDOM_ACCESS])
                xs.append(fanout)
                weights.append(depth)
                n = n / fanout ** depth
            n = jnp.maximum(n, 1.0)
        page = jnp.minimum(jnp.maximum(n, 1.0), cap)
        if levels[-1] == "ODP":
            ids.append(rows[_SORTED_SEARCH])
            xs.append(page)
            weights.append(jnp.asarray(1.0))
        else:          # UDP: expected half-page scan
            ids.append(rows[_SCAN])
            xs.append(0.5 * page)
            weights.append(jnp.asarray(1.0))
        # writes pay the read path plus a serial value write
        ids.append(rows[_SERIAL_WRITE])
        xs.append(value_bytes)
        weights.append(1.0 - read_fraction)
        y = devicecost.bank_predict(
            banks, jnp.asarray(ids, jnp.int32), jnp.stack(xs),
            with_knn=False)
        return (jnp.stack(weights) * y).sum()

    return jax.jit(jax.value_and_grad(cost))


def surrogate_cost(design: RelaxedDesign, hw: HardwareProfile,
                   n_entries: float, read_fraction: float = 1.0,
                   value_bytes: float = 8.0) -> float:
    """The smooth surrogate's per-query cost estimate (diagnostics)."""
    value, _ = _surrogate_fn(design.template)(
        jnp.asarray(design.theta, jnp.float32),
        devicecost.device_table(hw).banks,
        jnp.asarray(float(n_entries), jnp.float32),
        jnp.asarray(float(read_fraction), jnp.float32),
        jnp.asarray(float(value_bytes), jnp.float32))
    return float(value)


@dataclasses.dataclass(frozen=True)
class _RefineConfig:
    """The RunConfig slice :func:`repro.optim.adamw.adamw_update` reads —
    a constant schedule (no warmup, no cosine decay tail)."""

    learning_rate: float
    warmup_steps: int = 0
    total_steps: int = 1 << 30     # flat schedule over any step count
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.95


def refine(design: RelaxedDesign, hw: HardwareProfile,
           n_entries: float, read_fraction: float = 1.0,
           value_bytes: float = 8.0, steps: int = 8,
           learning_rate: float = 0.35) -> RelaxedDesign:
    """Walk a knob vector downhill on the surrogate with AdamW.

    Returns the refined (clipped) design; the caller decodes it and
    scores the discrete result with the real fused engine — gradients
    only ever *propose*.  Knobs are projected back into the template's
    log2 bounds after every step, so the optimizer cannot escape the
    decodable continuum.
    """
    grad_fn = _surrogate_fn(design.template)
    banks = devicecost.device_table(hw).banks
    lo, hi = design.template.knob_bounds()
    params = {"theta": jnp.asarray(design.theta, jnp.float32)}
    state = adamw_init(params)
    run = _RefineConfig(learning_rate=learning_rate)
    n = jnp.asarray(float(n_entries), jnp.float32)
    r = jnp.asarray(float(read_fraction), jnp.float32)
    vb = jnp.asarray(float(value_bytes), jnp.float32)
    for _ in range(max(int(steps), 1)):
        _, grad = grad_fn(params["theta"], banks, n, r, vb)
        updates, state = adamw_update({"theta": grad}, state, params, run)
        params = apply_updates(params, updates)
        params = {"theta": jnp.clip(params["theta"],
                                    jnp.asarray(lo, jnp.float32),
                                    jnp.asarray(hi, jnp.float32))}
    return RelaxedDesign(design.template,
                         tuple(float(v) for v in np.asarray(
                             params["theta"], np.float64)))


def read_fraction_of(mix: Optional[Dict[str, float]],
                     default_queries: float = 100.0) -> float:
    """The read share of an operation mix (``get``/``range_get`` weight
    over total) — the conditioning input of the relaxed objective."""
    if not mix:
        return 1.0
    total = sum(float(v) for v in mix.values())
    if total <= 0.0:
        return 1.0
    reads = sum(float(v) for op, v in mix.items()
                if op in ("get", "range_get"))
    return reads / total
