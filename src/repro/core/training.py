"""Cost-learning module (paper Fig. 4): benchmark -> fit -> profile.

Runs every Level-2 primitive's micro-benchmark over its size grid on the
current machine, fits the designated model family with JAX, and assembles a
:class:`HardwareProfile`.  This is the paper's offline "training" pass —
"it takes merely a few minutes" (Fig. 7b) — kept that cheap here by bounding
reps per size.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core import access
from repro.core.hardware import HardwareProfile
from repro.core.models import FittedModel, fit, r2_score


def benchmark_primitive(prim: access.Level2Primitive,
                        sizes: Optional[Iterable[int]] = None,
                        reps: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """Collect (X, Y): size grid vs measured seconds-per-op (Fig. 4 step 1-2)."""
    xs, ys = [], []
    for n in (sizes or prim.sizes):
        # fewer reps on big inputs keeps total training time bounded
        n_reps = max(int(reps / max(np.log2(n) - 6, 1)), 4)
        ys.append(prim.benchmark(int(n), n_reps))
        xs.append(float(n))
    return np.asarray(xs, np.float64), np.asarray(ys, np.float64)


def train_profile(name: str = "HW-container",
                  primitives: Optional[Iterable[str]] = None,
                  reps: int = 64,
                  max_size: Optional[int] = None) -> HardwareProfile:
    """Train all (or selected) Level-2 primitives on this machine."""
    models: Dict[str, FittedModel] = {}
    fit_quality: Dict[str, float] = {}
    t0 = time.perf_counter()
    names = list(primitives or access.LEVEL2.keys())
    for pname in names:
        prim = access.LEVEL2[pname]
        sizes = [s for s in prim.sizes if max_size is None or s <= max_size]
        x, y = benchmark_primitive(prim, sizes=sizes, reps=reps)
        model = fit(prim.model_kind, x, y)
        pred = model.predict(x)
        fit_quality[pname] = r2_score(y, pred)
        models[pname] = model
    train_s = time.perf_counter() - t0
    constants = {"training_seconds": train_s}
    constants.update({f"r2_{k}": v for k, v in fit_quality.items()})
    return HardwareProfile(name, models, constants=constants)


def quick_profile(name: str = "HW-container-quick") -> HardwareProfile:
    """Reduced grid used by tests: trains in a few seconds."""
    models: Dict[str, FittedModel] = {}
    for pname, prim in access.LEVEL2.items():
        sizes = prim.sizes[:5]
        x, y = benchmark_primitive(prim, sizes=sizes, reps=16)
        models[pname] = fit(prim.model_kind, x, y)
    return HardwareProfile(name, models)
