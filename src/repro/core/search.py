"""Population-based design search over the relaxed continuum.

The fused engine prices a *population* the same as a single design: one
:func:`repro.core.batchcost.pack_sweep` / ``score_sweep`` call per
generation scores every not-yet-seen candidate against every sweep
point in one jitted evaluation.  :func:`population_search` exploits
that with a classic evolutionary loop — tournament selection,
structural crossover at template (level) boundaries, gaussian knob
mutation in log2 space — hybridized with gradient refinement of the
elite through :func:`repro.core.relax.refine` (``jax.grad`` through the
same parameter banks the fused scorer reads).

Three invariants the loop maintains:

* **Survivors are never re-packed.**  A ``seen`` memo maps decoded
  chains to their scored cost; only genuinely new chains reach
  ``cost_sweep``, and those hit the incremental ``pack_frontier``
  segment memos for any structurally-shared levels.  After warmup the
  generation loop triggers zero recompiles (pow2 shape bucketing in
  :mod:`repro.core.devicecost`).
* **Budgets are designs-costed.**  A :class:`SearchBudget` counts every
  distinct design that reaches an engine, shared verbatim with
  ``design_hillclimb``/``design_beam`` so "equal budget" comparisons
  are exact, not wall-clock approximations.
* **Winners are oracle-verified.**  Whenever the incumbent best design
  changes, it is re-scored by the scalar expert system
  (:func:`repro.core.synthesis.cost_workload`) and must agree with the
  engine to 1e-6 relative before being reported; the reported design is
  always the *discrete* rounding (:func:`repro.core.relax.decode`), the
  relaxation never leaks out.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import batchcost, relax, synthesis
from repro.core.elements import DataStructureSpec
from repro.core.hardware import HardwareProfile
from repro.core.relax import RelaxTemplate, RelaxedDesign
from repro.core.synthesis import Workload

#: relative tolerance of the winner-vs-scalar-oracle check
ORACLE_RTOL = 1e-6

#: default structural skeletons seeding a search population
DEFAULT_TEMPLATES = (
    RelaxTemplate(("B+", "ODP")),
    RelaxTemplate(("CSB+", "ODP")),
    RelaxTemplate(("Hash", "UDP")),
    RelaxTemplate(("Hash", "UDP"), bloom=True),
    RelaxTemplate(("Range", "ODP")),
    RelaxTemplate(("Range", "B+", "ODP")),
    RelaxTemplate(("Hash", "B+", "ODP"), bloom=True),
    RelaxTemplate(("Trie", "UDP")),
)

#: crossover/mutation never grow chains beyond this many internal levels
MAX_INTERNAL_LEVELS = 3

#: the log2 jitter the mutation sigma anneals down to as budget depletes
FINE_SIGMA = 0.08


class BudgetExhausted(RuntimeError):
    """Raised by :meth:`SearchBudget.charge` when nothing remains."""


class SearchBudget:
    """Designs-costed accounting shared by every search strategy.

    ``charge(n)`` grants up to ``n`` units and returns the granted
    count (possibly smaller near the limit, zero raising
    :class:`BudgetExhausted`), so callers can truncate a candidate batch
    to exactly what the budget allows.  Thread-safe: the serving tier
    charges search requests from worker threads.
    """

    def __init__(self, limit: int) -> None:
        if limit < 1:
            raise ValueError("budget limit must be >= 1")
        self.limit = int(limit)
        self._spent = 0
        self._lock = threading.Lock()

    @property
    def spent(self) -> int:
        return self._spent

    @property
    def remaining(self) -> int:
        return max(self.limit - self._spent, 0)

    @property
    def exhausted(self) -> bool:
        return self._spent >= self.limit

    def charge(self, n: int) -> int:
        """Reserve up to ``n`` design evaluations; returns the grant."""
        if n < 0:
            raise ValueError("cannot charge a negative design count")
        with self._lock:
            grant = min(n, self.limit - self._spent)
            if n > 0 and grant == 0:
                raise BudgetExhausted(
                    f"designs-costed budget {self.limit} exhausted")
            self._spent += grant
            return grant

    def __repr__(self) -> str:
        return (f"SearchBudget(spent={self._spent}, "
                f"limit={self.limit})")


# ---------------------------------------------------------------------------
# Evolutionary operators (pure functions of an explicit random.Random).
# ---------------------------------------------------------------------------
def random_design(rng: random.Random, template: RelaxTemplate
                  ) -> RelaxedDesign:
    """Uniform knob sample inside the template's log2 bounds."""
    lo, hi = template.knob_bounds()
    theta = tuple(rng.uniform(float(a), float(b))
                  for a, b in zip(lo, hi))
    return RelaxedDesign(template, theta)


def mutate(rng: random.Random, design: RelaxedDesign,
           sigma: float = 0.6, structural_p: float = 0.25
           ) -> RelaxedDesign:
    """Gaussian log2 knob jitter, occasionally a structural edit.

    Structural edits stay inside the relaxable family: swap one internal
    level's class, add/drop an internal level (depth capped), swap the
    terminal class, or toggle the root bloom filter — each re-using the
    surviving knob values so a structural step doesn't reset tuning.
    """
    template = design.template
    theta = list(design.theta)
    if rng.random() < structural_p:
        levels = list(template.levels)
        internals = levels[:-1]
        bloom = template.bloom
        bloom_theta = theta[-1] if bloom else rng.uniform(
            relax.BLOOM_LO, relax.BLOOM_HI)
        knobs = theta[:len(levels)]          # per-level knobs only
        move = rng.choice(("swap", "grow", "shrink", "terminal", "bloom"))
        if move == "swap" and internals:
            i = rng.randrange(len(internals))
            internals[i] = rng.choice(relax.INTERNAL_NAMES)
        elif move == "grow" and len(internals) < MAX_INTERNAL_LEVELS:
            i = rng.randrange(len(internals) + 1)
            internals.insert(i, rng.choice(relax.INTERNAL_NAMES))
            knobs.insert(i, rng.uniform(relax.FANOUT_LO, relax.FANOUT_HI))
        elif move == "shrink" and len(internals) > 1:
            i = rng.randrange(len(internals))
            del internals[i]
            del knobs[i]
        elif move == "terminal":
            knobs[-1] = rng.uniform(relax.CAPACITY_LO, relax.CAPACITY_HI)
            levels[-1] = ("UDP" if levels[-1] == "ODP" else "ODP")
        else:
            bloom = not bloom
        bloom = bloom and bool(internals) and internals[0] == "Hash"
        template = RelaxTemplate((*internals, levels[-1]), bloom)
        theta = knobs + ([bloom_theta] if bloom else [])
    theta = [v + rng.gauss(0.0, sigma) for v in theta]
    return RelaxedDesign(template, tuple(theta)).clipped()


def crossover(rng: random.Random, a: RelaxedDesign, b: RelaxedDesign
              ) -> RelaxedDesign:
    """Structural crossover at a template (level) boundary.

    Splices a prefix of ``a``'s internal levels onto a suffix of ``b``'s
    chain (terminal included), knobs travelling with their levels, so
    offspring inherit *tuned* sub-structures rather than random knobs.
    The root bloom filter follows whichever parent contributes the root.
    """
    a_internals = len(a.template.levels) - 1
    cut_a = rng.randint(0, a_internals)
    b_internals = len(b.template.levels) - 1
    cut_b = rng.randint(0, b_internals)
    levels = (a.template.levels[:cut_a]
              + b.template.levels[cut_b:-1])[:MAX_INTERNAL_LEVELS]
    knobs = (list(a.theta[:cut_a])
             + list(b.theta[cut_b:b_internals]))[:MAX_INTERNAL_LEVELS]
    levels = levels + (b.template.levels[-1],)
    knobs.append(b.theta[b_internals])       # terminal capacity knob
    if cut_a > 0:
        bloom = a.template.bloom
        bloom_theta = a.theta[-1] if bloom else 0.0
    else:
        bloom = b.template.bloom and cut_b == 0
        bloom_theta = b.theta[-1] if bloom else 0.0
    bloom = bloom and len(levels) > 1 and levels[0] == "Hash"
    if bloom:
        knobs.append(bloom_theta)
    return RelaxedDesign(RelaxTemplate(levels, bloom),
                         tuple(knobs)).clipped()


def _tournament(rng: random.Random, pop: Sequence[RelaxedDesign],
                fits: Sequence[float], k: int) -> RelaxedDesign:
    picks = [rng.randrange(len(pop)) for _ in range(max(k, 1))]
    return pop[min(picks, key=lambda i: fits[i])]


# ---------------------------------------------------------------------------
# The search loop.
# ---------------------------------------------------------------------------
def _verify_winner(spec: DataStructureSpec, engine_cost: float,
                   points, hw: HardwareProfile) -> float:
    """Scalar-oracle check of a reported winner (mean over sweep points).

    Raises ``AssertionError`` on disagreement beyond :data:`ORACLE_RTOL`
    — a search must never report a design the expert system disowns.
    """
    oracle = float(np.mean([
        synthesis.cost_workload(spec, w, hw, dict(mix_items))
        for w, mix_items in points]))
    err = abs(oracle - engine_cost) / max(abs(oracle), 1e-30)
    if err > ORACLE_RTOL:
        raise AssertionError(
            f"winner {spec.name!r} fails oracle verification: "
            f"engine {engine_cost!r} vs scalar {oracle!r} "
            f"(rel err {err:.3e} > {ORACLE_RTOL})")
    return oracle


def population_search(
        workload: Workload, hw: HardwareProfile,
        mix: Optional[Dict[str, float]] = None, *,
        budget: SearchBudget,
        population: int = 24, generations: int = 12,
        tournament: int = 3, mutation_sigma: float = 0.6,
        crossover_rate: float = 0.6, refine_top: int = 4,
        refine_steps: int = 4, seed: int = 0, engine: str = "fused",
        templates: Sequence[RelaxTemplate] = DEFAULT_TEMPLATES,
        seeds: Sequence[DataStructureSpec] = (),
        workloads: Optional[Sequence[Workload]] = None,
        mixes=None,
        score_fn: Optional[Callable[
            [List[DataStructureSpec]], np.ndarray]] = None,
        verify_oracle: bool = True) -> Dict[str, object]:
    """Evolve a population of relaxed designs under a designs budget.

    Each generation decodes the population to discrete chains, scores
    the never-seen ones in **one** ``cost_sweep`` call (every sweep
    point, every new design, one fused evaluation), then breeds the next
    generation by tournament selection, structural crossover, knob
    mutation and AdamW refinement of the elite.  Fitness is the mean
    engine cost over the sweep points (pass ``workloads``/``mixes`` for
    a multi-point axis, e.g. a read-fraction sweep).  ``score_fn``
    overrides the scoring call (the serving tier injects its
    deadline/fault-healing path); it must return one cost per spec.

    Returns the ``design_beam``-shaped result dict (``design``,
    ``fanouts``, ``cost_s``, ``designs_costed``, ``elapsed_s``, ...)
    plus search diagnostics, with the winner oracle-verified.
    """
    if population < 2:
        raise ValueError("population must be >= 2")
    t0 = time.perf_counter()
    rng = random.Random(seed)
    points = batchcost.normalize_points(
        list(workloads) if workloads is not None else [workload],
        mixes if mixes is not None else mix)
    read_fraction = float(np.mean([
        relax.read_fraction_of(dict(mi)) for _, mi in points]))

    if score_fn is None:
        def score_fn(specs: List[DataStructureSpec]) -> np.ndarray:
            grid = batchcost.cost_sweep(
                specs, [w for w, _ in points], hw,
                [dict(mi) for _, mi in points], engine=engine)
            return np.asarray(grid, np.float64).mean(axis=0)

    seen: Dict[tuple, float] = {}       # chain -> mean engine cost

    def score_population(pop: List[RelaxedDesign]
                         ) -> Tuple[List[float], bool]:
        """One engine call for the generation; True when budget ran dry."""
        decoded = [relax.decode(d, f"gen{generation}_{i}")
                   for i, d in enumerate(pop)]
        fresh: List[DataStructureSpec] = []
        fresh_chains = set()
        for spec in decoded:
            if spec.chain not in seen and spec.chain not in fresh_chains:
                fresh.append(spec)
                fresh_chains.add(spec.chain)
        truncated = False
        if fresh:
            try:
                grant = budget.charge(len(fresh))
            except BudgetExhausted:
                grant = 0
            truncated = grant < len(fresh)
            fresh = fresh[:grant]
        if fresh:
            costs = score_fn(fresh)
            for spec, cost in zip(fresh, costs):
                seen[spec.chain] = float(cost)
        fits = [seen.get(spec.chain, float("inf")) for spec in decoded]
        return fits, truncated

    # -- generation 0: template-stratified random init + encoded seeds --
    pop: List[RelaxedDesign] = []
    for spec in seeds:
        enc = relax.encode(spec)
        if enc is not None:
            pop.append(enc)
    i = 0
    while len(pop) < population:
        pop.append(random_design(rng, templates[i % len(templates)]))
        i += 1
    pop = pop[:max(population, len(pop))]

    best_design: Optional[RelaxedDesign] = None
    best_spec: Optional[DataStructureSpec] = None
    best_cost = float("inf")
    history: List[float] = []
    verified_cost: Optional[float] = None
    generation = 0
    exhausted = False
    for generation in range(generations):
        fits, exhausted = score_population(pop)
        ranked = sorted(range(len(pop)), key=lambda i: fits[i])
        if fits[ranked[0]] < best_cost * (1.0 - 1e-12):
            best_cost = fits[ranked[0]]
            best_design = pop[ranked[0]]
            best_spec = relax.decode(best_design, "winner")
            if verify_oracle:
                verified_cost = _verify_winner(
                    best_spec, best_cost, points, hw)
        history.append(best_cost)
        if exhausted or budget.exhausted or generation == generations - 1:
            break
        # -- breed the next generation ------------------------------------
        elite = []
        for i in ranked:
            if np.isfinite(fits[i]) and pop[i] not in elite:
                elite.append(pop[i])
            if len(elite) >= max(refine_top, 1):
                break
        # anneal the knob jitter on budget *spent*, not generation count:
        # coarse structural exploration while designs are cheap, fine
        # continuum exploitation (below any pow2 grid step) near the end
        frac = budget.spent / budget.limit
        sigma = mutation_sigma * (
            min(FINE_SIGMA, mutation_sigma) / mutation_sigma) ** frac
        children: List[RelaxedDesign] = list(elite[:2])   # elitism
        for d in elite[:refine_top]:
            if refine_steps > 0:
                children.append(relax.refine(
                    d, hw, float(points[0][0].n_entries),
                    read_fraction, steps=refine_steps))
        for d in elite:                     # pure-knob local exploitation
            children.append(mutate(rng, d, FINE_SIGMA, structural_p=0.0))
        # one random immigrant keeps structural diversity from draining
        children.append(random_design(
            rng, templates[rng.randrange(len(templates))]))
        while len(children) < population:
            parent = _tournament(rng, pop, fits, tournament)
            if rng.random() < crossover_rate:
                other = _tournament(rng, pop, fits, tournament)
                child = crossover(rng, parent, other)
            else:
                child = parent
            children.append(mutate(rng, child, sigma))
        pop = children[:population]

    if best_spec is None:
        raise BudgetExhausted(
            "budget exhausted before any design was scored")
    fanouts = tuple(e.fanout or e.capacity for e in best_spec.chain)
    return {
        "design": best_spec,
        "template": best_design.template.describe(),
        "theta": best_design.theta,
        "fanouts": fanouts,
        "cost_s": best_cost,
        "oracle_cost_s": verified_cost,
        "designs_costed": budget.spent,
        "generations": generation + 1,
        "history": history,
        "elapsed_s": time.perf_counter() - t0,
        "budget_exhausted": exhausted or budget.exhausted,
        "engine": engine,
    }
