"""Thread-safe memo layer for the batch-costing stack.

The costing stack keeps several module-level memos (batchcost's segment /
frontier caches, devicecost's model-name interning and per-profile device
tables, templatecost's statics map).  A long-lived serving process
(:mod:`repro.serving`) answers questions from many threads, and the
``functools.lru_cache`` layers are already safe under CPython — but the
insertable dict caches and the interning tables are get-then-put sequences
whose hit/miss accounting (and ``OrderedDict`` recency bookkeeping) can be
corrupted by concurrent callers, and an insert racing ``clear_caches()``
can resurrect a stale entry mid-drain.

This module owns the single re-entrant lock every such memo shares
(``MEMO_LOCK``) plus the :class:`DictCache` built on it.  One lock — not
one per cache — so cross-layer operations (``batchcost.clear_caches()``,
``batchcost.cache_info()``) observe every layer at a consistent point:
no thread can be between a segment-cache put and the matching
frontier-cache put while a clear or info snapshot runs.

The lock guards *bookkeeping*, not computation: cache misses compute
outside the lock, so two threads may redundantly pack the same frontier —
benign (both store equal values) and far cheaper than serializing
synthesis.

Named caches self-register in :data:`REGISTRY` so the cache-key
*invariants* of the stack — hardware appears in no synthesis/packing key,
workload appears in no template-statics key (see
``docs/cost_pipeline.md``) — can be asserted by introspection
(``tests/test_cache_keys.py`` walks every registered cache's keys) instead
of being comments that rot.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional

#: the one re-entrant lock shared by every memo in the costing stack
MEMO_LOCK = threading.RLock()

#: named DictCaches, for cache-key introspection (tests, docs tooling);
#: re-registering a name replaces the entry (tests swap caches freely)
REGISTRY: Dict[str, "DictCache"] = {}


def registered_caches() -> Dict[str, "DictCache"]:
    """Snapshot of every named cache currently registered."""
    with MEMO_LOCK:
        return dict(REGISTRY)


CacheInfo = collections.namedtuple("CacheInfo",
                                   "hits misses maxsize currsize")


class DictCache:
    """An insertable memo with lru_cache-style hit/miss accounting.

    ``functools.lru_cache`` cannot be *populated* from outside, but the
    vectorized packer computes many entries per call and must store them
    all; this keeps the same observable counters so cache tests treat
    every layer uniformly.  ``maxsize`` evicts the least-recently-used
    entry (hits refresh recency — a burst of small what-if frontiers
    must not push the retained steady-state search frontier out).

    Every method holds :data:`MEMO_LOCK`, so counters, the recency order
    and ``info()`` snapshots stay consistent under concurrent scoring.
    """

    def __init__(self, maxsize: Optional[int] = None,
                 name: Optional[str] = None) -> None:
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self._maxsize = maxsize
        self._hits = 0
        self._misses = 0
        if name is not None:
            with MEMO_LOCK:
                REGISTRY[name] = self

    def keys(self) -> List:
        """Snapshot of the current keys (cache-key invariant tests)."""
        with MEMO_LOCK:
            return list(self._data.keys())

    def get(self, key):
        with MEMO_LOCK:
            entry = self._data.get(key)
            if entry is None:
                self._misses += 1
            else:
                self._hits += 1
                self._data.move_to_end(key)
            return entry

    def put(self, key, value) -> None:
        with MEMO_LOCK:
            self._data[key] = value
            if self._maxsize is not None and len(self._data) > self._maxsize:
                self._data.popitem(last=False)

    def clear(self) -> None:
        with MEMO_LOCK:
            self._data.clear()
            self._hits = self._misses = 0

    def info(self) -> CacheInfo:
        with MEMO_LOCK:
            return CacheInfo(self._hits, self._misses, self._maxsize,
                             len(self._data))
