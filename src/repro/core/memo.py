"""Thread-safe memo layer for the batch-costing stack.

The costing stack keeps several module-level memos (batchcost's segment /
frontier caches, devicecost's model-name interning and per-profile device
tables, templatecost's statics map).  A long-lived serving process
(:mod:`repro.serving`) answers questions from many threads, and the
``functools.lru_cache`` layers are already safe under CPython — but the
insertable dict caches and the interning tables are get-then-put sequences
whose hit/miss accounting (and ``OrderedDict`` recency bookkeeping) can be
corrupted by concurrent callers, and an insert racing ``clear_caches()``
can resurrect a stale entry mid-drain.

This module owns the single re-entrant lock every such memo shares
(``MEMO_LOCK``) plus the :class:`DictCache` built on it.  One lock — not
one per cache — so cross-layer operations (``batchcost.clear_caches()``,
``batchcost.cache_info()``) observe every layer at a consistent point:
no thread can be between a segment-cache put and the matching
frontier-cache put while a clear or info snapshot runs.

The lock guards *bookkeeping*, not computation: cache misses compute
outside the lock, so two threads may redundantly pack the same frontier —
benign (both store equal values) and far cheaper than serializing
synthesis.

Named caches self-register in :data:`REGISTRY` so the cache-key
*invariants* of the stack — hardware appears in no synthesis/packing key,
workload appears in no template-statics key (see
``docs/cost_pipeline.md``) — can be asserted by introspection
(``tests/test_cache_keys.py`` walks every registered cache's keys) instead
of being comments that rot.

**Warm-restart snapshots.**  Caches registered with ``snapshot=True``
(the template-statics and packed-segment memos — the expensive,
hardware-free synthesis products) can be persisted to a versioned
on-disk snapshot (:func:`snapshot_caches`) and restored on service start
(:func:`restore_caches`), so a restarted
:class:`~repro.serving.service.DesignCalculatorService` answers its
first question from warm caches.  The snapshot is keyed by a schema
number plus a fingerprint of the costing stack's source
(:func:`snapshot_version`): any code drift invalidates it and the
restore silently falls back to a cold start — a corrupt, truncated or
stale snapshot must *never* crash ``start()``.  Because Level-2 model
ids are interned lazily in first-use order, the snapshot records the
interning table and restore remaps every id-bearing value through the
live table (cache owners contribute the capture/remap hooks via
:func:`register_snapshot_env` / :func:`register_restore_transform`).
"""
from __future__ import annotations

import collections
import hashlib
import importlib
import os
import pickle
import threading
from typing import Callable, Dict, List, Optional, Tuple

#: the one re-entrant lock shared by every memo in the costing stack
MEMO_LOCK = threading.RLock()

#: named DictCaches, for cache-key introspection (tests, docs tooling);
#: re-registering a name replaces the entry (tests swap caches freely)
REGISTRY: Dict[str, "DictCache"] = {}


def registered_caches() -> Dict[str, "DictCache"]:
    """Snapshot of every named cache currently registered."""
    with MEMO_LOCK:
        return dict(REGISTRY)


CacheInfo = collections.namedtuple("CacheInfo",
                                   "hits misses maxsize currsize")


class DictCache:
    """An insertable memo with lru_cache-style hit/miss accounting.

    ``functools.lru_cache`` cannot be *populated* from outside, but the
    vectorized packer computes many entries per call and must store them
    all; this keeps the same observable counters so cache tests treat
    every layer uniformly.  ``maxsize`` evicts the least-recently-used
    entry (hits refresh recency — a burst of small what-if frontiers
    must not push the retained steady-state search frontier out).

    Every method holds :data:`MEMO_LOCK`, so counters, the recency order
    and ``info()`` snapshots stay consistent under concurrent scoring.
    """

    def __init__(self, maxsize: Optional[int] = None,
                 name: Optional[str] = None,
                 snapshot: bool = False) -> None:
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self._maxsize = maxsize
        self._hits = 0
        self._misses = 0
        #: include this cache's entries in warm-restart snapshots
        self.snapshot = snapshot
        if name is not None:
            with MEMO_LOCK:
                REGISTRY[name] = self

    def keys(self) -> List:
        """Snapshot of the current keys (cache-key invariant tests)."""
        with MEMO_LOCK:
            return list(self._data.keys())

    def get(self, key):
        with MEMO_LOCK:
            entry = self._data.get(key)
            if entry is None:
                self._misses += 1
            else:
                self._hits += 1
                self._data.move_to_end(key)
            return entry

    def put(self, key, value) -> None:
        with MEMO_LOCK:
            self._data[key] = value
            if self._maxsize is not None and len(self._data) > self._maxsize:
                self._data.popitem(last=False)

    def clear(self) -> None:
        with MEMO_LOCK:
            self._data.clear()
            self._hits = self._misses = 0

    def discard(self, pred) -> int:
        """Drop every entry with ``pred(key, value)`` true; returns the
        count.  Targeted invalidation for identity-keyed caches (the
        ``device_banks`` replica cache drops a profile's stale replicas
        when its device table rebuilds)."""
        with MEMO_LOCK:
            doomed = [k for k, v in self._data.items() if pred(k, v)]
            for k in doomed:
                del self._data[k]
            return len(doomed)

    def info(self) -> CacheInfo:
        with MEMO_LOCK:
            return CacheInfo(self._hits, self._misses, self._maxsize,
                             len(self._data))

    # -- warm-restart snapshot support ---------------------------------------
    def items(self) -> List[Tuple]:
        """Snapshot of (key, value) pairs, oldest first (LRU order)."""
        with MEMO_LOCK:
            return list(self._data.items())

    def load(self, key, value) -> None:
        """Populate without touching hit/miss counters (snapshot restore)."""
        with MEMO_LOCK:
            self._data[key] = value
            if self._maxsize is not None and len(self._data) > self._maxsize:
                self._data.popitem(last=False)


# ---------------------------------------------------------------------------
# Warm-restart snapshots: persist/restore the snapshot-enabled caches
# ---------------------------------------------------------------------------
#: bump when the snapshot container format itself changes
SNAPSHOT_SCHEMA = 1

#: side-band state captured with a snapshot and rebuilt on restore:
#: name -> (capture_fn() -> picklable, restore_fn(picklable) -> context).
#: The canonical hook is devicecost's lazily-interned model-id table —
#: restore_fn re-interns every recorded name and returns the old-id ->
#: new-id remap that the restore transforms index with.
SNAPSHOT_ENV: Dict[str, Tuple[Callable, Callable]] = {}

#: per-cache value rewrites applied on restore:
#: cache name -> fn(value, env) -> value (env: restored SNAPSHOT_ENV contexts)
RESTORE_TRANSFORMS: Dict[str, Callable] = {}

#: per-cache value rewrites applied at capture time:
#: cache name -> fn(value) -> picklable value.  Cache owners use these to
#: strip live-only state (device-resident array caches and other
#: ``__dict__`` memos) before the value hits the pickle.
CAPTURE_TRANSFORMS: Dict[str, Callable] = {}


def register_snapshot_env(name: str, capture_fn: Callable,
                          restore_fn: Callable) -> None:
    SNAPSHOT_ENV[name] = (capture_fn, restore_fn)


def register_restore_transform(name: str, fn: Callable) -> None:
    RESTORE_TRANSFORMS[name] = fn


def register_capture_transform(name: str, fn: Callable) -> None:
    CAPTURE_TRANSFORMS[name] = fn


#: source files whose drift invalidates a snapshot — every module that
#: defines a snapshotted cache's key or value types, or the model-id
#: interning the values index into
_FINGERPRINT_MODULES = (
    "repro.core.access", "repro.core.batchcost", "repro.core.devicecost",
    "repro.core.elements", "repro.core.memo", "repro.core.primitives",
    "repro.core.synthesis", "repro.core.templatecost",
)


def snapshot_version() -> str:
    """``"<schema>:<source fingerprint>"`` — the compatibility key.

    The fingerprint hashes the source of every module that shapes
    snapshot keys/values, so a code change that could make pickled
    entries wrong (not merely suboptimal) turns restore into a no-op
    cold start instead of a silent corruption."""
    digest = hashlib.sha256()
    for modname in _FINGERPRINT_MODULES:
        try:
            mod = importlib.import_module(modname)
            with open(mod.__file__, "rb") as fh:
                digest.update(fh.read())
        except Exception:
            digest.update(f"missing:{modname}".encode())
    return f"{SNAPSHOT_SCHEMA}:{digest.hexdigest()[:16]}"


def snapshot_caches(path: str) -> int:
    """Persist every snapshot-enabled cache to ``path`` (atomically).

    Returns the number of entries written.  The write goes through a
    sibling temp file + ``os.replace`` so a crash mid-dump never leaves
    a truncated snapshot where a good one stood."""
    with MEMO_LOCK:
        caches = {}
        for name, cache in REGISTRY.items():
            if not cache.snapshot:
                continue
            strip = CAPTURE_TRANSFORMS.get(name)
            items = cache.items()
            if strip is not None:
                items = [(key, strip(value)) for key, value in items]
            caches[name] = items
        env = {name: capture() for name, (capture, _) in
               SNAPSHOT_ENV.items()}
    payload = {"version": snapshot_version(), "env": env, "caches": caches}
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as fh:
            fh.write(blob)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return sum(len(items) for items in caches.values())


#: a restore attempt's entry count plus *why* it went the way it did:
#: ``restored`` / ``empty`` (valid snapshot, nothing to load) succeed;
#: ``missing`` / ``corrupt`` (unreadable or failed env rebuild) /
#: ``stale`` (version mismatch) / ``error`` (torn mid-restore, caches
#: cleared) all cold-start with 0 entries
RestoreReport = collections.namedtuple("RestoreReport", "entries outcome")


def restore_caches_report(path: str) -> RestoreReport:
    """Load a snapshot into the registered caches; never raises.

    Missing file, truncated pickle, schema/fingerprint mismatch, or a
    value that no longer remaps — every failure path quietly cold-starts
    with 0 entries, but the :class:`RestoreReport` outcome says *which*
    failure it was, so the serving tier can count and log discarded
    snapshots instead of silently eating them (a service ``start()``
    must still never die on a bad snapshot).  Partially-restored caches
    are cleared before an ``error`` return so a torn restore cannot
    leave inconsistent warm state."""
    from repro.testing import faults    # no cycle: faults is stdlib+numpy
    try:
        faults.check("memo.restore")
        with open(path, "rb") as fh:
            payload = pickle.load(fh)
    except FileNotFoundError:
        return RestoreReport(0, "missing")
    except Exception:
        return RestoreReport(0, "corrupt")
    try:
        if payload.get("version") != snapshot_version():
            return RestoreReport(0, "stale")
        env = {}
        for name, data in payload.get("env", {}).items():
            if name in SNAPSHOT_ENV:
                env[name] = SNAPSHOT_ENV[name][1](data)
    except Exception:
        return RestoreReport(0, "corrupt")
    restored = 0
    touched: List[DictCache] = []
    try:
        with MEMO_LOCK:
            for name, items in payload.get("caches", {}).items():
                cache = REGISTRY.get(name)
                if cache is None or not cache.snapshot:
                    continue
                transform = RESTORE_TRANSFORMS.get(name)
                touched.append(cache)
                for key, value in items:
                    if transform is not None:
                        value = transform(value, env)
                    cache.load(key, value)
                    restored += 1
        return RestoreReport(restored, "restored" if restored else "empty")
    except Exception:
        with MEMO_LOCK:       # a torn restore must not leave partial state
            for cache in touched:
                cache.clear()
        return RestoreReport(0, "error")


def restore_caches(path: str) -> int:
    """:func:`restore_caches_report` for callers that only want the
    entry count (0 on any failure, preserving the pre-report contract)."""
    return restore_caches_report(path).entries
