"""The Data Calculator core (paper's primary contribution) in JAX.

Layout primitives + elements describe the design space (§2); access
primitives with learned cost models synthesize operation latencies (§3);
what-if and auto-completion search the space (§4).  ``distcalc`` applies
the same paradigm to the distributed (TPU multi-pod) layout space.
"""
from repro.core import access, design_space, elements, hardware, models
from repro.core import primitives, structures, synthesis, training
from repro.core.elements import ALL_PAPER_SPECS, DataStructureSpec, Element
from repro.core.hardware import HardwareProfile, TPU_V5E
from repro.core.synthesis import (CostBreakdown, Workload, cost,
                                  cost_workload, instantiate,
                                  synthesize_operation)
