"""Template-vectorized cost synthesis: pack whole frontiers without
per-design Python — and, since PR 5, without per-*workload* re-derivation.

PR 1/2 vectorized frontier *scoring* (one grouped predict per model, then
one fused jitted call) and PR 3 vectorized frontier *construction* (chains
group by structural template and synthesize as batched numpy column ops).
What remained workload-keyed was the template machinery itself: every
point of a read/write-ratio or skew sweep re-derived the same chains'
geometry, because the per-chain cache key was (chain, workload).

This module now splits a packed segment into two orthogonal parts:

* **Template statics** (:func:`chain_statics`, memoized on
  ``(chain, depth signature)`` — *no workload anywhere in the key*): the
  per-element resolution (:class:`ElementStatics`), the expanded level
  structure (node counts are pure fanout products once the expansion
  depths are fixed), internal node bytes and cache regions, the
  structural template, and — via the ``segment_statics`` interning cache
  keyed ``(template, ops)`` — each segment's record model-ids and layout.
  The *depth signature* (:func:`_expansion_depths`) is the tuple of
  expanded level counts; it is derived from ``workload.n_entries`` by a
  trivial integer loop, but the expensive statics are keyed on the
  signature itself, so every workload that lands on the same structure
  shares one entry.
* **Workload geometry columns** (:func:`_build_workload_cols`): the
  workload-dependent numerics — entries per node, terminal node counts /
  regions, zipf/skew weights, record sizes/counts — evaluated as batched
  column ops over a **workload axis**: one ``[n_workloads, records]``
  numpy expression per emission class covers every (chain, workload)
  cell of a sweep.

The pipeline is then:

1. **Geometry**: resolve statics per chain (cache hit in steady state),
   build one flat SoA level table for all chains (:func:`_build_tables`),
   and evaluate the workload columns for all sweep points at once.
2. **Flat emission** (:func:`emit_operation`): every operation's records
   are emitted as ``[W, records]`` column ops over *emission-class
   masks*.  Records a chain's scalar synthesis would *not* emit (e.g.
   linked-list page hops when one page is visited) carry count 0.
3. **Assembly** (:func:`pack_points`): one argsort orders records by
   (chain, op, level, slot) — the order key is structural, so a single
   argsort serves every workload — and a vectorized scatter pads each
   design's block to a ``devicecost.TILE`` multiple.  The per-chain
   model-id arrays are interned on ``(template, ops)``: all workloads
   (and all chains sharing a template) reference the *same* ids array.

:func:`pack_specs` is the single-workload wrapper
(``pack_points(chains, one point)``), keeping the PR-3 API for
:mod:`repro.core.batchcost` and the record-parity tests.

The scalar path in :mod:`repro.core.synthesis` stays the 1e-9 oracle:
``tests/test_templatecost.py`` asserts record-level parity (identical
model-id sequences, sizes/counts to float tolerance) for every paper
spec, workload and operation, and ``tests/test_sweep.py`` asserts every
(design, workload) cell of a sweep against the same oracle.

Hardware never enters any key or value here — packing a frontier once
serves every what-if-hardware question unchanged.  Workload never enters
a *statics* key — sweeping workloads re-derives only the numeric
columns.  Both invariants are asserted by ``tests/test_cache_keys.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import access
from repro.core.devicecost import TILE, model_id
from repro.core.elements import Element
from repro.core import memo
from repro.core.memo import MEMO_LOCK, DictCache
from repro.core.synthesis import (CLS_APPEND, CLS_DEP, CLS_DEP_BLOOM,
                                  CLS_IND, CLS_IND_FUNC, CLS_LL, CLS_SKIP,
                                  FENCE_BYTES, PTR_BYTES, Workload,
                                  _node_bytes, element_class,
                                  skew_multipliers, symbolic_breakdown)

#: slots reserved per level in the intra-chain record order key
_SLOTS = 16
#: order-key stride per operation of the mix
_OP_STRIDE = 1 << 12


@functools.lru_cache(maxsize=64)
def _mid(level1: str, layout: str = "columnar", op: str = "equal") -> int:
    """Interned Level-2 model id of a resolved Level-1 call (lazy, so the
    global interning order stays exactly what the scalar path produces)."""
    return model_id(access.resolve(level1, layout=layout, op=op))


@dataclasses.dataclass(frozen=True)
class ElementStatics:
    """Everything synthesis ever reads from one element, resolved once.

    Purely structural — no workload, no hardware.  ``node_bytes`` is
    workload-independent (``synthesis._node_bytes`` never reads its
    workload argument; the record-parity tests run the same statics
    against several workloads and would catch a drift).
    """

    terminal: bool
    unlimited: bool
    fanout: Optional[int]          # fixed fanout value (None otherwise)
    capacity: Optional[int]        # terminal capacity (None otherwise)
    recursive: bool
    max_depth: int
    node_bytes: float              # internal node bytes (unlimited: header)
    bfs: bool                      # BFS / BFS-layer cache-region adjustment
    cls: int                       # emission class (see synthesis.CLS_*)
    fences: float                  # max(fanout - 1, 1) for data-dep search
    bloom_bits: float              # 0.0 when bloom_filters is off
    sorted_keys: bool
    layout: str                    # key_value_layout tag
    value_fetch: bool              # non-row-wise leaf refetches values
    area_links: bool               # leaf-to-leaf links (range sweeps)


def _compute_statics(e: Element) -> ElementStatics:
    unlimited = e.tag("fanout") == "unlimited"
    fanout = e.fanout
    rec_arg = e.get("recursion")
    max_depth = rec_arg[1] if isinstance(rec_arg, tuple) and \
        isinstance(rec_arg[1], int) else 64
    bf = e.get("bloom_filters")
    bloom_bits = float(bf[2]) if isinstance(bf, tuple) and bf[0] == "on" \
        else 0.0
    layout = e.tag("key_value_layout")
    if e.terminal or unlimited:
        node_bytes = 2.0 * PTR_BYTES   # terminal unused; LL page header
    else:
        # _node_bytes is workload-independent (asserted by parity tests)
        node_bytes = _node_bytes(e, fanout or 2, None)
    return ElementStatics(
        terminal=e.terminal, unlimited=unlimited, fanout=fanout,
        capacity=e.capacity, recursive=e.tag("recursion") == "yes",
        max_depth=max_depth, node_bytes=node_bytes,
        bfs=e.tag("sub_block_physical_layout") in ("BFS", "BFS-layer"),
        cls=element_class(e), fences=float(max((fanout or 2) - 1, 1)),
        bloom_bits=bloom_bits, sorted_keys=e.sorted_keys, layout=layout,
        value_fetch=layout != "row-wise" and e.retains_values,
        area_links=e.tag("area_links") != "none")


#: equal elements share one statics record; instances additionally pin it
#: on ``Element._tc_statics`` so the geometry pass pays one attribute read
_STATICS_BY_VALUE: Dict[Tuple, ElementStatics] = {}


def statics_of(e: Element) -> ElementStatics:
    st = e._tc_statics
    if st is None:
        # under the shared memo lock so a concurrent clear_template_caches
        # cannot interleave with the by-value insert (duplicate statics
        # would be benign, a torn OrderedDict/counter state would not be)
        with MEMO_LOCK:
            st = _STATICS_BY_VALUE.get(e.values)
            if st is None:
                st = _compute_statics(e)
                _STATICS_BY_VALUE[e.values] = st
        object.__setattr__(e, "_tc_statics", st)
    return st


# ---------------------------------------------------------------------------
# Template statics — the workload-free half of a chain's geometry
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=65536)
def _expansion_depths(chain: Tuple[Element, ...], n_entries: int
                      ) -> Tuple[int, ...]:
    """Per-element expanded level counts — the chain's *depth signature*.

    The only thing ``n_entries`` decides about a chain's structure is how
    many levels each recursive element expands to; everything else (node
    counts, bytes, regions) follows from the signature alone.  This is a
    trivial integer loop; the expensive statics are keyed on the
    signature, so every workload landing on the same structure shares
    one :class:`ChainStatics`.
    """
    term_st = statics_of(chain[-1])
    n = max(n_entries, 1)
    capacity = term_st.capacity or 256
    n_leaves = max(math.ceil(n / capacity), 1)
    depths: List[int] = []
    blocks = 1
    for element in chain[:-1]:
        st = statics_of(element)
        if st.fanout is None and st.unlimited:
            depths.append(1)
            continue
        fanout = st.fanout or 2
        d = 1
        if st.recursive:
            while blocks * fanout < n_leaves and d < st.max_depth:
                blocks *= fanout
                d += 1
        blocks *= fanout
        depths.append(d)
    return tuple(depths)


@dataclasses.dataclass
class ChainStatics:
    """One chain's workload-free structure, flattened to tuples.

    Everything here follows from (chain, depth signature): the expanded
    level stats, node counts (pure fanout products), node bytes, internal
    cache regions, and the structural ``template`` grouping chains whose
    record layout is identical up to numeric values.  Shared via the
    ``chain_statics`` memo; treat instances as immutable.
    """

    stats: Tuple[ElementStatics, ...]   # per expanded internal level
    n_nodes: Tuple[float, ...]
    node_bytes: Tuple[float, ...]
    region: Tuple[float, ...]           # path-so-far cache region (internal)
    term: ElementStatics
    blocks_final: float                 # block count after the division loop
    use_blocks: bool                    # terminal count sees blocks_final
    termcap: int                        # terminal capacity or 256
    cum_int_bytes: float                # total internal-level bytes
    template: Tuple
    depths: Tuple[int, ...]

    @property
    def n_internal(self) -> int:
        return len(self.stats)


#: (chain, depth signature) -> ChainStatics — workload never in the key;
#: snapshot-enabled (pure structural values, no model ids to remap)
_CHAIN_STATICS = DictCache(maxsize=65536, name="chain_statics",
                           snapshot=True)


def _compute_chain_statics(chain: Tuple[Element, ...],
                           depths: Tuple[int, ...]) -> ChainStatics:
    term_st = statics_of(chain[-1])
    stats: List[ElementStatics] = []
    nodes: List[float] = []
    nbytes: List[float] = []
    blocks = 1
    for element, d in zip(chain[:-1], depths):
        st = statics_of(element)
        if st.fanout is None and st.unlimited:
            stats.append(st)
            nodes.append(float(blocks))
            nbytes.append(PTR_BYTES * 2.0)
            continue
        fanout = st.fanout or 2
        for _ in range(d):
            stats.append(st)
            nodes.append(float(blocks))
            nbytes.append(st.node_bytes)
            blocks *= fanout
    region: List[float] = []
    cumulative = 0.0
    for st, nn, nb in zip(stats, nodes, nbytes):
        cumulative += nn * nb
        r = cumulative
        if st.bfs:
            group = (st.fanout or 2) * nb
            r = min(cumulative, max(group, nb))
        region.append(r)
    template = (tuple(st.cls for st in stats),
                (term_st.sorted_keys, term_st.bloom_bits > 0.0,
                 term_st.layout, term_st.value_fetch, term_st.area_links))
    return ChainStatics(
        stats=tuple(stats), n_nodes=tuple(nodes), node_bytes=tuple(nbytes),
        region=tuple(region), term=term_st, blocks_final=float(blocks),
        use_blocks=len(chain) > 1 and not statics_of(chain[-2]).unlimited,
        termcap=term_st.capacity or 256, cum_int_bytes=cumulative,
        template=template, depths=depths)


def chain_statics(chain: Tuple[Element, ...], n_entries: int
                  ) -> ChainStatics:
    """The workload-free template statics of a chain.

    ``n_entries`` only selects the depth signature; the memo key is
    (chain, signature) — every workload that lands on the same structure
    is one cache entry (the PR-5 cache-key invariant)."""
    depths = _expansion_depths(chain, n_entries)
    key = (chain, depths)
    st = _CHAIN_STATICS.get(key)
    if st is None:
        st = _compute_chain_statics(chain, depths)
        _CHAIN_STATICS.put(key, st)
    return st


# ---------------------------------------------------------------------------
# Per-chain geometry — statics + one workload's numerics (inspection API)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChainGeometry:
    """One chain's instantiated level structure under one workload.

    The statics half is shared via :func:`chain_statics`; only the
    workload numerics (entries per node, terminal counts/regions) are
    computed here.  ``template`` is the structural fingerprint grouping
    chains whose record layout is identical up to numeric values — the
    argument :func:`repro.core.synthesis.symbolic_breakdown` takes.

    Not ``frozen=True`` — instances are shared via the ``chain_geometry``
    memo and must be treated as immutable, but the frozen dataclass
    ``__setattr__`` init path costs more than the whole geometry
    computation at search-frontier scale.
    """

    stats: Tuple[ElementStatics, ...]   # per expanded internal level
    n_nodes: Tuple[float, ...]
    node_bytes: Tuple[float, ...]
    epn: Tuple[float, ...]              # entries routed per node
    region: Tuple[float, ...]           # path-so-far cache region
    term: ElementStatics
    t_n_nodes: float
    t_epn: float
    t_region: float
    total_bytes: float
    n: float                            # max(n_entries, 1)
    n_raw: float                        # workload.n_entries as-is
    termcap: int                        # terminal capacity or 256
    template: Tuple

    @property
    def n_internal(self) -> int:
        return len(self.stats)


@functools.lru_cache(maxsize=65536)
def chain_geometry(chain: Tuple[Element, ...], workload: Workload
                   ) -> ChainGeometry:
    """One chain's geometry under one workload — mirrors
    ``synthesis._instantiate_levels`` value for value (same int/float op
    sequence, asserted by the record-parity tests).  The structure comes
    from the workload-free :func:`chain_statics`; only the numeric
    columns are workload-keyed."""
    st = chain_statics(chain, workload.n_entries)
    n = max(workload.n_entries, 1)
    capacity = st.termcap
    n_leaves = max(math.ceil(n / capacity), 1)
    entries = float(n)
    epn = tuple(entries / nn for nn in st.n_nodes)
    if st.use_blocks:
        n_term = max(n_leaves, int(st.blocks_final))
    else:
        n_term = n_leaves
    term_bytes = min(capacity, n / max(n_term, 1)) * workload.pair_bytes
    term_bytes = max(term_bytes, float(workload.pair_bytes))
    cumulative = st.cum_int_bytes + n_term * term_bytes
    t_region = cumulative
    if st.term.bfs:
        group = (st.term.fanout or 2) * term_bytes
        t_region = min(cumulative, max(group, term_bytes))
    return ChainGeometry(
        stats=st.stats, n_nodes=st.n_nodes, node_bytes=st.node_bytes,
        epn=epn, region=st.region, term=st.term,
        t_n_nodes=float(int(n_term)), t_epn=entries / max(n_term, 1),
        t_region=t_region, total_bytes=cumulative, n=entries,
        n_raw=float(workload.n_entries), termcap=capacity,
        template=st.template)


def clear_template_caches() -> None:
    with MEMO_LOCK:
        chain_geometry.cache_clear()
        _expansion_depths.cache_clear()
        _CHAIN_STATICS.clear()
        _SEGMENT_IDS.clear()
        _STATICS_BY_VALUE.clear()


def cache_info() -> Dict[str, Tuple]:
    return {"chain_geometry": chain_geometry.cache_info(),
            "chain_statics": _CHAIN_STATICS.info(),
            "segment_statics": _SEGMENT_IDS.info()}


def segment_ranges(tile_segments: np.ndarray, n_segments: int,
                   n_parts: int) -> Tuple[np.ndarray, np.ndarray]:
    """Contiguous per-shard segment ranges over a packed record layout.

    Returns ``(seg_cuts, tile_cuts)``, each of length ``n_parts + 1``:
    ``seg_cuts`` splits ``[0, n_segments)`` into ~equal contiguous
    ranges (round-balanced) and ``tile_cuts`` maps each cut onto the
    sorted per-tile segment ids, so shard ``d`` owns tiles
    ``tile_cuts[d]:tile_cuts[d+1]`` — records ``* TILE``.  Design blocks
    are tile-aligned by construction, so tile cuts never split a design;
    every segment's records land wholly in one shard, which is what
    keeps sharded totals bit-identical to the flat reduction.  Shared by
    ``devicecost._score_sharded`` (pmap shards), ``PackedFrontier.split``
    (the serving shard pool's partitions) and per-shard packing."""
    seg_cuts = np.asarray([round(n_segments * d / n_parts)
                           for d in range(n_parts + 1)])
    tile_cuts = np.searchsorted(tile_segments, seg_cuts, side="left")
    return seg_cuts, tile_cuts


# ---------------------------------------------------------------------------
# Flat SoA tables over all chains being packed (structural half)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Tables:
    """Workload-free columns: the (chain, mix) half of every segment."""

    # internal-level table, one row per expanded internal level
    ch: np.ndarray          # owning chain index
    lvl: np.ndarray         # level position within the chain
    cls: np.ndarray
    fanout: np.ndarray
    n_nodes: np.ndarray
    node_bytes: np.ndarray
    region: np.ndarray      # internal cache regions (structural)
    fences: np.ndarray
    bloom_bits: np.ndarray
    termcap: np.ndarray     # owning chain's terminal capacity
    # terminal table, one row per chain
    c_n_int: np.ndarray     # internal level count (terminal order base)
    c_t_sorted: np.ndarray
    c_t_value_fetch: np.ndarray
    c_t_area: np.ndarray
    c_t_bloom: np.ndarray
    c_mid_search: np.ndarray   # layout-resolved sorted-search model id
    c_mid_scan: np.ndarray     # layout-resolved equal-scan model id
    c_mid_rscan: np.ndarray    # layout-resolved range-scan model id
    c_termcap: np.ndarray
    c_blocks_final: np.ndarray
    c_use_blocks: np.ndarray
    c_cum_int_bytes: np.ndarray
    c_term_bfs: np.ndarray
    c_term_fanout: np.ndarray


def _build_tables(statics_list: Sequence[ChainStatics]) -> _Tables:
    i_rows: List[Tuple] = []
    c_rows: List[Tuple] = []
    for c, g in enumerate(statics_list):
        for j, st in enumerate(g.stats):
            i_rows.append((c, j, st.cls, float(st.fanout or 0),
                           g.n_nodes[j], g.node_bytes[j], g.region[j],
                           st.fences, st.bloom_bits, float(g.termcap)))
        t = g.term
        c_rows.append((g.n_internal, t.sorted_keys, t.value_fetch,
                       t.area_links, t.bloom_bits,
                       _mid(access.SORTED_SEARCH, t.layout),
                       _mid(access.SCAN, t.layout),
                       _mid(access.SCAN, t.layout, "range"),
                       float(g.termcap), g.blocks_final, g.use_blocks,
                       g.cum_int_bytes, t.bfs, float(t.fanout or 2)))
    icols = list(zip(*i_rows)) if i_rows else [[] for _ in range(10)]
    ccols = list(zip(*c_rows))
    f8, i8 = np.float64, np.int64
    return _Tables(
        ch=np.asarray(icols[0], i8), lvl=np.asarray(icols[1], i8),
        cls=np.asarray(icols[2], i8), fanout=np.asarray(icols[3], f8),
        n_nodes=np.asarray(icols[4], f8),
        node_bytes=np.asarray(icols[5], f8),
        region=np.asarray(icols[6], f8), fences=np.asarray(icols[7], f8),
        bloom_bits=np.asarray(icols[8], f8),
        termcap=np.asarray(icols[9], f8),
        c_n_int=np.asarray(ccols[0], i8),
        c_t_sorted=np.asarray(ccols[1], bool),
        c_t_value_fetch=np.asarray(ccols[2], bool),
        c_t_area=np.asarray(ccols[3], bool),
        c_t_bloom=np.asarray(ccols[4], f8),
        c_mid_search=np.asarray(ccols[5], np.int32),
        c_mid_scan=np.asarray(ccols[6], np.int32),
        c_mid_rscan=np.asarray(ccols[7], np.int32),
        c_termcap=np.asarray(ccols[8], f8),
        c_blocks_final=np.asarray(ccols[9], f8),
        c_use_blocks=np.asarray(ccols[10], bool),
        c_cum_int_bytes=np.asarray(ccols[11], f8),
        c_term_bfs=np.asarray(ccols[12], bool),
        c_term_fanout=np.asarray(ccols[13], f8))


# ---------------------------------------------------------------------------
# Workload geometry columns — the numeric half, batched over a workload axis
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _WorkloadCols:
    """Per-workload numerics for one table set, shape ``[W, ...]``.

    Every column is one broadcast expression over the structural tables —
    the batched twin of the scalar block-division epilogue in
    :func:`chain_geometry`, evaluated for all sweep points at once."""

    workloads: Tuple[Workload, ...]
    key_bytes: np.ndarray      # [W]
    value_bytes: np.ndarray    # [W]
    pair_bytes: np.ndarray     # [W]
    selectivity: np.ndarray    # [W]
    n_raw: np.ndarray          # [W]
    epn: np.ndarray            # [W, L] entries per node, internal rows
    t_region_rows: np.ndarray  # [W, L] owning chain's terminal region
    t_n_nodes_rows: np.ndarray  # [W, L] owning chain's terminal node count
    c_t_n_nodes: np.ndarray    # [W, C]
    c_t_epn: np.ndarray        # [W, C]
    c_t_region: np.ndarray     # [W, C]
    c_total_bytes: np.ndarray  # [W, C]

    def mult_static(self, n_nodes: np.ndarray) -> np.ndarray:
        """Skew multipliers for structural node counts, one row per
        workload (zipf masses come from the shared synthesis memo)."""
        return np.stack([skew_multipliers(n_nodes, w)
                         for w in self.workloads])

    def mult_rows(self, n_nodes: np.ndarray) -> np.ndarray:
        """Skew multipliers for per-workload node counts ``[W, n]``."""
        return np.stack([skew_multipliers(n_nodes[i], w)
                         for i, w in enumerate(self.workloads)])


def _build_workload_cols(t: _Tables, workloads: Sequence[Workload]
                         ) -> _WorkloadCols:
    f8 = np.float64
    w_count = len(workloads)
    n = np.asarray([float(max(w.n_entries, 1)) for w in workloads], f8)
    pair = np.asarray([float(w.pair_bytes) for w in workloads], f8)
    n_col, pair_col = n[:, None], pair[:, None]
    n_leaves = np.maximum(np.ceil(n_col / t.c_termcap), 1.0)
    n_term = np.where(t.c_use_blocks,
                      np.maximum(n_leaves, t.c_blocks_final), n_leaves)
    safe_term = np.maximum(n_term, 1.0)
    term_bytes = np.maximum(
        np.minimum(t.c_termcap, n_col / safe_term) * pair_col, pair_col)
    cumulative = t.c_cum_int_bytes + n_term * term_bytes
    group = np.maximum(t.c_term_fanout * term_bytes, term_bytes)
    c_t_region = np.where(t.c_term_bfs,
                          np.minimum(cumulative, group), cumulative)
    if len(t.n_nodes):
        epn = n_col / t.n_nodes[None, :]
        t_region_rows = c_t_region[:, t.ch]
        t_n_nodes_rows = n_term[:, t.ch]
    else:
        epn = np.zeros((w_count, 0), f8)
        t_region_rows = np.zeros((w_count, 0), f8)
        t_n_nodes_rows = np.zeros((w_count, 0), f8)
    return _WorkloadCols(
        workloads=tuple(workloads),
        key_bytes=np.asarray([float(w.key_bytes) for w in workloads], f8),
        value_bytes=np.asarray([float(w.value_bytes) for w in workloads],
                               f8),
        pair_bytes=pair,
        selectivity=np.asarray([float(w.selectivity) for w in workloads],
                               f8),
        n_raw=np.asarray([float(w.n_entries) for w in workloads], f8),
        epn=epn, t_region_rows=t_region_rows,
        t_n_nodes_rows=t_n_nodes_rows, c_t_n_nodes=n_term,
        c_t_epn=n_col / safe_term, c_t_region=c_t_region,
        c_total_bytes=cumulative)


# ---------------------------------------------------------------------------
# Vectorized record emission (one numpy expression per class x slot,
# broadcast over the workload axis)
# ---------------------------------------------------------------------------
class _Rows:
    """Accumulates record columns: (chain, order, model id) are structural
    1-D arrays; sizes and counts carry the ``[W, n]`` workload axis."""

    def __init__(self, n_workloads: int) -> None:
        self.W = n_workloads
        self.parts: List[Tuple[np.ndarray, ...]] = []

    def emit(self, ch, order, mid, size, count=None) -> None:
        ch = np.asarray(ch, np.int64)
        n = len(ch)
        if n == 0:
            return
        if np.isscalar(mid):
            mid = np.full(n, mid, np.int32)
        size = np.asarray(size, np.float64)
        if size.ndim == 1:          # workload-independent sizes broadcast
            size = np.broadcast_to(size, (self.W, n))
        if count is None:
            count = np.ones((self.W, n))
        else:
            count = np.asarray(count, np.float64)
            if count.ndim == 1:
                count = np.broadcast_to(count, (self.W, n))
        self.parts.append((ch, np.asarray(order, np.int64),
                           np.asarray(mid, np.int32), size, count))

    def collect(self) -> Tuple[np.ndarray, ...]:
        if not self.parts:
            z = np.zeros(0)
            return (z.astype(np.int64), z.astype(np.int64),
                    z.astype(np.int32), np.zeros((self.W, 0)),
                    np.zeros((self.W, 0)))
        return (np.concatenate([p[0] for p in self.parts]),
                np.concatenate([p[1] for p in self.parts]),
                np.concatenate([p[2] for p in self.parts]),
                np.concatenate([p[3] for p in self.parts], axis=1),
                np.concatenate([p[4] for p in self.parts], axis=1))


def _emit_get(t: _Tables, wc: _WorkloadCols, rows: _Rows) -> None:
    kb = wc.key_bytes[:, None]
    # -- internal levels ----------------------------------------------------
    m = t.cls >= CLS_IND_FUNC                 # every class with its own P
    mult = wc.mult_static(t.n_nodes[m])
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS,
              _mid(access.RANDOM_ACCESS),
              np.maximum(t.region[m][None] * mult, 1.0))
    m = t.cls == CLS_SKIP                     # skip list: fence search
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS, _mid(access.SORTED_SEARCH),
              np.maximum(np.maximum(wc.epn[:, m] / t.termcap[m][None],
                                    1.0) * FENCE_BYTES, 1.0))
    m = t.cls == CLS_LL                       # linked list: head + hops
    pages = np.maximum(wc.epn[:, m] / t.termcap[m][None], 1.0)
    visited = (pages + 1.0) / 2.0
    mult = wc.mult_rows(wc.t_n_nodes_rows[:, m])
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS, _mid(access.RANDOM_ACCESS),
              np.maximum(wc.t_region_rows[:, m] * mult, 1.0))
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1, _mid(access.RANDOM_ACCESS),
              wc.t_region_rows[:, m], np.maximum(visited - 1.0, 0.0))
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 2, _mid(access.SCAN),
              t.termcap[m][None] * kb, np.maximum(visited - 1.0, 0.0))
    m = t.cls == CLS_IND_FUNC                 # hash partitioning probe
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1, _mid(access.HASH_PROBE),
              np.maximum(t.n_nodes[m] * np.maximum(t.fanout[m], 1.0) *
                         PTR_BYTES, 1.0))
    m = (t.cls == CLS_DEP) | (t.cls == CLS_DEP_BLOOM)   # sorted fences
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1,
              _mid(access.SORTED_SEARCH, "row-wise"),
              np.maximum(t.fences[m] * FENCE_BYTES, 1.0))
    m = t.cls == CLS_DEP_BLOOM
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 2, _mid(access.BLOOM_PROBE),
              np.maximum(t.bloom_bits[m] / 8.0, 1.0))
    m = t.cls == CLS_APPEND                   # append partitioning scan
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1, _mid(access.SCAN),
              np.maximum(np.where(t.fanout[m] > 0, t.fanout[m], 2.0) *
                         FENCE_BYTES, 1.0))
    # -- terminal node ------------------------------------------------------
    ch = np.arange(len(t.c_n_int))
    base = t.c_n_int * _SLOTS
    entries = np.maximum(wc.c_t_epn, 1.0)
    mult = wc.mult_rows(wc.c_t_n_nodes)
    rows.emit(ch, base, _mid(access.RANDOM_ACCESS),
              np.maximum(wc.c_t_region * mult, 1.0))
    m = t.c_t_bloom > 0.0
    rows.emit(ch[m], base[m] + 1, _mid(access.BLOOM_PROBE),
              np.maximum(t.c_t_bloom[m] / 8.0, 1.0))
    m = t.c_t_sorted
    rows.emit(ch[m], base[m] + 2, t.c_mid_search[m],
              np.maximum(entries[:, m] * kb, 1.0))
    m = ~t.c_t_sorted
    rows.emit(ch[m], base[m] + 2, t.c_mid_scan[m],
              entries[:, m] * kb / 2.0)
    m = t.c_t_value_fetch
    rows.emit(ch[m], base[m] + 3, _mid(access.RANDOM_ACCESS),
              np.maximum(entries[:, m] * wc.value_bytes[:, None], 1.0))


def _emit_tail_range(t: _Tables, wc: _WorkloadCols, rows: _Rows) -> None:
    """Fig. 10 range sweep appended after the get descent."""
    ch = np.arange(len(t.c_n_int))
    base = (t.c_n_int + 1) * _SLOTS
    frac = np.maximum(wc.selectivity, 0.0)[:, None]
    n_pages = np.maximum(np.ceil(frac * wc.c_t_n_nodes), 1.0)
    hop = np.where(t.c_t_area[None, :] | (wc.c_t_n_nodes == 1.0),
                   wc.c_t_region, wc.c_total_bytes)
    rows.emit(ch, base, _mid(access.RANDOM_ACCESS), hop,
              np.maximum(n_pages - 1.0, 0.0))
    rows.emit(ch, base + 1, t.c_mid_rscan,
              np.maximum(wc.c_t_epn, 1.0) * wc.key_bytes[:, None],
              n_pages)


def _emit_bulk_load(t: _Tables, wc: _WorkloadCols, rows: _Rows) -> None:
    n_chains = len(t.c_n_int)
    ch = np.arange(n_chains)
    data_bytes = np.broadcast_to((wc.n_raw * wc.pair_bytes)[:, None],
                                 (rows.W, n_chains))
    nr = np.broadcast_to(wc.n_raw[:, None], (rows.W, n_chains))
    m = t.c_t_sorted
    rows.emit(ch[m], np.zeros(int(m.sum()), np.int64), _mid(access.SORT),
              np.maximum(nr[:, m], 1.0))
    rows.emit(ch[m], np.ones(int(m.sum()), np.int64),
              _mid(access.ORDERED_BATCH_WRITE),
              np.maximum(data_bytes[:, m], 1.0))
    m = ~t.c_t_sorted
    rows.emit(ch[m], np.zeros(int(m.sum()), np.int64),
              _mid(access.SERIAL_WRITE),
              np.maximum(data_bytes[:, m], 1.0))
    level_bytes = np.maximum(t.n_nodes * t.node_bytes, 1.0)
    base = (t.lvl + 1) * _SLOTS
    m = (t.cls == CLS_IND) | (t.cls == CLS_IND_FUNC)
    rows.emit(t.ch[m], base[m], _mid(access.SCAN),
              np.maximum(data_bytes[:, t.ch[m]], 1.0))
    rows.emit(t.ch[m], base[m] + 1, _mid(access.SCATTERED_BATCH_WRITE),
              np.maximum(level_bytes[m], 1.0))
    m = ~m
    rows.emit(t.ch[m], base[m], _mid(access.ORDERED_BATCH_WRITE),
              np.maximum(level_bytes[m], 1.0))


def emit_operation(op: str, t: _Tables, wc: _WorkloadCols
                   ) -> Tuple[np.ndarray, ...]:
    """Record columns (chain, order, model id, sizes ``[W, n]``, counts
    ``[W, n]``) of one operation over every chain and every workload in
    the tables — the vectorized twin of
    ``synthesis.synthesize_operation`` + ``batchcost.compile_breakdown``,
    with a workload axis."""
    rows = _Rows(len(wc.workloads))
    if op == "get":
        _emit_get(t, wc, rows)
    elif op == "range_get":
        _emit_get(t, wc, rows)
        _emit_tail_range(t, wc, rows)
    elif op == "update":
        _emit_get(t, wc, rows)
        ch = np.arange(len(t.c_n_int))
        rows.emit(ch, (t.c_n_int + 1) * _SLOTS, _mid(access.SERIAL_WRITE),
                  np.broadcast_to(np.maximum(wc.value_bytes, 1.0)[:, None],
                                  (rows.W, len(ch))))
    elif op == "bulk_load":
        _emit_bulk_load(t, wc, rows)
    else:
        raise KeyError(op)
    return rows.collect()


# ---------------------------------------------------------------------------
# Assembly: per-spec tile-padded segments, for every sweep point at once
# ---------------------------------------------------------------------------
#: (template, ops) -> interned per-chain model-id array — workload-free:
#: every workload of a sweep (and every chain sharing a template)
#: references the SAME ids array object
_SEGMENT_IDS = DictCache(maxsize=65536, name="segment_statics",
                         snapshot=True)


def _restore_segment_ids(value, env):
    """Remap a snapshotted interned per-chain model-id array onto the
    live interning table (warm-restart restore)."""
    ids = env["model_ids"][np.asarray(value, dtype=np.int64)]
    ids = np.ascontiguousarray(ids)
    ids.setflags(write=False)
    return ids


memo.register_restore_transform("segment_statics", _restore_segment_ids)


def _frozen(arr: np.ndarray) -> np.ndarray:
    """An owned, read-only copy of one segment column."""
    arr = arr.copy()
    arr.setflags(write=False)
    return arr


def _intern_segment_ids(template: Tuple, ops: Tuple[str, ...],
                        ids: np.ndarray) -> np.ndarray:
    key = (template, ops)
    cached = _SEGMENT_IDS.get(key)
    if cached is not None and len(cached) == len(ids):
        return cached
    _SEGMENT_IDS.put(key, ids)
    return ids


def _pack_group(chains: Sequence[Tuple[Element, ...]],
                points: Sequence[Tuple[Workload, Tuple]],
                ops: Tuple[str, ...], pidx: List[int],
                out: List[List]) -> None:
    """Pack one (op sequence, structural signature) group of sweep points:
    statics and the argsorted record layout are computed once; sizes and
    weights carry the group's workload axis."""
    n_chains = len(chains)
    workloads = [points[pi][0] for pi in pidx]
    statics_list = [chain_statics(c, workloads[0].n_entries)
                    for c in chains]
    t = _build_tables(statics_list)
    wc = _build_workload_cols(t, workloads)
    # op weights are per sweep point: a read/write-ratio sweep shares all
    # statics and numerics, only this [n_ops, W] table varies
    op_weights = np.asarray([[points[pi][1][pos][1] for pi in pidx]
                             for pos in range(len(ops))], np.float64)
    ch_parts, key_parts, mid_parts, size_parts, w_parts = [], [], [], [], []
    for pos, op in enumerate(ops):
        ch, order, mid, sizes, counts = emit_operation(op, t, wc)
        ch_parts.append(ch)
        key_parts.append(order + pos * _OP_STRIDE)
        mid_parts.append(mid)
        size_parts.append(sizes)
        w_parts.append(counts * op_weights[pos][:, None])
    ch = np.concatenate(ch_parts)
    key = ch * (_OP_STRIDE * len(ops)) + np.concatenate(key_parts)
    mids = np.concatenate(mid_parts)
    sizes = np.concatenate(size_parts, axis=1)
    weights = np.concatenate(w_parts, axis=1)

    # the order key is structural, so ONE argsort serves every workload
    idx = np.argsort(key, kind="stable")
    ch, mids = ch[idx], mids[idx]
    sizes, weights = sizes[:, idx], weights[:, idx]

    counts = np.bincount(ch, minlength=n_chains)
    # every chain must emit exactly its template's symbolic record schema
    # (the once-per-template breakdown synthesis.py declares); a mismatch
    # means the vectorized emission drifted from the expert system
    expected_by_template: Dict[Tuple, int] = {}
    for c, st in enumerate(statics_list):
        expected = expected_by_template.get(st.template)
        if expected is None:
            expected = sum(len(symbolic_breakdown(op, st.template))
                           for op in ops)
            expected_by_template[st.template] = expected
        if counts[c] != expected:
            raise AssertionError(
                f"template emission drift: chain {c} produced {counts[c]} "
                f"records, schema says {expected} (template {st.template})")
    padded = counts + (-counts % TILE)
    pad_off = np.concatenate([[0], np.cumsum(padded)])
    raw_off = np.concatenate([[0], np.cumsum(counts)])
    total = int(pad_off[-1])
    out_ids = np.empty(total, np.int32)
    out_sizes = np.ones((len(pidx), total), np.float64)
    out_weights = np.zeros((len(pidx), total), np.float64)
    # pad rows repeat the block's first real model id (see the pad-id note
    # in batchcost); fill per chain, then scatter the real rows over it
    out_ids[:] = np.repeat(mids[raw_off[:-1]], padded)
    pos_idx = np.arange(len(ch)) + np.repeat(pad_off[:-1] - raw_off[:-1],
                                             counts)
    out_ids[pos_idx] = mids
    out_sizes[:, pos_idx] = sizes
    out_weights[:, pos_idx] = weights
    # per-chain segments are COPIES, not views: cached segments outlive
    # this call (batchcost's segment cache), and a view would pin the
    # whole group's [W, total] buffers alive for as long as any one
    # small chain stays cached
    for c, st in enumerate(statics_list):
        sl = slice(int(pad_off[c]), int(pad_off[c + 1]))
        ids_c = _intern_segment_ids(st.template, ops,
                                    _frozen(out_ids[sl]))
        for wi, pi in enumerate(pidx):
            out[pi][c] = (ids_c, _frozen(out_sizes[wi, sl]),
                          _frozen(out_weights[wi, sl]))


def pack_points(chains: Sequence[Tuple[Element, ...]],
                points: Sequence[Tuple[Workload, Tuple]]
                ) -> List[List[Tuple[np.ndarray, np.ndarray, np.ndarray]]]:
    """Mix-weighted (ids, sizes, weights) per chain for EVERY sweep point.

    ``points`` is a sequence of ``(workload, mix_items)`` pairs; the
    result is indexed ``[point][chain]``.  Points sharing an op sequence
    and a joint structural signature (the common case: read/write-ratio,
    skew, selectivity or query-count sweeps over a fixed data size) are
    packed as ONE group — statics, emission layout and the argsort are
    computed once, and all numeric columns are evaluated with a workload
    axis.  Points whose ``n_entries`` changes a chain's expansion depths
    simply land in their own group.
    """
    n_chains = len(chains)
    points = tuple(points)
    out: List[List] = [[None] * n_chains for _ in points]
    if n_chains == 0 or not points:
        return out
    groups: Dict[Tuple, List[int]] = {}
    for pi, (workload, mix_items) in enumerate(points):
        ops = tuple(op for op, _ in mix_items)
        sig = tuple(_expansion_depths(chain, workload.n_entries)
                    for chain in chains)
        groups.setdefault((ops, sig), []).append(pi)
    for (ops, _), pidx in groups.items():
        _pack_group(chains, points, ops, pidx, out)
    return out


def pack_specs(chains: Sequence[Tuple[Element, ...]], workload: Workload,
               mix_items: Tuple[Tuple[str, float], ...]
               ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Single-workload wrapper over :func:`pack_points` — the vectorized
    equivalent of packing every chain through the scalar
    ``instantiate -> synthesize -> compile -> pad`` pipeline."""
    if not chains:
        return []
    return pack_points(chains, ((workload, mix_items),))[0]
