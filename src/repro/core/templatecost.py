"""Template-vectorized cost synthesis: pack whole frontiers without
per-design Python.

PR 1/2 vectorized frontier *scoring* (one grouped predict per model, then
one fused jitted call) but frontier *construction* still walked the scalar
expert system once per design: ``instantiate`` -> ``synthesize_*`` ->
``compile_breakdown`` -> pad, thousands of Python-level ``Element.tag``
lookups and dataclass allocations per candidate.  After PR 2 that pipeline
is the end-to-end search bottleneck (the Amdahl gap recorded in
``experiments/bench/BENCH_search.json``).

This module replaces the loop with a three-stage vectorized pipeline:

1. **Geometry pass** (:func:`chain_geometry`, memoized on
   (chain, workload)): a lean re-statement of
   ``synthesis._instantiate_levels`` — per-element statics (branch class,
   node bytes, emission flags) are resolved once per distinct
   :class:`~repro.core.elements.Element` and the block-division loop runs
   on plain ints/floats, no dataclass allocation.  The tuple of per-level
   :func:`~repro.core.synthesis.element_class` values plus the terminal's
   emission flags is the chain's **structural template**;
   :func:`repro.core.synthesis.symbolic_breakdown` emits each template's
   record schema once.
2. **Flat emission** (:func:`emit_operation`): all chains' levels
   concatenate into one SoA level table; every operation's records are
   emitted as batched numpy column ops over *emission-class masks* — one
   numpy expression covers every level of every chain sharing a class, so
   the per-record Python of the scalar path disappears entirely.  Records
   a chain's scalar synthesis would *not* emit (e.g. linked-list page hops
   when one page is visited) carry count 0 — they weigh nothing and keep
   the emission branch-free.
3. **Assembly** (:func:`pack_specs`): one argsort orders records by
   (chain, op, level, slot) — the exact scalar emission order — and a
   vectorized scatter pads each design's block to a ``devicecost.TILE``
   multiple, yielding the same per-spec (ids, sizes, weights) segments
   ``batchcost.pack_frontier`` used to build one design at a time.

The scalar path in :mod:`repro.core.synthesis` stays the 1e-9 oracle:
``tests/test_templatecost.py`` asserts record-level parity (identical
model-id sequences, sizes/counts to float tolerance) for every paper
spec, workload and operation, and checks the emitted layout against the
per-template symbolic breakdown.

Hardware never enters any key or value here — packing a frontier once
serves every what-if-hardware question unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import access
from repro.core.devicecost import TILE, model_id
from repro.core.elements import Element
from repro.core.memo import MEMO_LOCK
from repro.core.synthesis import (CLS_APPEND, CLS_DEP, CLS_DEP_BLOOM,
                                  CLS_IND, CLS_IND_FUNC, CLS_LL, CLS_SKIP,
                                  FENCE_BYTES, PTR_BYTES, Workload,
                                  _node_bytes, element_class,
                                  skew_multipliers, symbolic_breakdown)

#: slots reserved per level in the intra-chain record order key
_SLOTS = 16
#: order-key stride per operation of the mix
_OP_STRIDE = 1 << 12


@functools.lru_cache(maxsize=64)
def _mid(level1: str, layout: str = "columnar", op: str = "equal") -> int:
    """Interned Level-2 model id of a resolved Level-1 call (lazy, so the
    global interning order stays exactly what the scalar path produces)."""
    return model_id(access.resolve(level1, layout=layout, op=op))


@dataclasses.dataclass(frozen=True)
class ElementStatics:
    """Everything synthesis ever reads from one element, resolved once.

    Purely structural — no workload, no hardware.  ``node_bytes`` is
    workload-independent (``synthesis._node_bytes`` never reads its
    workload argument; the record-parity tests run the same statics
    against several workloads and would catch a drift).
    """

    terminal: bool
    unlimited: bool
    fanout: Optional[int]          # fixed fanout value (None otherwise)
    capacity: Optional[int]        # terminal capacity (None otherwise)
    recursive: bool
    max_depth: int
    node_bytes: float              # internal node bytes (unlimited: header)
    bfs: bool                      # BFS / BFS-layer cache-region adjustment
    cls: int                       # emission class (see synthesis.CLS_*)
    fences: float                  # max(fanout - 1, 1) for data-dep search
    bloom_bits: float              # 0.0 when bloom_filters is off
    sorted_keys: bool
    layout: str                    # key_value_layout tag
    value_fetch: bool              # non-row-wise leaf refetches values
    area_links: bool               # leaf-to-leaf links (range sweeps)


def _compute_statics(e: Element) -> ElementStatics:
    unlimited = e.tag("fanout") == "unlimited"
    fanout = e.fanout
    rec_arg = e.get("recursion")
    max_depth = rec_arg[1] if isinstance(rec_arg, tuple) and \
        isinstance(rec_arg[1], int) else 64
    bf = e.get("bloom_filters")
    bloom_bits = float(bf[2]) if isinstance(bf, tuple) and bf[0] == "on" \
        else 0.0
    layout = e.tag("key_value_layout")
    if e.terminal or unlimited:
        node_bytes = 2.0 * PTR_BYTES   # terminal unused; LL page header
    else:
        # _node_bytes is workload-independent (asserted by parity tests)
        node_bytes = _node_bytes(e, fanout or 2, None)
    return ElementStatics(
        terminal=e.terminal, unlimited=unlimited, fanout=fanout,
        capacity=e.capacity, recursive=e.tag("recursion") == "yes",
        max_depth=max_depth, node_bytes=node_bytes,
        bfs=e.tag("sub_block_physical_layout") in ("BFS", "BFS-layer"),
        cls=element_class(e), fences=float(max((fanout or 2) - 1, 1)),
        bloom_bits=bloom_bits, sorted_keys=e.sorted_keys, layout=layout,
        value_fetch=layout != "row-wise" and e.retains_values,
        area_links=e.tag("area_links") != "none")


#: equal elements share one statics record; instances additionally pin it
#: on ``Element._tc_statics`` so the geometry pass pays one attribute read
_STATICS_BY_VALUE: Dict[Tuple, ElementStatics] = {}


def statics_of(e: Element) -> ElementStatics:
    st = e._tc_statics
    if st is None:
        # under the shared memo lock so a concurrent clear_template_caches
        # cannot interleave with the by-value insert (duplicate statics
        # would be benign, a torn OrderedDict/counter state would not be)
        with MEMO_LOCK:
            st = _STATICS_BY_VALUE.get(e.values)
            if st is None:
                st = _compute_statics(e)
                _STATICS_BY_VALUE[e.values] = st
        object.__setattr__(e, "_tc_statics", st)
    return st


# ---------------------------------------------------------------------------
# Geometry pass — lean _instantiate_levels (the per-chain structure memo)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChainGeometry:
    """One chain's instantiated level structure, flattened to tuples.

    ``template`` is the structural fingerprint grouping chains whose
    record layout is identical up to numeric values — the argument
    :func:`repro.core.synthesis.symbolic_breakdown` takes.

    Not ``frozen=True`` — instances are shared via the ``chain_geometry``
    memo and must be treated as immutable, but the frozen dataclass
    ``__setattr__`` init path costs more than the whole geometry
    simulation at search-frontier scale (thousands of chains per call).
    """

    stats: Tuple[ElementStatics, ...]   # per expanded internal level
    n_nodes: Tuple[float, ...]
    node_bytes: Tuple[float, ...]
    epn: Tuple[float, ...]              # entries routed per node
    region: Tuple[float, ...]           # path-so-far cache region
    term: ElementStatics
    t_n_nodes: float
    t_epn: float
    t_region: float
    total_bytes: float
    n: float                            # max(n_entries, 1)
    n_raw: float                        # workload.n_entries as-is
    termcap: int                        # terminal capacity or 256
    template: Tuple

    @property
    def n_internal(self) -> int:
        return len(self.stats)


@functools.lru_cache(maxsize=65536)
def chain_geometry(chain: Tuple[Element, ...], workload: Workload
                   ) -> ChainGeometry:
    """Block-division simulation of one chain — mirrors
    ``synthesis._instantiate_levels`` value for value (same int/float op
    sequence, asserted by the record-parity tests), memoized on
    (chain, workload) with hardware nowhere in the key."""
    term_st = statics_of(chain[-1])
    n = max(workload.n_entries, 1)
    capacity = term_st.capacity or 256
    n_leaves = max(math.ceil(n / capacity), 1)

    stats: List[ElementStatics] = []
    nodes: List[float] = []
    nbytes: List[float] = []
    epn: List[float] = []
    blocks = 1
    entries = float(n)
    for element in chain[:-1]:
        st = statics_of(element)
        if st.fanout is None and st.unlimited:
            stats.append(st)
            nodes.append(float(blocks))
            nbytes.append(PTR_BYTES * 2.0)
            epn.append(entries / max(blocks, 1))
            continue
        fanout = st.fanout or 2
        if st.recursive:
            depth = 0
            while blocks * fanout < n_leaves and depth < st.max_depth - 1:
                stats.append(st)
                nodes.append(float(blocks))
                nbytes.append(st.node_bytes)
                epn.append(entries / blocks if blocks else entries)
                blocks *= fanout
                depth += 1
        stats.append(st)
        nodes.append(float(blocks))
        nbytes.append(st.node_bytes)
        epn.append(entries / blocks)
        blocks *= fanout

    if len(chain) > 1 and not statics_of(chain[-2]).unlimited:
        n_term = max(n_leaves, blocks)
    else:
        n_term = n_leaves
    term_bytes = min(capacity, n / max(n_term, 1)) * workload.pair_bytes
    term_bytes = max(term_bytes, float(workload.pair_bytes))

    region: List[float] = []
    cumulative = 0.0
    for st, nn, nb in zip(stats, nodes, nbytes):
        cumulative += nn * nb
        r = cumulative
        if st.bfs:
            group = (st.fanout or 2) * nb
            r = min(cumulative, max(group, nb))
        region.append(r)
    cumulative += n_term * term_bytes
    t_region = cumulative
    if term_st.bfs:
        group = (term_st.fanout or 2) * term_bytes
        t_region = min(cumulative, max(group, term_bytes))

    template = (tuple(st.cls for st in stats),
                (term_st.sorted_keys, term_st.bloom_bits > 0.0,
                 term_st.layout, term_st.value_fetch, term_st.area_links))
    return ChainGeometry(
        stats=tuple(stats), n_nodes=tuple(nodes), node_bytes=tuple(nbytes),
        epn=tuple(epn), region=tuple(region), term=term_st,
        t_n_nodes=float(int(n_term)), t_epn=entries / max(n_term, 1),
        t_region=t_region, total_bytes=cumulative, n=float(n),
        n_raw=float(workload.n_entries), termcap=capacity,
        template=template)


def clear_template_caches() -> None:
    with MEMO_LOCK:
        chain_geometry.cache_clear()
        _STATICS_BY_VALUE.clear()


def cache_info() -> Dict[str, Tuple]:
    return {"chain_geometry": chain_geometry.cache_info()}


# ---------------------------------------------------------------------------
# Flat SoA tables over all chains being packed
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Tables:
    # internal-level table, one row per expanded internal level
    ch: np.ndarray          # owning chain index
    lvl: np.ndarray         # level position within the chain
    cls: np.ndarray
    fanout: np.ndarray
    n_nodes: np.ndarray
    node_bytes: np.ndarray
    epn: np.ndarray
    region: np.ndarray
    fences: np.ndarray
    bloom_bits: np.ndarray
    termcap: np.ndarray     # owning chain's terminal capacity
    t_region: np.ndarray    # owning chain's terminal region
    t_n_nodes: np.ndarray   # owning chain's terminal node count
    # terminal table, one row per chain
    c_n_int: np.ndarray     # internal level count (terminal order base)
    c_t_n_nodes: np.ndarray
    c_t_epn: np.ndarray
    c_t_region: np.ndarray
    c_t_bloom: np.ndarray
    c_t_sorted: np.ndarray
    c_t_value_fetch: np.ndarray
    c_t_area: np.ndarray
    c_mid_search: np.ndarray   # layout-resolved sorted-search model id
    c_mid_scan: np.ndarray     # layout-resolved equal-scan model id
    c_mid_rscan: np.ndarray    # layout-resolved range-scan model id
    c_total_bytes: np.ndarray
    c_n_raw: np.ndarray


def _build_tables(geoms: Sequence[ChainGeometry]) -> _Tables:
    i_rows: List[Tuple] = []
    c_rows: List[Tuple] = []
    for c, g in enumerate(geoms):
        for j, st in enumerate(g.stats):
            i_rows.append((c, j, st.cls, float(st.fanout or 0),
                           g.n_nodes[j], g.node_bytes[j], g.epn[j],
                           g.region[j], st.fences, st.bloom_bits,
                           float(g.termcap), g.t_region, g.t_n_nodes))
        t = g.term
        c_rows.append((g.n_internal, g.t_n_nodes, g.t_epn, g.t_region,
                       t.bloom_bits, t.sorted_keys, t.value_fetch,
                       t.area_links,
                       _mid(access.SORTED_SEARCH, t.layout),
                       _mid(access.SCAN, t.layout),
                       _mid(access.SCAN, t.layout, "range"),
                       g.total_bytes, g.n_raw))
    icols = list(zip(*i_rows)) if i_rows else [[] for _ in range(13)]
    ccols = list(zip(*c_rows))
    f8, i8 = np.float64, np.int64
    return _Tables(
        ch=np.asarray(icols[0], i8), lvl=np.asarray(icols[1], i8),
        cls=np.asarray(icols[2], i8), fanout=np.asarray(icols[3], f8),
        n_nodes=np.asarray(icols[4], f8),
        node_bytes=np.asarray(icols[5], f8), epn=np.asarray(icols[6], f8),
        region=np.asarray(icols[7], f8), fences=np.asarray(icols[8], f8),
        bloom_bits=np.asarray(icols[9], f8),
        termcap=np.asarray(icols[10], f8),
        t_region=np.asarray(icols[11], f8),
        t_n_nodes=np.asarray(icols[12], f8),
        c_n_int=np.asarray(ccols[0], i8),
        c_t_n_nodes=np.asarray(ccols[1], f8),
        c_t_epn=np.asarray(ccols[2], f8),
        c_t_region=np.asarray(ccols[3], f8),
        c_t_bloom=np.asarray(ccols[4], f8),
        c_t_sorted=np.asarray(ccols[5], bool),
        c_t_value_fetch=np.asarray(ccols[6], bool),
        c_t_area=np.asarray(ccols[7], bool),
        c_mid_search=np.asarray(ccols[8], np.int32),
        c_mid_scan=np.asarray(ccols[9], np.int32),
        c_mid_rscan=np.asarray(ccols[10], np.int32),
        c_total_bytes=np.asarray(ccols[11], f8),
        c_n_raw=np.asarray(ccols[12], f8))


# ---------------------------------------------------------------------------
# Vectorized record emission (one numpy expression per class x slot)
# ---------------------------------------------------------------------------
class _Rows:
    """Accumulates record columns: (chain, order, model id, size, count)."""

    def __init__(self) -> None:
        self.parts: List[Tuple[np.ndarray, ...]] = []

    def emit(self, ch, order, mid, size, count=None) -> None:
        n = len(ch)
        if n == 0:
            return
        if np.isscalar(mid):
            mid = np.full(n, mid, np.int32)
        if count is None:
            count = np.ones(n)
        self.parts.append((np.asarray(ch, np.int64),
                           np.asarray(order, np.int64),
                           np.asarray(mid, np.int32),
                           np.asarray(size, np.float64),
                           np.asarray(count, np.float64)))

    def collect(self) -> Tuple[np.ndarray, ...]:
        if not self.parts:
            z = np.zeros(0)
            return (z.astype(np.int64), z.astype(np.int64),
                    z.astype(np.int32), z, z)
        return tuple(np.concatenate([p[i] for p in self.parts])
                     for i in range(5))


def _emit_get(t: _Tables, workload: Workload, rows: _Rows) -> None:
    key_bytes = float(workload.key_bytes)
    # -- internal levels ----------------------------------------------------
    m = t.cls >= CLS_IND_FUNC                 # every class with its own P
    mult = skew_multipliers(t.n_nodes[m], workload)
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS,
              _mid(access.RANDOM_ACCESS),
              np.maximum(t.region[m] * mult, 1.0))
    m = t.cls == CLS_SKIP                     # skip list: fence search
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS, _mid(access.SORTED_SEARCH),
              np.maximum(np.maximum(t.epn[m] / t.termcap[m], 1.0) *
                         FENCE_BYTES, 1.0))
    m = t.cls == CLS_LL                       # linked list: head + hops
    pages = np.maximum(t.epn[m] / t.termcap[m], 1.0)
    visited = (pages + 1.0) / 2.0
    mult = skew_multipliers(t.t_n_nodes[m], workload)
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS, _mid(access.RANDOM_ACCESS),
              np.maximum(t.t_region[m] * mult, 1.0))
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1, _mid(access.RANDOM_ACCESS),
              t.t_region[m], np.maximum(visited - 1.0, 0.0))
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 2, _mid(access.SCAN),
              t.termcap[m] * key_bytes, np.maximum(visited - 1.0, 0.0))
    m = t.cls == CLS_IND_FUNC                 # hash partitioning probe
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1, _mid(access.HASH_PROBE),
              np.maximum(t.n_nodes[m] * np.maximum(t.fanout[m], 1.0) *
                         PTR_BYTES, 1.0))
    m = (t.cls == CLS_DEP) | (t.cls == CLS_DEP_BLOOM)   # sorted fences
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1,
              _mid(access.SORTED_SEARCH, "row-wise"),
              np.maximum(t.fences[m] * FENCE_BYTES, 1.0))
    m = t.cls == CLS_DEP_BLOOM
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 2, _mid(access.BLOOM_PROBE),
              np.maximum(t.bloom_bits[m] / 8.0, 1.0))
    m = t.cls == CLS_APPEND                   # append partitioning scan
    rows.emit(t.ch[m], t.lvl[m] * _SLOTS + 1, _mid(access.SCAN),
              np.maximum(np.where(t.fanout[m] > 0, t.fanout[m], 2.0) *
                         FENCE_BYTES, 1.0))
    # -- terminal node ------------------------------------------------------
    ch = np.arange(len(t.c_n_int))
    base = t.c_n_int * _SLOTS
    entries = np.maximum(t.c_t_epn, 1.0)
    mult = skew_multipliers(t.c_t_n_nodes, workload)
    rows.emit(ch, base, _mid(access.RANDOM_ACCESS),
              np.maximum(t.c_t_region * mult, 1.0))
    m = t.c_t_bloom > 0.0
    rows.emit(ch[m], base[m] + 1, _mid(access.BLOOM_PROBE),
              np.maximum(t.c_t_bloom[m] / 8.0, 1.0))
    m = t.c_t_sorted
    rows.emit(ch[m], base[m] + 2, t.c_mid_search[m],
              np.maximum(entries[m] * key_bytes, 1.0))
    m = ~t.c_t_sorted
    rows.emit(ch[m], base[m] + 2, t.c_mid_scan[m],
              entries[m] * key_bytes / 2.0)
    m = t.c_t_value_fetch
    rows.emit(ch[m], base[m] + 3, _mid(access.RANDOM_ACCESS),
              np.maximum(entries[m] * float(workload.value_bytes), 1.0))


def _emit_tail_range(t: _Tables, workload: Workload, rows: _Rows) -> None:
    """Fig. 10 range sweep appended after the get descent."""
    ch = np.arange(len(t.c_n_int))
    base = (t.c_n_int + 1) * _SLOTS
    frac = max(workload.selectivity, 0.0)
    n_pages = np.maximum(np.ceil(frac * t.c_t_n_nodes), 1.0)
    hop = np.where(t.c_t_area | (t.c_t_n_nodes == 1.0),
                   t.c_t_region, t.c_total_bytes)
    rows.emit(ch, base, _mid(access.RANDOM_ACCESS), hop,
              np.maximum(n_pages - 1.0, 0.0))
    rows.emit(ch, base + 1, t.c_mid_rscan,
              np.maximum(t.c_t_epn, 1.0) * float(workload.key_bytes),
              n_pages)


def _emit_bulk_load(t: _Tables, workload: Workload, rows: _Rows) -> None:
    ch = np.arange(len(t.c_n_int))
    data_bytes = t.c_n_raw * float(workload.pair_bytes)
    m = t.c_t_sorted
    rows.emit(ch[m], np.zeros(int(m.sum()), np.int64), _mid(access.SORT),
              np.maximum(t.c_n_raw[m], 1.0))
    rows.emit(ch[m], np.ones(int(m.sum()), np.int64),
              _mid(access.ORDERED_BATCH_WRITE),
              np.maximum(data_bytes[m], 1.0))
    m = ~t.c_t_sorted
    rows.emit(ch[m], np.zeros(int(m.sum()), np.int64),
              _mid(access.SERIAL_WRITE), np.maximum(data_bytes[m], 1.0))
    level_bytes = np.maximum(t.n_nodes * t.node_bytes, 1.0)
    base = (t.lvl + 1) * _SLOTS
    m = (t.cls == CLS_IND) | (t.cls == CLS_IND_FUNC)
    rows.emit(t.ch[m], base[m], _mid(access.SCAN),
              np.maximum(data_bytes[t.ch[m]], 1.0))
    rows.emit(t.ch[m], base[m] + 1, _mid(access.SCATTERED_BATCH_WRITE),
              np.maximum(level_bytes[m], 1.0))
    m = ~m
    rows.emit(t.ch[m], base[m], _mid(access.ORDERED_BATCH_WRITE),
              np.maximum(level_bytes[m], 1.0))


def emit_operation(op: str, t: _Tables, workload: Workload
                   ) -> Tuple[np.ndarray, ...]:
    """Record columns (chain, order, model id, size, count) of one
    operation over every chain in the tables — the vectorized twin of
    ``synthesis.synthesize_operation`` + ``batchcost.compile_breakdown``."""
    rows = _Rows()
    if op == "get":
        _emit_get(t, workload, rows)
    elif op == "range_get":
        _emit_get(t, workload, rows)
        _emit_tail_range(t, workload, rows)
    elif op == "update":
        _emit_get(t, workload, rows)
        ch = np.arange(len(t.c_n_int))
        rows.emit(ch, (t.c_n_int + 1) * _SLOTS, _mid(access.SERIAL_WRITE),
                  np.full(len(ch), max(float(workload.value_bytes), 1.0)))
    elif op == "bulk_load":
        _emit_bulk_load(t, workload, rows)
    else:
        raise KeyError(op)
    return rows.collect()


# ---------------------------------------------------------------------------
# Assembly: per-spec tile-padded segments, ready for frontier concatenation
# ---------------------------------------------------------------------------
def pack_specs(chains: Sequence[Tuple[Element, ...]], workload: Workload,
               mix_items: Tuple[Tuple[str, float], ...]
               ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Mix-weighted (ids, sizes, weights) per chain, each padded to a TILE
    multiple — the vectorized equivalent of packing every chain through
    the scalar ``instantiate -> synthesize -> compile -> pad`` pipeline."""
    n_chains = len(chains)
    if n_chains == 0:
        return []
    geoms = [chain_geometry(c, workload) for c in chains]
    t = _build_tables(geoms)
    ch_parts, key_parts, mid_parts, size_parts, w_parts = [], [], [], [], []
    for pos, (op, op_w) in enumerate(mix_items):
        ch, order, mid, size, count = emit_operation(op, t, workload)
        ch_parts.append(ch)
        key_parts.append(order + pos * _OP_STRIDE)
        mid_parts.append(mid)
        size_parts.append(size)
        w_parts.append(count * float(op_w))
    ch = np.concatenate(ch_parts)
    key = ch * (_OP_STRIDE * len(mix_items)) + np.concatenate(key_parts)
    mids = np.concatenate(mid_parts)
    sizes = np.concatenate(size_parts)
    weights = np.concatenate(w_parts)

    idx = np.argsort(key, kind="stable")
    ch, mids, sizes, weights = ch[idx], mids[idx], sizes[idx], weights[idx]

    counts = np.bincount(ch, minlength=n_chains)
    # every chain must emit exactly its template's symbolic record schema
    # (the once-per-template breakdown synthesis.py declares); a mismatch
    # means the vectorized emission drifted from the expert system
    expected_by_template: Dict[Tuple, int] = {}
    for c, g in enumerate(geoms):
        expected = expected_by_template.get(g.template)
        if expected is None:
            expected = sum(len(symbolic_breakdown(op, g.template))
                           for op, _ in mix_items)
            expected_by_template[g.template] = expected
        if counts[c] != expected:
            raise AssertionError(
                f"template emission drift: chain {c} produced {counts[c]} "
                f"records, schema says {expected} (template {g.template})")
    padded = counts + (-counts % TILE)
    pad_off = np.concatenate([[0], np.cumsum(padded)])
    raw_off = np.concatenate([[0], np.cumsum(counts)])
    total = int(pad_off[-1])
    out_ids = np.empty(total, np.int32)
    out_sizes = np.ones(total, np.float64)
    out_weights = np.zeros(total, np.float64)
    # pad rows repeat the block's first real model id (see the pad-id note
    # in batchcost); fill per chain, then scatter the real rows over it
    out_ids[:] = np.repeat(mids[raw_off[:-1]], padded)
    pos = np.arange(len(ch)) + np.repeat(pad_off[:-1] - raw_off[:-1], counts)
    out_ids[pos] = mids
    out_sizes[pos] = sizes
    out_weights[pos] = weights
    for arr in (out_ids, out_sizes, out_weights):
        arr.setflags(write=False)
    return [(out_ids[pad_off[c]:pad_off[c + 1]],
             out_sizes[pad_off[c]:pad_off[c + 1]],
             out_weights[pad_off[c]:pad_off[c + 1]])
            for c in range(n_chains)]
