"""Fused device-resident frontier scoring: one jitted call per frontier.

PR 1's grouped engine (:mod:`repro.core.batchcost`) already evaluates a
whole candidate frontier with one vectorized ``FittedModel.predict`` per
Level-2 model — but that is still a Python loop over ~14 models with a
host<->device round trip each.  This module removes the loop: an entire
:class:`~repro.core.hardware.HardwareProfile` is packed once into
device-resident *parameter banks*, and a frontier — parallel
``(model_id, size, weight, segment)`` arrays — is scored by a single
jitted function that

1. gathers each record's parameters from per-kind stacked banks
   (kind-masked, so every record evaluates all three families and selects
   the right one — branch-free and fully vectorized);
2. reduces records to per-design totals with a dense ``TILE``-wide
   pre-reduction followed by one ``segment_sum``.

Banks cover the whole model zoo:

* the **linear-basis family** (linear / log_linear / log_loglog / nlogn)
  collapses into one canonical 4-feature basis ``[x, ln x, ln ln x,
  x ln x]`` with per-model weight rows (absent features carry weight 0);
* **sigmoids** (and **sigmoids2d**, whose plain-predict is its m=1 slice
  S1) stack into ``[M, K]`` amplitude/slope/center banks, zero-padded;
* **knn** joins via a fixed k=4 ``top_k`` over inverse log-distance
  weights with sentinel-masked padding (see ``models._knn_predict``).

Shapes are bucketed exactly like ``batchcost._predict_padded`` — records
and segment counts pad to powers of two (chunked at ``_MAX_FUSED_RECORDS``)
— so XLA compiles a bounded shape set.  Bank widths are fixed per process,
which makes a what-if-hardware question a pure parameter-table swap: a new
profile builds new banks of identical shape and reuses the compiled
executable with **zero recompilation** (asserted via :func:`trace_count`).
Large frontiers shard across local devices with ``pmap`` over contiguous
segment ranges.

Totals agree with the grouped PR-1 oracle to <=1e-6 relative (XLA fuses
the banked computation differently than the per-kind eager predicts, and
the segment reduction runs in float32) — relaxed from the 1e-9
scalar/grouped contract, see ``tests/test_batchcost.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import memo
from repro.core.hardware import HardwareProfile
from repro.core.memo import MEMO_LOCK
from repro.core.models import _BASES, KNN_SENTINEL
from repro.testing import faults

# ---------------------------------------------------------------------------
# Level-2 model-name interning: frontier records refer to models by id.
# Owned here (the table rows are aligned to it); batchcost re-exports.
# Guarded by the shared memo lock: a torn read of (_MODEL_IDS,
# _MODEL_NAMES) under concurrent serving threads could hand two models
# one id, silently mis-scoring every frontier that uses either.
# ---------------------------------------------------------------------------
_MODEL_IDS: Dict[str, int] = {}
_MODEL_NAMES: List[str] = []


def model_id(name: str) -> int:
    mid = _MODEL_IDS.get(name)
    if mid is None:
        with MEMO_LOCK:
            mid = _MODEL_IDS.get(name)
            if mid is None:
                mid = len(_MODEL_NAMES)
                _MODEL_NAMES.append(name)
                _MODEL_IDS[name] = mid
    return mid


def _capture_model_names() -> List[str]:
    with MEMO_LOCK:
        return list(_MODEL_NAMES)


def _restore_model_remap(names: List[str]) -> np.ndarray:
    """old interned id -> live id, re-interning every snapshotted name.

    Ids are assigned lazily in first-use order, so a restarted process
    (or one that interned extra names first) may disagree with the
    snapshot; every id-bearing restored value is rewritten through this
    remap (a fresh process re-interns in snapshot order, making the
    remap the identity)."""
    return np.asarray([model_id(n) for n in names], dtype=np.int32)


memo.register_snapshot_env("model_ids", _capture_model_names,
                           _restore_model_remap)


def model_name(mid: int) -> str:
    return _MODEL_NAMES[mid]


KIND_LINEAR, KIND_SIGMOID, KIND_KNN = 0, 1, 2

#: canonical feature positions of each basis' weight vector, in order —
#: e.g. nlogn's basis is [x ln x, x], landing at canonical slots (3, 0)
_CANONICAL_SLOTS = {
    "linear": (0,),
    "log_linear": (0, 1),
    "log_loglog": (0, 1, 2),
    "nlogn": (3, 0),
}

#: fixed per-process bank widths; profiles needing more grow to the next
#: power of two (a width change recompiles once, then stays fixed)
_SIG_SLOTS = 4
_KNN_SLOTS = 16

#: largest fused record-chunk; bigger frontiers accumulate over chunks
_MAX_FUSED_RECORDS = 1 << 18

#: records per reduction tile: packing pads every design's record block to
#: a multiple of TILE (pad rows carry weight 0), so an in-register dense
#: reshape-sum shrinks the scatter by 8x before the single segment_sum —
#: XLA's scatter-add is serial on CPU and the frontier reduction would
#: otherwise dominate the fused call
TILE = 8


def _pow2(n: int, floor: int) -> int:
    return max(1 << max(n - 1, 0).bit_length(), floor)


@dataclasses.dataclass(frozen=True)
class DeviceTable:
    """One profile's parameter banks, resident on device.

    ``banks`` is the jit-traced pytree; the remaining fields are host-side
    metadata (row validity, interning watermark) used to validate frontiers
    and to decide when a table must be rebuilt.
    """

    profile_name: str
    banks: Dict[str, jax.Array]   # kinds/lin_*/sig_*/knn_*/xlo/xhi, [M,...]
    avail: np.ndarray             # bool [M] — rows backed by a fitted model
    n_interned: int               # len(_MODEL_NAMES) at build time
    sig_slots: int
    knn_slots: int
    has_knn: bool                 # static jit flag: skip top_k when False
    models_ref: int               # id() of the models dict banked here

    @property
    def n_rows(self) -> int:
        return int(self.banks["kinds"].shape[0])


def build_table(hw: HardwareProfile, *, sig_slots: int = _SIG_SLOTS,
                knn_slots: int = _KNN_SLOTS) -> DeviceTable:
    """Pack every fitted model of ``hw`` into stacked device banks."""
    for name in hw.models:
        model_id(name)          # rows must exist for every profile model
    needed_sig = max([sig_slots] + [
        len(np.atleast_1d(m.params[key]))
        for m in hw.models.values() for key in ("c", "s1_c")
        if key in m.params])
    needed_knn = max([knn_slots] + [
        len(np.atleast_1d(m.params["x"]))
        for m in hw.models.values() if m.kind == "knn"])
    sig_slots = _pow2(needed_sig, sig_slots)
    knn_slots = _pow2(needed_knn, knn_slots)

    m_rows = _pow2(len(_MODEL_NAMES), 16)
    kinds = np.zeros(m_rows, np.int32)
    lin_w = np.zeros((m_rows, 4), np.float32)
    lin_y0 = np.zeros(m_rows, np.float32)
    sig_c = np.zeros((m_rows, sig_slots), np.float32)
    sig_k = np.ones((m_rows, sig_slots), np.float32)
    sig_x0 = np.zeros((m_rows, sig_slots), np.float32)
    sig_y0 = np.zeros(m_rows, np.float32)
    knn_lx = np.full((m_rows, knn_slots), KNN_SENTINEL, np.float32)
    knn_y = np.zeros((m_rows, knn_slots), np.float32)
    xlo = np.ones(m_rows, np.float32)
    xhi = np.ones(m_rows, np.float32)
    avail = np.zeros(m_rows, bool)

    for name, model in hw.models.items():
        row = _MODEL_IDS[name]
        avail[row] = True
        xlo[row], xhi[row] = model.x_range
        p = model.params
        if model.kind in _BASES:
            for w_val, slot in zip(np.atleast_1d(p["w"]),
                                   _CANONICAL_SLOTS[model.kind]):
                lin_w[row, slot] = w_val
            lin_y0[row] = p["y0"]
        elif model.kind in ("sigmoids", "sigmoids2d"):
            prefix = "s1_" if model.kind == "sigmoids2d" else ""
            kinds[row] = KIND_SIGMOID
            n_sig = len(np.atleast_1d(p[prefix + "c"]))
            sig_c[row, :n_sig] = p[prefix + "c"]
            sig_k[row, :n_sig] = p[prefix + "k"]
            sig_x0[row, :n_sig] = p[prefix + "x0"]
            sig_y0[row] = p[prefix + "y0"]
        elif model.kind == "knn":
            kinds[row] = KIND_KNN
            n_pts = len(p["x"])
            knn_lx[row, :n_pts] = np.log(
                np.asarray(p["x"], np.float32) + 1.0)
            knn_y[row, :n_pts] = p["y"]
        else:
            raise ValueError(f"unbankable model kind: {model.kind}")

    banks = {k: jnp.asarray(v) for k, v in {
        "kinds": kinds, "lin_w": lin_w, "lin_y0": lin_y0,
        "sig_c": sig_c, "sig_k": sig_k, "sig_x0": sig_x0, "sig_y0": sig_y0,
        "knn_lx": knn_lx, "knn_y": knn_y, "xlo": xlo, "xhi": xhi}.items()}
    # chaos seam: a corrupt rule NaN-poisons the float banks (the int
    # gather indices stay intact), surfacing as non-finite fused totals
    # until invalidate_table() forces a clean rebuild
    banks = faults.corrupt("devicecost.banks", banks, key=hw.name)
    return DeviceTable(hw.name, banks, avail, len(_MODEL_NAMES),
                       sig_slots, knn_slots,
                       has_knn=bool((kinds[avail] == KIND_KNN).any()),
                       models_ref=id(hw.models))


def device_table(hw: HardwareProfile) -> DeviceTable:
    """The (cached) device table of a profile, rebuilt when stale.

    A table goes stale when the global model-name interning has grown past
    its watermark, or when the profile's models dict is no longer the one
    that was banked (a profile derived from another must never score with
    its parent's banks); bank *shapes* stay fixed until a power-of-two
    boundary crosses, so rebuilds almost never recompile the scorer — and
    two profiles of the same model zoo always share compiled executables.
    """
    def _current(table) -> bool:
        return table is not None and \
            table.n_interned == len(_MODEL_NAMES) and \
            table.models_ref == id(hw.models)

    with MEMO_LOCK:   # consistent staleness check vs concurrent interning
        table = hw._device_table
        if _current(table):
            return table
    # build OUTSIDE the lock — bank construction is the expensive path and
    # must not stall every concurrent scorer's cache traffic; two racing
    # threads may build duplicate (equal) tables, last write wins
    table = build_table(hw)
    with MEMO_LOCK:
        stale = hw._device_table
        hw._device_table = table
        if stale is not None:
            _BANK_REPLICAS.discard(lambda k, v: v[0] is stale)
        return table


def invalidate_table(hw: HardwareProfile) -> None:
    """Drop a profile's cached device table and every bank replica of it.

    The serving tier's degraded-engine recovery probe calls this before
    re-trying the fused engine: if the banks were corrupted (non-finite
    totals demoted the profile to the grouped oracle), the next
    :func:`device_table` call rebuilds them from the fitted models."""
    with MEMO_LOCK:
        stale = hw._device_table
        hw._device_table = None
        if stale is not None:
            _BANK_REPLICAS.discard(lambda k, v: v[0] is stale)


# ---------------------------------------------------------------------------
# Per-device bank placement.  A table's banks live wherever jax put them
# (device 0); the sharded paths need them ON every participating device,
# and the serving shard pool needs them committed to one SPECIFIC device.
# Both placements happen once per (table, placement) and are interned in
# the ``device_banks`` cache — after that, repeat scores touch the host
# only for the O(R) availability check.  Keys carry ``id(table)``; the
# value keeps a strong reference to the table, so the id cannot be reused
# while its entry lives, and ``device_table`` discards a profile's
# replicas the moment it swaps in a rebuilt table.
# ---------------------------------------------------------------------------
_BANK_REPLICAS = memo.DictCache(maxsize=32, name="device_banks")


def replicated_banks(table: DeviceTable, n_dev: int) -> Dict[str, jax.Array]:
    """``table.banks`` stacked across the first ``n_dev`` local devices
    (``jax.device_put_replicated``), ready as a leading-axis pmap input."""
    key = (id(table), n_dev)
    hit = _BANK_REPLICAS.get(key)
    if hit is not None and hit[0] is table:
        return hit[1]
    stacked = jax.device_put_replicated(table.banks,
                                        jax.local_devices()[:n_dev])
    _BANK_REPLICAS.put(key, (table, stacked))
    return stacked


def _banks_on(table: DeviceTable, device) -> Dict[str, jax.Array]:
    """The table's banks committed to one specific local device (the
    serving shard pool routes each partition's jit dispatch by device)."""
    key = (id(table), "device", device.id)
    hit = _BANK_REPLICAS.get(key)
    if hit is not None and hit[0] is table:
        return hit[1]
    banks = jax.device_put(table.banks, device)
    _BANK_REPLICAS.put(key, (table, banks))
    return banks


# ---------------------------------------------------------------------------
# The fused scorer
# ---------------------------------------------------------------------------
#: traced-function entry counter — increments only while jax (re)traces the
#: kernel, i.e. exactly once per compiled (shape, static-arg) signature.
#: Tests probe it to assert what-if-hardware swaps trigger no recompilation.
_TRACE_COUNT = [0]


def trace_count() -> int:
    return _TRACE_COUNT[0]


def bank_predict(banks: Dict[str, jax.Array], ids: jax.Array,
                 x: jax.Array, with_knn: bool) -> jax.Array:
    """Per-record model evaluation against stacked parameter banks.

    ``ids`` is ``[R]``; ``x`` is ``[..., R]`` — any number of leading
    batch axes (the flat scorer passes ``[R]``, the sweep scorer
    ``[W, R]``) broadcast against the ``[R, ...]`` bank gathers via the
    trailing record dimension, so both kernels share one body and the
    parameter gathers are issued once per record regardless of the
    batch shape.  Differentiable in ``x`` through the linear-basis and
    sigmoid families (``jnp.clip``/``log``/``sigmoid`` are smooth
    inside the fitted range), which is what lets
    :mod:`repro.core.relax` drive ``jax.grad`` through the very same
    bank rows the fused engine scores with.  knn rows join through a
    ``top_k`` gather whose value-gradients flow through the inverse
    log-distance weights.
    """
    x = jnp.clip(x, banks["xlo"][ids], banks["xhi"][ids])
    lx = jnp.log(x + 1.0)

    feats = jnp.stack([x, lx, jnp.log(lx + 1.0), x * lx], axis=-1)
    lin = (feats * banks["lin_w"][ids]).sum(-1) + banks["lin_y0"][ids]

    sig = (jax.nn.sigmoid(banks["sig_k"][ids] *
                          (lx[..., None] - banks["sig_x0"][ids])) *
           banks["sig_c"][ids]).sum(-1) + banks["sig_y0"][ids]

    kind = banks["kinds"][ids]
    y = jnp.where(kind == KIND_SIGMOID, sig, lin)
    if with_knn:   # static: profiles without knn models skip the top_k
        klx = banks["knn_lx"][ids]
        d = jnp.abs(lx[..., None] - klx) + 1e-6
        w = jnp.where(klx >= KNN_SENTINEL * 0.5, 0.0, 1.0 / d)
        wk, idx = jax.lax.top_k(w, 4)
        yk = jnp.take_along_axis(
            jnp.broadcast_to(banks["knn_y"][ids], w.shape), idx, axis=-1)
        knn = (wk * yk).sum(-1) / jnp.maximum(wk.sum(-1), 1e-30)
        y = jnp.where(kind == KIND_KNN, knn, y)
    return jnp.maximum(y, 0.0)


def _score_kernel(banks: Dict[str, jax.Array], ids: jax.Array,
                  sizes: jax.Array, weights: jax.Array,
                  segments: jax.Array, n_segments: int,
                  with_knn: bool) -> jax.Array:
    _TRACE_COUNT[0] += 1
    y = bank_predict(banks, ids, sizes, with_knn)
    # tile-aligned design blocks: dense pre-reduction, then one scatter
    tiles = (weights * y).reshape(-1, TILE).sum(-1)
    return jax.ops.segment_sum(tiles, segments, num_segments=n_segments,
                               indices_are_sorted=True)


_score_jit = jax.jit(_score_kernel, static_argnums=(5, 6))


def _sweep_kernel(banks: Dict[str, jax.Array], ids: jax.Array,
                  sizes: jax.Array, weights: jax.Array,
                  segments: jax.Array, n_segments: int,
                  with_knn: bool) -> jax.Array:
    """The workload-axis twin of :func:`_score_kernel`.

    ``sizes``/``weights`` carry a leading workload axis ``[W, R]`` while
    ``ids``/``segments`` stay 1-D: a design-continuum sweep shares its
    record layout across every workload point, so the parameter-bank
    gathers (the memory-bound half of the fused call) are issued ONCE for
    all W workloads instead of once per workload — on top of collapsing W
    dispatches into one.  Per-record math is :func:`bank_predict` with a
    leading batch axis; only the reduction differs.
    """
    _TRACE_COUNT[0] += 1
    y = bank_predict(banks, ids, sizes, with_knn)
    tiles = (weights * y).reshape(y.shape[0], -1, TILE).sum(-1)
    return jax.vmap(lambda t: jax.ops.segment_sum(
        t, segments, num_segments=n_segments,
        indices_are_sorted=True))(tiles)


_sweep_jit = jax.jit(_sweep_kernel, static_argnums=(5, 6))


@functools.lru_cache(maxsize=64)
def _score_pmap(n_segments: int, with_knn: bool):
    # banks arrive pre-stacked via replicated_banks (one replica per
    # device, placed once) — in_axes=0 consumes them without the per-call
    # host broadcast that in_axes=None would re-issue
    return jax.pmap(
        functools.partial(_score_kernel, n_segments=n_segments,
                          with_knn=with_knn),
        in_axes=(0, 0, 0, 0, 0))


@functools.lru_cache(maxsize=64)
def _sweep_pmap(n_segments: int, with_knn: bool):
    """Workload-row twin of :func:`_score_pmap`: every device scores its
    own ``[W_shard, R]`` slice of the sweep with the shared record
    layout (ids/tile_segments replicated, sizes/weights sharded)."""
    return jax.pmap(
        functools.partial(_sweep_kernel, n_segments=n_segments,
                          with_knn=with_knn),
        in_axes=(0, 0, 0, 0, 0))


def _pad_records(ids: np.ndarray, sizes: np.ndarray, weights: np.ndarray,
                 tile_segments: np.ndarray, bucket: int
                 ) -> Tuple[np.ndarray, ...]:
    """Pad a tile-aligned record block to ``bucket`` rows (and its tile
    segments to ``bucket // TILE``); pad rows carry weight 0 so they
    contribute exactly nothing.  Pad segments repeat the *last* real
    segment id — appending 0 would break the sorted order that the
    kernel's ``indices_are_sorted`` scatter hint promises.

    Dtype conversions are copy-free when the input already matches —
    ``PackedFrontier`` hands the steady-state scoring path cached
    device-dtype views, so a retained frontier that lands exactly on its
    bucket reaches the jit call with zero host-side array copies."""
    n = len(ids)
    if n == bucket:
        return (np.asarray(ids, np.int32), np.asarray(sizes, np.float32),
                np.asarray(weights, np.float32),
                np.asarray(tile_segments, np.int32))
    pad = bucket - n
    seg_pad = bucket // TILE - len(tile_segments)
    seg_fill = tile_segments[-1] if len(tile_segments) else 0
    return (np.concatenate([ids, np.zeros(pad, ids.dtype)]).astype(np.int32),
            np.concatenate([sizes, np.ones(pad, sizes.dtype)]
                           ).astype(np.float32),
            np.concatenate([weights, np.zeros(pad, weights.dtype)]
                           ).astype(np.float32),
            np.concatenate([tile_segments,
                            np.full(seg_pad, seg_fill,
                                    tile_segments.dtype)]
                           ).astype(np.int32))


# ---------------------------------------------------------------------------
# Auto-shard threshold.  pmap dispatch costs more than jit dispatch, so
# small products must stay on one device and large ones must not miss the
# sharded path.  The cut-over is a per-process knob resolved as: explicit
# ``set_shard_threshold`` override > ``REPRO_SHARD_THRESHOLD`` env var >
# a lazily-run device-count-aware calibration (below).
# ---------------------------------------------------------------------------
_SHARD_STATE: Dict[str, Optional[int]] = {"override": None,
                                          "calibrated": None}

#: pow2 record buckets the calibration probes, smallest first
_CALIBRATION_BUCKETS = (1024, 4096)

SHARD_THRESHOLD_ENV = "REPRO_SHARD_THRESHOLD"


def set_shard_threshold(records: Optional[int]) -> None:
    """Override the auto-shard cut-over (records for frontiers, cells for
    sweeps).  ``None`` drops the override back to the env-var/calibrated
    default; the calibration result itself stays memoized."""
    with MEMO_LOCK:
        _SHARD_STATE["override"] = \
            None if records is None else max(int(records), 1)


def shard_threshold() -> int:
    """Product size (frontier records / sweep cells) at which the auto
    path starts sharding across devices.  See :func:`set_shard_threshold`
    and the ``REPRO_SHARD_THRESHOLD`` env var; with neither set, a quick
    calibration times jit vs pmap dispatch at :data:`_CALIBRATION_BUCKETS`
    once per process (single-device processes skip straight to "never")."""
    override = _SHARD_STATE["override"]
    if override is not None:
        return override
    env = os.environ.get(SHARD_THRESHOLD_ENV)
    if env:
        try:
            return max(int(env), 1)
        except ValueError:
            pass
    calibrated = _SHARD_STATE["calibrated"]
    if calibrated is None:
        # racing threads calibrate redundantly but agree; not worth
        # holding the memo lock across timed device dispatches
        # lint: unlocked(idempotent single-key write; races agree on value)
        calibrated = _SHARD_STATE["calibrated"] = _calibrate_shard_threshold()
    return calibrated


def _calibration_table() -> DeviceTable:
    """A tiny synthetic all-linear table (row 0 scores y = x) so the
    calibration never touches a real profile's banks or model interning."""
    m = 16
    lin_w = np.zeros((m, 4), np.float32)
    lin_w[:, 0] = 1.0
    banks = {k: jnp.asarray(v) for k, v in {
        "kinds": np.zeros(m, np.int32), "lin_w": lin_w,
        "lin_y0": np.zeros(m, np.float32),
        "sig_c": np.zeros((m, _SIG_SLOTS), np.float32),
        "sig_k": np.ones((m, _SIG_SLOTS), np.float32),
        "sig_x0": np.zeros((m, _SIG_SLOTS), np.float32),
        "sig_y0": np.zeros(m, np.float32),
        "knn_lx": np.full((m, _KNN_SLOTS), KNN_SENTINEL, np.float32),
        "knn_y": np.zeros((m, _KNN_SLOTS), np.float32),
        "xlo": np.ones(m, np.float32),
        "xhi": np.full(m, 1e9, np.float32)}.items()}
    return DeviceTable("__shard_calibration__", banks, np.ones(m, bool),
                       m, _SIG_SLOTS, _KNN_SLOTS, has_knn=False,
                       models_ref=-1)


def _best_of(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _calibrate_shard_threshold() -> int:
    """Smallest probed record bucket where the pmap path beats the jit
    path on synthetic frontiers (TILE-sized designs, shared shapes with
    real traffic); 4x the largest bucket when pmap never wins, and
    effectively "never" on a single-device process."""
    if len(jax.local_devices()) <= 1:
        return _MAX_FUSED_RECORDS
    table = _calibration_table()
    for bucket in _CALIBRATION_BUCKETS:
        ids = np.zeros(bucket, np.int32)
        sizes = np.ones(bucket, np.float32)
        weights = np.ones(bucket, np.float32)
        tiles = np.arange(bucket // TILE, dtype=np.int64)
        n_seg = bucket // TILE

        def _single():
            np.asarray(_score_jit(table.banks, ids, sizes, weights,
                                  tiles.astype(np.int32),
                                  _pow2(n_seg, 16), False))

        def _sharded():
            _score_sharded(table, ids, sizes, weights, tiles, n_seg)

        _single(), _sharded()          # compile both paths first
        if _best_of(_sharded) <= _best_of(_single):
            return bucket
    return 4 * _CALIBRATION_BUCKETS[-1]


def _check_frontier(table: DeviceTable, ids: np.ndarray) -> None:
    if len(ids) and not table.avail[ids].all():
        missing = sorted({_MODEL_NAMES[m] for m in np.unique(ids)
                          if not table.avail[m]})
        raise KeyError(f"profile {table.profile_name!r} has no fitted "
                       f"model for: {missing}")


def score_frontier(ids: np.ndarray, sizes: np.ndarray, weights: np.ndarray,
                   tile_segments: np.ndarray, n_segments: int,
                   hw: HardwareProfile,
                   shard: Optional[bool] = None,
                   device=None) -> np.ndarray:
    """Per-design totals for packed frontier records, in one fused call.

    Records must be TILE-aligned per design and ``tile_segments`` sorted
    ascending — exactly the layout
    :func:`repro.core.batchcost.pack_frontier` emits.  ``shard=None``
    auto-shards across local devices when more than one is present and
    the frontier clears :func:`shard_threshold` records; ``shard=True``
    forces the pmap path (works on a single device too), ``shard=False``
    forces the single-device jit path.  ``device`` routes the jit path
    onto one specific local device (banks committed there once, see
    :func:`_banks_on`) — the serving shard pool's dispatch primitive;
    it implies ``shard=False``.
    """
    if n_segments == 0:
        return np.zeros(0, np.float64)
    table = device_table(hw)
    _check_frontier(table, ids)
    n_pad = _pow2(n_segments, 16)
    if shard is None:
        shard = device is None and len(jax.local_devices()) > 1 \
            and len(ids) >= shard_threshold()
    if shard:
        return faults.corrupt(
            "devicecost.fused",
            _score_sharded(table, ids, sizes, weights, tile_segments,
                           n_segments))
    banks = table.banks if device is None else _banks_on(table, device)
    totals = np.zeros(n_pad, np.float64)
    for lo in range(0, max(len(ids), 1), _MAX_FUSED_RECORDS):
        chunk = slice(lo, lo + _MAX_FUSED_RECORDS)
        tile_chunk = slice(lo // TILE, (lo + _MAX_FUSED_RECORDS) // TILE)
        bucket = _pow2(len(ids[chunk]), 16)
        padded = _pad_records(ids[chunk], sizes[chunk], weights[chunk],
                              tile_segments[tile_chunk], bucket)
        if device is not None:
            padded = tuple(jax.device_put(a, device) for a in padded)
        out = _score_jit(banks, *padded, n_pad, table.has_knn)
        totals += np.asarray(out, np.float64)
    return faults.corrupt("devicecost.fused", totals[:n_segments])


def pad_sweep(ids: np.ndarray, sizes: np.ndarray, weights: np.ndarray,
              tile_segments: np.ndarray, bucket: int
              ) -> Tuple[np.ndarray, ...]:
    """:func:`_pad_records` for sweep layouts: ``sizes``/``weights`` pad
    along their record axis (axis 1), ``ids``/``tile_segments`` stay 1-D.
    Public so :class:`repro.core.batchcost.PackedSweep` can cache the
    padded device-dtype arrays once and hand repeat scores a zero-copy
    call."""
    n = len(ids)
    if n == bucket:
        return (np.asarray(ids, np.int32), np.asarray(sizes, np.float32),
                np.asarray(weights, np.float32),
                np.asarray(tile_segments, np.int32))
    pad = bucket - n
    w = sizes.shape[0]
    seg_pad = bucket // TILE - len(tile_segments)
    seg_fill = tile_segments[-1] if len(tile_segments) else 0
    # pad ids repeat a REAL model id (never a blind 0): the availability
    # check may run on the padded array, and a profile without a fitted
    # model for whatever name was interned first must not spuriously
    # reject a sweep that never references it
    pad_id = ids[-1] if n else 0
    return (np.concatenate([ids, np.full(pad, pad_id, ids.dtype)]
                           ).astype(np.int32),
            np.concatenate([sizes, np.ones((w, pad), sizes.dtype)],
                           axis=1).astype(np.float32),
            np.concatenate([weights, np.zeros((w, pad), weights.dtype)],
                           axis=1).astype(np.float32),
            np.concatenate([tile_segments,
                            np.full(seg_pad, seg_fill,
                                    tile_segments.dtype)]
                           ).astype(np.int32))


def sweep_chunk(w_axis: int) -> int:
    """Largest per-chunk record count of a W-workload sweep: keeps
    W x chunk under the fused-record ceiling, cut on tile boundaries so
    no design block is ever split mid-tile."""
    return max((_MAX_FUSED_RECORDS // max(w_axis, 1)) // TILE * TILE,
               TILE)


def to_device_sweep(ids, sizes, weights, tile_segments) -> Tuple:
    """Commit padded sweep arrays to the device when they fit one fused
    chunk (the retained-sweep steady path skips every host->device copy
    on repeat scores); multi-chunk sweeps stay host-side, where the
    chunk loop slices them."""
    if len(ids) > sweep_chunk(sizes.shape[0]):
        return ids, sizes, weights, tile_segments
    return tuple(jnp.asarray(a)
                 for a in (ids, sizes, weights, tile_segments))


def sweep_shard_count(w_axis: int, n_records: int,
                      shard: Optional[bool] = None) -> int:
    """How many workload-row shards a ``[w_axis, n_records]`` sweep
    should use (1 means the flat single-device path).

    ``shard=None`` auto-shards when more than one local device is
    present, the sweep has rows to split, and the grid clears
    :func:`shard_threshold` cells; ``shard=True`` forces
    ``min(devices, w_axis)`` shards (>= 1, so the pmap path is exercised
    even on one device); ``shard=False`` forces 1."""
    if shard is False or w_axis <= 0:
        return 1
    n_dev = max(min(len(jax.local_devices()), w_axis), 1)
    if shard is True:
        return n_dev
    if n_dev < 2:
        return 1
    return n_dev if w_axis * max(n_records, 1) >= shard_threshold() else 1


def shard_sweep(ids: np.ndarray, sizes: np.ndarray, weights: np.ndarray,
                tile_segments: np.ndarray, n_dev: int) -> Tuple:
    """Stack record-padded rectangular sweep arrays into per-device
    workload-row shards committed to the first ``n_dev`` local devices.

    ``sizes``/``weights`` are host ``[W, R]`` (R already at its pow2
    bucket, e.g. via :func:`pad_sweep`).  A ragged W pads by repeating
    the last sizes row with all-zero weights; the caller slices the
    output back to ``[:W]``, so pad rows are computed-and-dropped, never
    observable — the sharded grid stays bit-identical to the flat call.
    Returns ``(w_axis, (ids, sizes, weights, tile_segments))`` where
    ``sizes``/``weights`` are pmap-sharded (``jax.device_put_sharded``)
    and ``ids``/``tile_segments`` replicated: a retained sweep keeps the
    tuple and every repeat score is a pure pmap dispatch with zero
    host->device copies."""
    devices = jax.local_devices()[:n_dev]
    w_axis = int(sizes.shape[0])
    w_shard = -(-w_axis // n_dev)
    pad = n_dev * w_shard - w_axis
    sizes = np.asarray(sizes, np.float32)
    weights = np.asarray(weights, np.float32)
    if pad:
        sizes = np.concatenate([sizes, np.repeat(sizes[-1:], pad, axis=0)])
        weights = np.concatenate(
            [weights, np.zeros((pad, weights.shape[1]), np.float32)])
    return w_axis, (
        jax.device_put_replicated(np.asarray(ids, np.int32), devices),
        jax.device_put_sharded(list(sizes.reshape(n_dev, w_shard, -1)),
                               devices),
        jax.device_put_sharded(list(weights.reshape(n_dev, w_shard, -1)),
                               devices),
        jax.device_put_replicated(np.asarray(tile_segments, np.int32),
                                  devices))


def _sweep_sharded(table: DeviceTable, state: Tuple,
                   n_segments: int) -> np.ndarray:
    """Dispatch a :func:`shard_sweep` product: one pmap call, per-device
    bank replicas, output rows re-flattened and pad rows sliced off."""
    w_axis, (ids_sh, sizes_sh, weights_sh, tiles_sh) = state
    n_dev = int(sizes_sh.shape[0])
    out = np.asarray(
        _sweep_pmap(_pow2(n_segments, 16), table.has_knn)(
            replicated_banks(table, n_dev), ids_sh, sizes_sh, weights_sh,
            tiles_sh),
        np.float64)
    return out.reshape(-1, out.shape[-1])[:w_axis, :n_segments]


def score_sweep_sharded(state: Tuple, n_segments: int, hw: HardwareProfile,
                        host_ids: np.ndarray) -> np.ndarray:
    """Steady-path twin of :func:`score_sweep` for a prebuilt (retained)
    :func:`shard_sweep` product: beyond the O(R) availability check this
    is one pmap dispatch against device-committed shards — zero copies,
    and hardware swaps reuse the compiled executable."""
    table = device_table(hw)
    _check_frontier(table, host_ids)
    return faults.corrupt("devicecost.fused",
                          _sweep_sharded(table, state, n_segments))


def score_sweep(ids, sizes, weights, tile_segments, n_segments: int,
                hw: HardwareProfile,
                host_ids: Optional[np.ndarray] = None,
                shard: Optional[bool] = None,
                device=None) -> np.ndarray:
    """Per-(workload, design) totals for a rectangular sweep, one fused
    call.

    ``sizes``/``weights`` are ``[W, R]`` with a shared record layout
    (``ids`` ``[R]``, TILE-aligned per design, ``tile_segments`` sorted
    ascending — the layout :func:`repro.core.batchcost.pack_sweep`
    emits); numpy or (already padded, e.g. via :func:`to_device_sweep`)
    device arrays.  When ``ids`` is device-resident, pass ``host_ids``
    (a host-side copy) so the per-call availability check never pulls
    the array back from the device.  Returns ``[W, n_segments]``.
    Shapes are pow2-bucketed like :func:`score_frontier`, so repeat
    sweeps (and what-if-hardware swaps against a sweep) reuse the
    compiled executable with zero recompilation.

    ``shard`` splits the grid across local devices along workload rows
    (:func:`sweep_shard_count` decides the shard count; single-row
    sweeps fall back to PR 2's segment-range pmap) — ``None``
    auto-shards past :func:`shard_threshold` cells, ``True`` forces the
    sharded path, ``False`` pins the flat path.  Retained sweeps should
    prefer :func:`score_sweep_sharded`, which skips the per-call shard
    build.  ``device`` routes the flat call onto one specific device
    (implies ``shard=False``).
    """
    w_axis = int(sizes.shape[0])
    if n_segments == 0 or w_axis == 0:
        return np.zeros((w_axis, n_segments), np.float64)
    table = device_table(hw)
    host_ids = np.asarray(ids) if host_ids is None else host_ids
    _check_frontier(table, host_ids)
    n_pad = _pow2(n_segments, 16)
    chunk_r = sweep_chunk(w_axis)
    n = len(host_ids)
    if device is None and shard is not False \
            and isinstance(sizes, np.ndarray):
        # device-resident retained arrays skip this block: re-sharding
        # them would pull every array back to the host per call — a
        # retained sweep shards once via score_sweep_sharded instead
        n_dev = sweep_shard_count(w_axis, n, shard)
        if (n_dev > 1 or (shard is True and w_axis > 1)) and \
                _pow2(n, 16) <= sweep_chunk(-(-w_axis // n_dev)):
            padded = pad_sweep(host_ids, np.asarray(sizes),
                               np.asarray(weights),
                               np.asarray(tile_segments), _pow2(n, 16))
            return faults.corrupt(
                "devicecost.fused",
                _sweep_sharded(table, shard_sweep(*padded, n_dev),
                               n_segments))
        if w_axis == 1 and (shard is True or (
                shard is None and len(jax.local_devices()) > 1
                and n >= shard_threshold())):
            # flat frontier disguised as a 1-row sweep: segment-range pmap
            flat = _score_sharded(table, host_ids, np.asarray(sizes)[0],
                                  np.asarray(weights)[0],
                                  np.asarray(tile_segments), n_segments)
            return faults.corrupt("devicecost.fused", flat[None])
    banks = table.banks if device is None else _banks_on(table, device)
    if n == _pow2(n, 16) and n <= chunk_r:
        # bucket-aligned single chunk — the steady path: PackedSweep
        # hands over cached padded device-resident arrays plus host ids,
        # so beyond the O(R) availability check above this is a pure
        # fused dispatch with zero copies
        args = (ids, sizes, weights, tile_segments)
        if device is not None:
            args = tuple(jax.device_put(np.asarray(a), device)
                         for a in args)
        out = _sweep_jit(banks, *args, n_pad, table.has_knn)
        return faults.corrupt("devicecost.fused",
                              np.asarray(out, np.float64)[:, :n_segments])
    ids = host_ids
    sizes, weights = np.asarray(sizes), np.asarray(weights)
    tile_segments = np.asarray(tile_segments)
    totals = np.zeros((w_axis, n_pad), np.float64)
    for lo in range(0, max(n, 1), chunk_r):
        chunk = slice(lo, lo + chunk_r)
        tile_chunk = slice(lo // TILE, (lo + chunk_r) // TILE)
        bucket = _pow2(len(ids[chunk]), 16)
        padded = pad_sweep(ids[chunk], sizes[:, chunk], weights[:, chunk],
                           tile_segments[tile_chunk], bucket)
        if device is not None:
            padded = tuple(jax.device_put(a, device) for a in padded)
        out = _sweep_jit(banks, *padded, n_pad, table.has_knn)
        totals += np.asarray(out, np.float64)
    return faults.corrupt("devicecost.fused", totals[:, :n_segments])


def _score_sharded(table: DeviceTable, ids: np.ndarray, sizes: np.ndarray,
                   weights: np.ndarray, tile_segments: np.ndarray,
                   n_segments: int) -> np.ndarray:
    """pmap the scorer over contiguous segment ranges, one per device."""
    from repro.core.templatecost import segment_ranges  # circular at top
    devices = jax.local_devices()
    n_dev = max(min(len(devices), n_segments), 1)
    seg_cuts, tile_cuts = segment_ranges(tile_segments, n_segments, n_dev)
    rec_bucket = _pow2(int(max(np.diff(tile_cuts), default=1)) * TILE, 16)
    seg_pad = _pow2(int(max(np.diff(seg_cuts), default=1)), 16)
    shards = []
    for d in range(n_dev):
        t0, t1 = tile_cuts[d], tile_cuts[d + 1]
        r0, r1 = t0 * TILE, t1 * TILE
        shards.append(_pad_records(ids[r0:r1], sizes[r0:r1],
                                   weights[r0:r1],
                                   tile_segments[t0:t1] - seg_cuts[d],
                                   rec_bucket))
    stacked = [np.stack([s[i] for s in shards]) for i in range(4)]
    out = np.asarray(
        _score_pmap(seg_pad, table.has_knn)(
            replicated_banks(table, n_dev), *stacked),
        np.float64)
    return np.concatenate([
        out[d, :seg_cuts[d + 1] - seg_cuts[d]] for d in range(n_dev)])
