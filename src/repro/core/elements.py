"""Elements and data structure specifications (paper §2, Appendix F).

An *element* is a full assignment of layout primitives describing one node
type.  A *specification* is a hierarchy of elements: each non-terminal
element partitions its block of data into sub-blocks handled by the next
element in the chain (recursion allowed onto the same element).

The element library below reproduces Figure 30 (UDP, ODP, Hash, Range, Trie,
B+, LL, SL) plus the CSB+ and FAST internal nodes of Figure 11.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.primitives import Value, tag_of, validate_assignment


@dataclasses.dataclass(frozen=True)
class Element:
    """A full specification of a single data structure node type."""

    name: str
    values: Tuple[Tuple[str, Value], ...]  # sorted (primitive, value) pairs

    def __post_init__(self) -> None:
        # primitive -> value index: get()/tag() are the synthesizer's hottest
        # calls (dozens per costed design); not a dataclass field, so eq/hash
        # still compare (name, values) only
        object.__setattr__(self, "_lookup", dict(self.values))
        # frontier packing hashes every element chain on each memo lookup
        # (thousands of designs per batched call) — hash the nested value
        # tuples once, not per lookup
        object.__setattr__(self, "_hash", hash((self.name, self.values)))
        # synthesis statics slot: repro.core.templatecost resolves every
        # tag/model the synthesizer reads into one record, lazily, and pins
        # it here so the vectorized geometry pass pays a single attribute
        # read per level instead of dozens of tag() dict lookups (equal
        # elements share one record via templatecost's by-value registry)
        object.__setattr__(self, "_tc_statics", None)

    @staticmethod
    def make(name: str, **values: Value) -> "Element":
        errors = validate_assignment(values)
        if errors:
            raise ValueError(f"invalid element {name}: {errors}")
        return Element(name, tuple(sorted(values.items())))

    def get(self, primitive: str, default: Value = None) -> Value:
        return self._lookup.get(primitive, default)

    def tag(self, primitive: str, default: str = "none") -> str:
        value = self.get(primitive)
        return tag_of(value) if value is not None else default

    # -- convenience accessors used by the cost synthesizer ----------------
    @property
    def terminal(self) -> bool:
        return self.tag("fanout") == "terminal"

    @property
    def capacity(self) -> Optional[int]:
        fanout = self.get("fanout")
        if isinstance(fanout, tuple) and fanout[0] == "terminal":
            return int(fanout[1])
        return None

    @property
    def fanout(self) -> Optional[int]:
        value = self.get("fanout")
        if isinstance(value, tuple) and value[0] == "fixed":
            return int(value[1])
        return None  # unlimited / terminal / func

    @property
    def sorted_keys(self) -> bool:
        return self.tag("key_partitioning") == "data-dep"

    @property
    def retains_keys(self) -> bool:
        return self.tag("key_retention") != "no"

    @property
    def retains_values(self) -> bool:
        return self.tag("value_retention") != "no"

    def with_values(self, **overrides: Value) -> "Element":
        values = dict(self.values)
        values.update(overrides)
        return Element.make(self.name, **values)


# the dataclass-generated __hash__ re-hashes the nested values tuples on
# every call; serve the precomputed one instead (assigned post-decoration —
# frozen dataclasses install their own __hash__ over a class-body override)
Element.__hash__ = lambda self: self._hash  # type: ignore[method-assign]


# ---------------------------------------------------------------------------
# Element library (Figure 30 / Figure 11 columns).
# ---------------------------------------------------------------------------
def _terminal(name: str, *, sorted_: bool, capacity: int = 256,
              area_links: str = "none", **extra: Value) -> Element:
    values: Dict[str, Value] = dict(
        key_retention="yes", value_retention="yes",
        key_value_layout="columnar", intra_node_access="direct",
        utilization=(">=", 0.5) if sorted_ else "none",
        bloom_filters="off", zone_map_filters="off",
        fanout=("terminal", capacity),
        key_partitioning=("data-dep", "sorted") if sorted_ else ("append", "fw"),
        immediate_node_links="none", skip_node_links="none",
        area_links=area_links,
    )
    values.update(extra)
    return Element.make(name, **values)


def unordered_data_page(capacity: int = 256) -> Element:
    return _terminal("UDP", sorted_=False, capacity=capacity,
                     utilization="none")


def ordered_data_page(capacity: int = 256) -> Element:
    return _terminal("ODP", sorted_=True, capacity=capacity,
                     area_links="forward")


def hash_element(buckets: int = 100) -> Element:
    return Element.make(
        "Hash",
        key_retention="no", value_retention="no",
        intra_node_access="direct", utilization="none",
        bloom_filters="off", zone_map_filters="off",
        fanout=("fixed", buckets),
        key_partitioning=("data-ind", "func", "mod"),
        sub_block_capacity="unrestricted",
        immediate_node_links="none", skip_node_links="none", area_links="none",
        sub_block_physical_location="pointed",
        sub_block_physical_layout="scatter",
        sub_blocks_homogeneous="true", sub_block_consolidation="false",
        sub_block_instantiation="lazy", recursion="no",
    )


def range_element(partitions: int = 100) -> Element:
    return Element.make(
        "Range",
        key_retention="no", value_retention="no",
        intra_node_access="direct", utilization="none",
        bloom_filters="off", zone_map_filters="off",
        fanout=("fixed", partitions),
        key_partitioning=("data-ind", "range", partitions),
        sub_block_capacity="unrestricted",
        immediate_node_links="none", skip_node_links="none", area_links="none",
        sub_block_physical_location="pointed",
        sub_block_physical_layout="scatter",
        sub_blocks_homogeneous="true", sub_block_consolidation="false",
        sub_block_instantiation="lazy", recursion="no",
    )


def trie_element(radix: int = 256, max_depth: int = 8) -> Element:
    return Element.make(
        "Trie",
        key_retention=("func", "radix"), value_retention=("func", "subset"),
        key_value_layout="columnar",
        intra_node_access="direct", utilization="none",
        bloom_filters="off", zone_map_filters="off",
        fanout=("fixed", radix),
        key_partitioning=("data-ind", "radix", radix),
        sub_block_capacity="unrestricted",
        immediate_node_links="none", skip_node_links="none", area_links="none",
        sub_block_physical_location="pointed",
        sub_block_physical_layout="scatter",
        sub_blocks_homogeneous="true", sub_block_consolidation="true",
        sub_block_instantiation="lazy", recursion=("yes", max_depth),
    )


def btree_internal(fanout: int = 20) -> Element:
    return Element.make(
        "B+",
        key_retention="no", value_retention="no",
        intra_node_access="direct", utilization=(">=", 0.5),
        bloom_filters="off", zone_map_filters="min",
        filters_memory_layout="scatter",
        fanout=("fixed", fanout),
        key_partitioning=("data-dep", "sorted"),
        sub_block_capacity="balanced",
        immediate_node_links="none", skip_node_links="none", area_links="none",
        sub_block_physical_location="pointed",
        sub_block_physical_layout="scatter",
        sub_blocks_homogeneous="true", sub_block_consolidation="false",
        sub_block_instantiation="lazy", recursion=("yes", "logn"),
    )


def csb_internal(fanout: int = 20) -> Element:
    """Cache-conscious B+tree internal node [75]: BFS children, one pointer."""
    base = btree_internal(fanout).with_values(sub_block_physical_layout="BFS")
    return Element("CSB+", base.values)


def fast_internal(fanout: int = 16, layer_group: int = 4) -> Element:
    """FAST [51]: inline homogeneous children, BFS layer grouping, no pointers."""
    base = btree_internal(fanout).with_values(
        key_partitioning=("data-dep", "k-ary", 4),
        sub_block_physical_location="inline",
        sub_block_physical_layout=("BFS-layer", layer_group),
    )
    return Element("FAST", base.values)


def linked_list_element(page_capacity: int = 256) -> Element:
    return Element.make(
        "LL",
        key_retention="no", value_retention="no",
        intra_node_access="head_link", utilization="none",
        bloom_filters="off", zone_map_filters="off",
        fanout="unlimited",
        key_partitioning=("append", "fw"),
        sub_block_capacity=("fixed", page_capacity),
        immediate_node_links="next", skip_node_links="none", area_links="none",
        sub_block_physical_location="inline",
        sub_block_physical_layout="scatter",
        sub_blocks_homogeneous="true", sub_block_consolidation="false",
        sub_block_instantiation="lazy", links_location="scatter",
        recursion="no",
    )


def skip_list_element(page_capacity: int = 256) -> Element:
    return Element.make(
        "SL",
        key_retention="no", value_retention="no",
        intra_node_access="head_link", utilization="none",
        bloom_filters="off", zone_map_filters="both",
        filters_memory_layout="scatter",
        fanout="unlimited",
        key_partitioning=("append", "fw"),
        sub_block_capacity=("fixed", page_capacity),
        immediate_node_links="next", skip_node_links="perfect",
        area_links="none",
        sub_block_physical_location="inline",
        sub_block_physical_layout="scatter",
        sub_blocks_homogeneous="true", sub_block_consolidation="false",
        sub_block_instantiation="lazy", links_location="scatter",
        recursion="no",
    )


# ---------------------------------------------------------------------------
# Specifications: chains of elements (Appendix F notation  A -> B -> C).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DataStructureSpec:
    name: str
    chain: Tuple[Element, ...]  # root element first; last must be terminal

    def __post_init__(self) -> None:
        if not self.chain:
            raise ValueError("spec needs at least one element")
        if not self.chain[-1].terminal:
            raise ValueError("last element must be terminal")
        for el in self.chain[:-1]:
            if el.terminal:
                raise ValueError("only the last element may be terminal")

    @property
    def terminal(self) -> Element:
        return self.chain[-1]

    def describe(self) -> str:
        return " -> ".join(e.name for e in self.chain)


# -- specifications used in the paper's experiments (Appendix F) ------------
def spec_array(n_puts: int) -> DataStructureSpec:
    return DataStructureSpec(
        "Array", (unordered_data_page(capacity=max(n_puts, 1)),))


def spec_sorted_array(n_puts: int) -> DataStructureSpec:
    return DataStructureSpec(
        "SortedArray", (ordered_data_page(capacity=max(n_puts, 1)),))


def spec_linked_list(page: int = 256) -> DataStructureSpec:
    return DataStructureSpec(
        "LinkedList", (linked_list_element(page), unordered_data_page(page)))


def spec_range_partitioned_linked_list(parts: int = 100,
                                       page: int = 256) -> DataStructureSpec:
    return DataStructureSpec(
        "RangePartitionedLinkedList",
        (range_element(parts), linked_list_element(page),
         unordered_data_page(page)))


def spec_skip_list(page: int = 256) -> DataStructureSpec:
    # NOTE: Appendix F writes SL -> UDP, but the paper's own cost output
    # (G.1) binary-searches the target page — B(256) — i.e. pages behave as
    # ordered data pages.  We follow the cost output (and our ground truth).
    return DataStructureSpec(
        "SkipList", (skip_list_element(page), ordered_data_page(page)))


def spec_trie(radix: int = 256, depth: int = 8,
              page: int = 256) -> DataStructureSpec:
    return DataStructureSpec(
        "Trie", (trie_element(radix, depth), unordered_data_page(page)))


def spec_btree(fanout: int = 20, page: int = 256) -> DataStructureSpec:
    return DataStructureSpec(
        "B+Tree", (btree_internal(fanout), ordered_data_page(page)))


def spec_csb_tree(fanout: int = 20, page: int = 256) -> DataStructureSpec:
    return DataStructureSpec(
        "CSB+Tree", (csb_internal(fanout), ordered_data_page(page)))


def spec_fast(fanout: int = 16, page: int = 256) -> DataStructureSpec:
    return DataStructureSpec(
        "FAST", (fast_internal(fanout), ordered_data_page(page)))


def spec_hash_table(buckets: int = 100, page: int = 5) -> DataStructureSpec:
    return DataStructureSpec(
        "HashTable",
        (hash_element(buckets), linked_list_element(page),
         unordered_data_page(page)))


ALL_PAPER_SPECS = {
    "array": spec_array,
    "sorted_array": spec_sorted_array,
    "linked_list": spec_linked_list,
    "range_partitioned_linked_list": spec_range_partitioned_linked_list,
    "skip_list": spec_skip_list,
    "trie": spec_trie,
    "btree": spec_btree,
    "csb_tree": spec_csb_tree,
    "fast": spec_fast,
    "hash_table": spec_hash_table,
}
