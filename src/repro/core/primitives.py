"""Data layout primitives — the paper's §2 / Appendix C design space.

Each of the 21 primitives has a name, a domain of values, and (optionally)
rules that invalidate it in combination with other primitive settings.
A full assignment of primitives is an *element* (see elements.py).

Domains follow Figure 11 / Appendix C of the paper.  Parameterized values
(e.g. ``fixed(20)``) are represented as ``(tag, args...)`` tuples so that
elements are hashable and comparable.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

Value = Any  # str tag or (tag, args...) tuple


@dataclasses.dataclass(frozen=True)
class Primitive:
    """One data layout primitive and its (possibly reduced) value domain."""

    name: str
    #: canonical value tags, e.g. ("yes", "no", "func")
    tags: Tuple[str, ...]
    #: representative concrete values used for search/enumeration
    domain: Tuple[Value, ...]
    #: full-domain cardinality per the paper's accounting (Figure 11 "size")
    cardinality: int
    doc: str = ""

    def validate(self, value: Value) -> bool:
        tag = value[0] if isinstance(value, tuple) else value
        return tag in self.tags


def _p(name: str, tags: Sequence[str], domain: Sequence[Value], card: int,
       doc: str = "") -> Primitive:
    return Primitive(name, tuple(tags), tuple(domain), card, doc)


# ---------------------------------------------------------------------------
# The 21 primitives (Appendix C), with the paper's reduced-domain cardinality
# used for the design-space size accounting (Figure 11 rightmost "size" col).
# ---------------------------------------------------------------------------
PRIMITIVES: Dict[str, Primitive] = {p.name: p for p in [
    _p("key_retention", ("yes", "no", "func"), ("yes", "no", ("func", "radix")), 3,
       "Whether a node stores keys fully / not at all / partially (tries)."),
    _p("value_retention", ("yes", "no", "func"), ("yes", "no", ("func", "subset")), 3,
       "Whether a node stores values."),
    _p("key_value_layout", ("row-wise", "columnar", "col-row-groups"),
       ("row-wise", "columnar", ("col-row-groups", 64)), 102,
       "Physical layout of key-value pairs. Requires some retention."),
    _p("intra_node_access", ("direct", "head_link", "tail_link", "func"),
       ("direct", "head_link", "tail_link"), 4,
       "How sub-blocks are addressed within a node."),
    _p("utilization", ("none", ">=", "func"), ("none", (">=", 0.5)), 3,
       "Capacity utilization constraint (e.g. B+tree >=50%)."),
    _p("bloom_filters", ("off", "on"), ("off", ("on", 2, 1 << 13), ("on", 4, 1 << 16)),
       1001, "Per-sub-block bloom filters (num_hashes, num_bits)."),
    _p("zone_map_filters", ("min", "max", "both", "exact", "off"),
       ("min", "max", "both", "exact", "off"), 5,
       "Fence/zone-map filters per sub-block."),
    _p("filters_memory_layout", ("consolidate", "scatter"),
       ("consolidate", "scatter"), 2,
       "Filters contiguous for the element or scattered per sub-block. "
       "Requires bloom or zone maps on."),
    _p("fanout", ("fixed", "func", "unlimited", "terminal"),
       (("fixed", 20), ("fixed", 100), "unlimited", ("terminal", 256)), 22,
       "Sub-block count, or terminal node capacity."),
    _p("key_partitioning",
       ("append", "data-dep", "data-ind", "temporal"),
       (("append", "fw"), ("append", "bw"), ("data-dep", "sorted"),
        ("data-dep", "k-ary", 4), ("data-ind", "range", 100),
        ("data-ind", "radix", 8), ("data-ind", "func", "mod"),
        ("temporal", 10, "tier")), 406,
       "How keys map to sub-blocks / how data is ordered within the node."),
    _p("sub_block_capacity", ("fixed", "balanced", "unrestricted", "func"),
       (("fixed", 256), "balanced", "unrestricted"), 13,
       "Capacity of each sub-block. Requires fanout != terminal."),
    _p("immediate_node_links", ("next", "previous", "both", "none"),
       ("next", "previous", "both", "none"), 4,
       "Sibling links between sub-blocks."),
    _p("skip_node_links", ("perfect", "randomized", "func", "none"),
       ("perfect", ("randomized", 0.5), "none"), 13,
       "Skip links across sub-blocks (skip lists)."),
    _p("area_links", ("forward", "backward", "both", "none"),
       ("forward", "backward", "both", "none"), 4,
       "Leaf-level links across sub-trees (B+tree linked leaves)."),
    _p("sub_block_physical_location", ("inline", "pointed", "double-pointed", "none"),
       ("inline", "pointed", "double-pointed"), 4,
       "Sub-blocks inline in the parent vs pointed in heap. "
       "Requires fanout != terminal."),
    _p("sub_block_physical_layout", ("BFS", "BFS-layer", "scatter"),
       ("BFS", ("BFS-layer", 4), "scatter"), 5,
       "Physical order of sub-blocks (cache-conscious designs). "
       "Requires fanout != terminal."),
    _p("sub_blocks_homogeneous", ("true", "false"), ("true", "false"), 2,
       "All sub-blocks share one element definition. Requires non-terminal."),
    _p("sub_block_consolidation", ("true", "false"), ("true", "false"), 2,
       "Merge single children into parents. Requires non-terminal."),
    _p("sub_block_instantiation", ("lazy", "eager"), ("lazy", "eager"), 2,
       "Empty sub-blocks as null pointers (lazy) or materialized (eager)."),
    _p("links_location", ("consolidate", "scatter"), ("consolidate", "scatter"), 2,
       "Link storage. Requires some links."),
    _p("recursion", ("yes", "no"), (("yes", "logn"), ("yes", 8), "no"), 11,
       "Sub-blocks recursively use this element until max depth."),
]}


def tag_of(value: Value) -> str:
    return value[0] if isinstance(value, tuple) else value


# ---------------------------------------------------------------------------
# Invalidation rules (Figure 11 "Rules:" entries).  Each rule returns an error
# string when the combination is invalid, else None.
# ---------------------------------------------------------------------------
Rule = Callable[[Dict[str, Value]], Optional[str]]


def _rule_kv_layout(v: Dict[str, Value]) -> Optional[str]:
    if "key_value_layout" not in v:
        return None
    if tag_of(v.get("key_retention", "no")) == "no" and \
       tag_of(v.get("value_retention", "no")) == "no":
        return "key_value_layout requires key or value retention"
    return None


def _rule_filters_layout(v: Dict[str, Value]) -> Optional[str]:
    if "filters_memory_layout" not in v:
        return None
    if tag_of(v.get("bloom_filters", "off")) == "off" and \
       tag_of(v.get("zone_map_filters", "off")) == "off":
        return "filters_memory_layout requires bloom or zone map filters"
    return None


def _requires_non_terminal(name: str) -> Rule:
    def rule(v: Dict[str, Value]) -> Optional[str]:
        if name in v and tag_of(v.get("fanout", "unlimited")) == "terminal":
            return f"{name} requires fanout != terminal"
        return None
    return rule


def _rule_links_location(v: Dict[str, Value]) -> Optional[str]:
    if "links_location" not in v:
        return None
    if tag_of(v.get("immediate_node_links", "none")) == "none" and \
       tag_of(v.get("skip_node_links", "none")) == "none":
        return "links_location requires immediate or skip links"
    return None


def _rule_terminal_partitioning(v: Dict[str, Value]) -> Optional[str]:
    # terminal nodes cannot use data-independent partitioning into sub-blocks
    if tag_of(v.get("fanout", "unlimited")) == "terminal" and \
       tag_of(v.get("key_partitioning", ("append", "fw"))) == "data-ind":
        return "terminal node cannot partition data-independently into sub-blocks"
    return None


INVALIDATION_RULES: Tuple[Rule, ...] = (
    _rule_kv_layout,
    _rule_filters_layout,
    _requires_non_terminal("sub_block_capacity"),
    _requires_non_terminal("sub_block_physical_location"),
    _requires_non_terminal("sub_block_physical_layout"),
    _requires_non_terminal("sub_blocks_homogeneous"),
    _requires_non_terminal("sub_block_consolidation"),
    _requires_non_terminal("sub_block_instantiation"),
    _requires_non_terminal("recursion"),
    _rule_links_location,
    _rule_terminal_partitioning,
)


def validate_assignment(values: Dict[str, Value]) -> List[str]:
    """Return the list of invalidation errors for a primitive assignment."""
    errors: List[str] = []
    for name, value in values.items():
        prim = PRIMITIVES.get(name)
        if prim is None:
            errors.append(f"unknown primitive {name!r}")
        elif not prim.validate(value):
            errors.append(f"{name}: value {value!r} outside domain {prim.tags}")
    for rule in INVALIDATION_RULES:
        err = rule(values)
        if err:
            errors.append(err)
    return errors


def enumerate_elements(names: Sequence[str],
                       max_count: Optional[int] = None):
    """Yield valid assignments over the *reduced* domains of ``names``.

    Used by the auto-completion search (§4) to source candidate elements.
    """
    prims = [PRIMITIVES[n] for n in names]
    count = 0
    for combo in itertools.product(*(p.domain for p in prims)):
        values = dict(zip(names, combo))
        if not validate_assignment(values):
            yield values
            count += 1
            if max_count is not None and count >= max_count:
                return
