"""What-if design engine (paper §4) and workload-sweep questions.

Answers design questions by re-costing a specification under a varied
design / hardware / workload, e.g.:

* "What if we change our hardware to HW3?"
* "Would it be beneficial to add a bloom filter in all B-tree leaves?"
* "What if the workload becomes skewed?"
* "How does the best design change as the read fraction goes 0 -> 1?"

A binary question is two cost-synthesis invocations (baseline +
variation) over the same inputs, so answers arrive in
milliseconds–seconds.  All kinds run on the batched/fused engine
(:mod:`repro.core.batchcost` / :mod:`repro.core.devicecost`): design and
workload questions pack baseline and variant independently and *splice*
them into one two-design frontier (``concat_frontiers`` — repeat
questions against the same baseline reuse its cached segment instead of
re-synthesizing it), and a hardware question scores the *same* packed
frontier against both profiles — a pure device parameter-table swap with
zero re-synthesis and zero recompilation.

:func:`workload_sweep` generalizes the workload question to a whole
**design continuum** (in the spirit of *Learning Key-Value Store
Design*): a (designs x workloads) grid — read/write-ratio, skew,
selectivity or data-size axes — packed once by splicing shared template
statics with per-workload geometry columns and scored in ONE fused call
(:func:`repro.core.batchcost.cost_sweep`).  ``read_fraction_mixes``
builds the canonical read/write axis;
:func:`repro.core.autocomplete.design_continuum` runs the sweep over an
auto-completion frontier.

Pass ``engine="scalar"`` to fall back to the per-record scalar path
(``cost_workload``) — the parity oracle for tests.  :mod:`repro.serving`
serves all these question kinds concurrently, coalescing a window of
them into one fused call per hardware profile.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batchcost import (SweepPoint, concat_frontiers,
                                  cost_sweep, normalize_points,
                                  pack_frontier)
from repro.core.elements import DataStructureSpec
from repro.core.hardware import HardwareProfile
from repro.core.synthesis import Workload, cost_workload


def question_design(spec: DataStructureSpec,
                    variant: DataStructureSpec) -> str:
    return f"design {spec.describe()} -> {variant.describe()}"


def question_hardware(hw: HardwareProfile, new_hw: HardwareProfile) -> str:
    return f"hardware {hw.name} -> {new_hw.name}"


def question_workload(workload: Workload, new_workload: Workload) -> str:
    return (f"workload n={workload.n_entries},zipf={workload.zipf_alpha} -> "
            f"n={new_workload.n_entries},zipf={new_workload.zipf_alpha}")


@dataclasses.dataclass
class WhatIfAnswer:
    question: str
    baseline_seconds: float
    variant_seconds: float
    elapsed_seconds: float
    #: the scoring engine that produced the answer ("fused", "grouped",
    #: "scalar"; the serving tier retags with "fused-flat"/"grouped" when
    #: a degraded-engine fallback served it — see docs/serving.md)
    engine: str = "fused"

    @property
    def speedup(self) -> float:
        return self.baseline_seconds / max(self.variant_seconds, 1e-30)

    @property
    def beneficial(self) -> bool:
        return self.variant_seconds < self.baseline_seconds

    def summary(self) -> str:
        verdict = "beneficial" if self.beneficial else "detrimental"
        return (f"{self.question}: {verdict} "
                f"({self.baseline_seconds:.3e}s -> {self.variant_seconds:.3e}s,"
                f" {self.speedup:.2f}x, answered in {self.elapsed_seconds:.2f}s)")


def what_if_design(spec: DataStructureSpec, variant: DataStructureSpec,
                   workload: Workload, hw: HardwareProfile,
                   mix: Optional[Dict[str, float]] = None,
                   engine: str = "fused") -> WhatIfAnswer:
    """Same workload + hardware, different design (Fig. 2 leftmost input).

    Baseline and variant pack independently (each a segment-cache hit
    when asked about before) and splice into one two-design frontier — a
    single fused scoring call answers the question.
    """
    t0 = time.perf_counter()
    if engine == "scalar":
        base = cost_workload(spec, workload, hw, mix)
        var = cost_workload(variant, workload, hw, mix)
    else:
        packed = concat_frontiers([pack_frontier([spec], workload, mix),
                                   pack_frontier([variant], workload, mix)])
        base, var = packed.score(hw, engine=engine)
    return WhatIfAnswer(question_design(spec, variant),
                        float(base), float(var), time.perf_counter() - t0,
                        engine=engine)


def what_if_hardware(spec: DataStructureSpec, workload: Workload,
                     hw: HardwareProfile, new_hw: HardwareProfile,
                     mix: Optional[Dict[str, float]] = None,
                     engine: str = "fused") -> WhatIfAnswer:
    """Test new hardware without deploying to it (paper §4/§5).

    The design is packed once; each profile only swaps its device
    parameter table into the already-compiled fused scorer.
    """
    t0 = time.perf_counter()
    if engine == "scalar":
        base = cost_workload(spec, workload, hw, mix)
        var = cost_workload(spec, workload, new_hw, mix)
    else:
        packed = pack_frontier([spec], workload, mix)
        base = packed.score(hw, engine=engine)[0]
        var = packed.score(new_hw, engine=engine)[0]
    return WhatIfAnswer(question_hardware(hw, new_hw),
                        float(base), float(var), time.perf_counter() - t0,
                        engine=engine)


def what_if_workload(spec: DataStructureSpec, workload: Workload,
                     new_workload: Workload, hw: HardwareProfile,
                     mix: Optional[Dict[str, float]] = None,
                     engine: str = "fused") -> WhatIfAnswer:
    """E.g. "what if queries skew to 0.01% of the key space?".

    Packing is workload-keyed but *scoring* is workload-free, so the two
    workload variants splice into one two-design frontier and a single
    fused call answers the question — and, like the design/hardware
    questions, repeat questions against either workload hit the segment
    cache instead of re-synthesizing the spec.
    """
    t0 = time.perf_counter()
    if engine == "scalar":
        base = cost_workload(spec, workload, hw, mix)
        var = cost_workload(spec, new_workload, hw, mix)
    else:
        packed = concat_frontiers([pack_frontier([spec], workload, mix),
                                   pack_frontier([spec], new_workload, mix)])
        base, var = packed.score(hw, engine=engine)
    return WhatIfAnswer(question_workload(workload, new_workload),
                        float(base), float(var), time.perf_counter() - t0,
                        engine=engine)


def question_sweep(points: Sequence[SweepPoint], n_designs: int) -> str:
    return f"sweep {len(points)} workloads x {n_designs} designs"


@dataclasses.dataclass
class WorkloadSweepAnswer:
    """The totals grid of a (designs x workloads) sweep.

    ``totals[w, d]`` is the cost of design ``d`` under sweep point ``w``
    — the full design continuum, answered in one fused scoring call.
    """

    question: str
    specs: Tuple[DataStructureSpec, ...]
    points: Tuple[SweepPoint, ...]
    totals: np.ndarray               # [n_points, n_designs]
    elapsed_seconds: float
    #: the scoring engine that produced the grid (see WhatIfAnswer.engine)
    engine: str = "fused"

    @property
    def best_indices(self) -> np.ndarray:
        """Index of the cheapest design per sweep point (computed once)."""
        cached = self.__dict__.get("_best_indices")
        if cached is None:
            cached = np.argmin(self.totals, axis=1)
            self.__dict__["_best_indices"] = cached
        return cached

    def best(self, point: int) -> Tuple[DataStructureSpec, float]:
        d = int(self.best_indices[point])
        return self.specs[d], float(self.totals[point, d])

    def continuum(self) -> List[Tuple[SweepPoint, DataStructureSpec,
                                      float]]:
        """(point, best design, cost) per sweep point — the
        best-design-vs-workload curve."""
        return [(p, *self.best(i)) for i, p in enumerate(self.points)]

    def summary(self) -> str:
        lines = [f"{self.question} in {self.elapsed_seconds:.2f}s"]
        for (workload, mix_items), spec, cost in self.continuum():
            mix = ", ".join(f"{op}={w:g}" for op, w in mix_items)
            lines.append(
                f"  zipf={workload.zipf_alpha:g} n={workload.n_entries}"
                f" [{mix}] -> {spec.describe()} ({cost:.3e}s)")
        return "\n".join(lines)


def read_fraction_mixes(fractions: Sequence[float],
                        n_ops: float = 100.0) -> List[Dict[str, float]]:
    """The canonical read/write-ratio axis: get/update mixes totalling
    ``n_ops`` operations per sweep point."""
    return [{"get": f * n_ops, "update": (1.0 - f) * n_ops}
            for f in fractions]


def workload_sweep(specs: Sequence[DataStructureSpec],
                   workloads: Sequence[Workload], hw: HardwareProfile,
                   mixes=None, engine: str = "fused"
                   ) -> WorkloadSweepAnswer:
    """Cost every design under every workload point, as one question.

    The generalization of :func:`what_if_workload` from one (baseline,
    variant) pair to a whole grid: template statics are packed once and
    every workload contributes only its numeric geometry columns, so a
    read/write-ratio or skew sweep is answered at frontier-scoring speed
    (one fused call) instead of one packing + scoring round per point.
    ``engine="scalar"`` is the per-cell ``cost_workload`` oracle.
    """
    t0 = time.perf_counter()
    specs = tuple(specs)
    points = normalize_points(workloads, mixes)
    if engine == "scalar":
        totals = np.asarray(
            [[cost_workload(s, w, hw, dict(mix_items)) for s in specs]
             for w, mix_items in points]).reshape(len(points), len(specs))
    else:
        totals = cost_sweep(specs, [p[0] for p in points], hw,
                            [dict(p[1]) for p in points], engine=engine)
    return WorkloadSweepAnswer(question_sweep(points, len(specs)), specs,
                               points, totals,
                               time.perf_counter() - t0, engine=engine)


def add_bloom_filters(spec: DataStructureSpec, num_hashes: int = 4,
                      num_bits: int = 1 << 14) -> DataStructureSpec:
    """The paper's running example: add a bloom filter to every leaf."""
    leaf = spec.terminal.with_values(
        bloom_filters=("on", num_hashes, num_bits),
        filters_memory_layout="scatter")
    return DataStructureSpec(spec.name + "+bloom",
                             spec.chain[:-1] + (leaf,))
