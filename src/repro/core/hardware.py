"""Hardware profiles (paper §3 "hardware and data profiles").

A hardware profile is either *trained* (Level-2 cost models fitted from
micro-benchmarks run on that machine — the container CPU profile) or
*analytical* (derived from published hardware constants — used both for the
paper's what-if "new hardware" questions and for the TPU v5e target of the
distributed layer).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional

import numpy as np

from repro.core.models import FittedModel


@dataclasses.dataclass
class HardwareProfile:
    """Container for fitted Level-2 models plus descriptive constants."""

    name: str
    models: Dict[str, FittedModel]
    constants: Dict[str, float] = dataclasses.field(default_factory=dict)
    key_bytes: int = 8
    value_bytes: int = 8
    #: lazily-built device-resident parameter banks for the fused frontier
    #: scorer (:func:`repro.core.devicecost.device_table`); excluded from
    #: eq/repr and never persisted — what-if hardware questions swap this
    #: table into an already-compiled scorer with zero recompilation.
    #: ``init=False`` so ``dataclasses.replace``-derived profiles never
    #: inherit another model zoo's banks (devicecost re-checks the models
    #: identity anyway before trusting a cached table)
    _device_table: Optional[object] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def model(self, level2_name: str) -> FittedModel:
        return self.models[level2_name]

    def save(self, path: str) -> None:
        obj = {"name": self.name, "constants": self.constants,
               "key_bytes": self.key_bytes, "value_bytes": self.value_bytes,
               "models": {k: m.to_json() for k, m in self.models.items()}}
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump(obj, fh)

    @staticmethod
    def load(path: str) -> "HardwareProfile":
        with open(path) as fh:
            obj = json.load(fh)
        return HardwareProfile(
            name=obj["name"],
            models={k: FittedModel.from_json(v)
                    for k, v in obj["models"].items()},
            constants=obj.get("constants", {}),
            key_bytes=obj.get("key_bytes", 8),
            value_bytes=obj.get("value_bytes", 8))


def analytical_profile(name: str = "HW-analytical", *,
                       cpu_ns_per_cmp: float = 1.0,
                       l1_bytes: int = 32 << 10,
                       l2_bytes: int = 256 << 10,
                       l3_bytes: int = 16 << 20,
                       l1_ns: float = 1.5, l2_ns: float = 5.0,
                       l3_ns: float = 20.0, mem_ns: float = 90.0,
                       bw_bytes_per_s: float = 20e9) -> HardwareProfile:
    """Build a profile from first-principles constants (no benchmarks).

    The paper's models start out analytical before being trained; this
    constructor realizes that starting point and also lets us pose what-if
    questions about hypothetical machines (e.g. 2x memory bandwidth).
    """
    def sigmoid_cache_model(per_elem_bytes: float) -> FittedModel:
        # steps at each cache boundary, measured against region size in slots
        c = np.array([l2_ns - l1_ns, l3_ns - l2_ns, mem_ns - l3_ns],
                     dtype=np.float32) * 1e-9
        x0 = np.log(np.array([l1_bytes, l2_bytes, l3_bytes]) /
                    per_elem_bytes).astype(np.float32)
        return FittedModel("sigmoids", {
            "c": c, "k": np.full(3, 8.0, np.float32), "x0": x0,
            "y0": np.asarray(l1_ns * 1e-9, np.float32)},
            (1.0, 1e12))

    ns = 1e-9
    scan = FittedModel("linear", {
        "w": np.asarray([cpu_ns_per_cmp * ns], np.float32),
        "y0": np.asarray(5 * ns, np.float32)}, (1.0, 1e12))
    write = FittedModel("linear", {
        "w": np.asarray([16.0 / bw_bytes_per_s], np.float32),
        "y0": np.asarray(10 * ns, np.float32)}, (1.0, 1e12))
    bsearch = FittedModel("log_linear", {
        "w": np.asarray([0.0, (mem_ns / 3 + cpu_ns_per_cmp) * ns], np.float32),
        "y0": np.asarray(5 * ns, np.float32)}, (1.0, 1e12))
    isearch = FittedModel("log_loglog", {
        "w": np.asarray([0.0, 2 * cpu_ns_per_cmp * ns,
                         mem_ns / 2 * ns], np.float32),
        "y0": np.asarray(5 * ns, np.float32)}, (1.0, 1e12))
    sort = FittedModel("nlogn", {
        "w": np.asarray([cpu_ns_per_cmp * ns, 2 * cpu_ns_per_cmp * ns],
                        np.float32),
        "y0": np.asarray(20 * ns, np.float32)}, (1.0, 1e12))
    ra = sigmoid_cache_model(8.0)
    models = {
        "scalar_scan_rowstore_equal": scan,
        "scalar_scan_columnstore_equal": scan,
        "scalar_scan_columnstore_range": scan,
        "binary_search_rowstore": bsearch,
        "binary_search_columnstore": bsearch,
        "interpolation_search_columnstore": isearch,
        "hash_probe_multiply_shift": ra,
        "bloom_probe_multiply_shift": ra,
        "quicksort": sort,
        "random_memory_access": ra,
        "batched_random_memory_access": sigmoid_cache_model(64.0),
        "serial_write": write,
        "ordered_batch_write": write,
        "scattered_batch_write": ra,
    }
    return HardwareProfile(name, models, constants=dict(
        l1_bytes=l1_bytes, l2_bytes=l2_bytes, l3_bytes=l3_bytes,
        mem_ns=mem_ns, bw_bytes_per_s=bw_bytes_per_s))


# Three reference machines in the spirit of the paper's HW1..HW3 grid, used
# by the what-if benchmarks (Fig. 6 rows / §5 design questions).
def hw1() -> HardwareProfile:
    return analytical_profile("HW1", mem_ns=90.0, l3_bytes=16 << 20,
                              bw_bytes_per_s=20e9)


def hw2() -> HardwareProfile:
    return analytical_profile("HW2", mem_ns=120.0, l3_bytes=8 << 20,
                              cpu_ns_per_cmp=1.5, bw_bytes_per_s=12e9)


def hw3() -> HardwareProfile:
    return analytical_profile("HW3", mem_ns=70.0, l3_bytes=32 << 20,
                              cpu_ns_per_cmp=0.7, bw_bytes_per_s=40e9)


# ---------------------------------------------------------------------------
# TPU v5e target constants (distributed Data Calculator + roofline analysis)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TPUProfile:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    hbm_bytes: float = 16e9             # per chip
    ici_bw: float = 50e9                # bytes/s per link per direction
    ici_links_per_axis: int = 1         # 2D torus: 1 link per mesh direction
    vmem_bytes: float = 128e6
    mxu_tile: int = 128

    def compute_seconds(self, flops_per_chip: float) -> float:
        return flops_per_chip / self.peak_flops_bf16

    def memory_seconds(self, bytes_per_chip: float) -> float:
        return bytes_per_chip / self.hbm_bw

    def collective_seconds(self, bytes_per_chip: float) -> float:
        return bytes_per_chip / self.ici_bw


TPU_V5E = TPUProfile()
