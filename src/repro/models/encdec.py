"""Encoder-decoder backbone (seamless-m4t): 24L encoder + 24L decoder.

Encoder input is precomputed frame embeddings (the modality frontend is a
stub per the assignment).  Both stacks are scanned; decoder layers add
cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.parallel import ctx

Params = Dict[str, Any]


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 4)

    def enc_layer(k):
        ks = jax.random.split(k, 2)
        return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
                "mlp": L.init_mlp(ks[1], cfg)}

    def dec_layer(k):
        ks = jax.random.split(k, 3)
        return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
                "attn": L.init_attention(ks[0], cfg),
                "ln_x": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
                "xattn": L.init_attention(ks[1], cfg),
                "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
                "mlp": L.init_mlp(ks[2], cfg)}

    return {
        "embed": L.init_embed(keys[0], cfg),
        "encoder": jax.vmap(enc_layer)(
            jax.random.split(keys[1], cfg.n_encoder_layers)),
        "decoder": jax.vmap(dec_layer)(
            jax.random.split(keys[2], cfg.n_layers)),
        "enc_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
    }


def encode(params: Params, src_embeds: jax.Array, cfg: ArchConfig
           ) -> jax.Array:
    x = src_embeds.astype(cfg.cdtype())
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, layer):
        x = x + L.attention(layer["attn"],
                            L.rmsnorm(layer["ln1"], x, cfg.norm_eps),
                            cfg, positions, causal=False)
        x = x + L.mlp(layer["mlp"],
                      L.rmsnorm(layer["ln2"], x, cfg.norm_eps), cfg)
        return ctx.constrain_residual(x), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_layers(cfg, body, x, params["encoder"],
                      length=cfg.n_encoder_layers)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(layer: Params, x: jax.Array, enc_kv, cfg: ArchConfig,
               positions: jax.Array) -> jax.Array:
    x = x + L.attention(layer["attn"],
                        L.rmsnorm(layer["ln1"], x, cfg.norm_eps),
                        cfg, positions)
    x = x + L.attention(layer["xattn"],
                        L.rmsnorm(layer["ln_x"], x, cfg.norm_eps),
                        cfg, positions, kv=enc_kv)
    return ctx.constrain_residual(
        x + L.mlp(layer["mlp"],
                  L.rmsnorm(layer["ln2"], x, cfg.norm_eps), cfg))


def _cross_kv(layer: Params, enc_out: jax.Array, cfg: ArchConfig):
    dtype = cfg.cdtype()
    k = jnp.einsum("bsd,dhk->bshk", enc_out, layer["xattn"]["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, layer["xattn"]["wv"].astype(dtype))
    if "bk" in layer["xattn"]:
        k = k + layer["xattn"]["bk"].astype(dtype)
        v = v + layer["xattn"]["bv"].astype(dtype)
    return k, v


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training forward.  ``embeds`` = source frame embeds,
    ``tokens`` = target tokens."""
    assert embeds is not None, "enc-dec needs source embeddings"
    enc_out = encode(params, embeds, cfg)
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, layer):
        kv = _cross_kv(layer, enc_out, cfg)
        return _dec_layer(layer, x, kv, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_layers(cfg, body, x, params["decoder"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if hidden:
        return x, jnp.zeros((), jnp.float32)
    return L.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode: self-attn KV cache + precomputed cross-attn KV per layer
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               src_len: int = 4096) -> Params:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                       cfg.cdtype()),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                       cfg.cdtype()),
        "xk": jnp.zeros((cfg.n_layers, batch, src_len, cfg.n_kv_heads, hd),
                        cfg.cdtype()),
        "xv": jnp.zeros((cfg.n_layers, batch, src_len, cfg.n_kv_heads, hd),
                        cfg.cdtype()),
    }


def prefill_cross(params: Params, src_embeds: jax.Array, cfg: ArchConfig,
                  cache: Params) -> Params:
    enc_out = encode(params, src_embeds, cfg)

    def per_layer(layer):
        return _cross_kv(layer, enc_out, cfg)

    xk, xv = jax.vmap(per_layer)(params["decoder"])
    return dict(cache, xk=xk, xv=xv)


def decode_step(params: Params, cache: Params, token: jax.Array,
                pos: jax.Array, cfg: ArchConfig
                ) -> Tuple[jax.Array, Params]:
    x = L.embed(params["embed"], token[:, None], cfg)
    max_len = cache["k"].shape[2]
    src_len = cache["xk"].shape[2]
    dtype = cfg.cdtype()

    def body(x, inputs):
        layer, k_c, v_c, xk, xv = inputs
        h = L.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        y, k_c, v_c = L.decode_attention(layer["attn"], h, cfg, k_c, v_c,
                                         pos, max_len)
        x = x + y
        # cross attention against the precomputed encoder KV
        h = L.rmsnorm(layer["ln_x"], x, cfg.norm_eps)
        q, _, _ = L._qkv(layer["xattn"], h, cfg, pos[:, None], rope=False)
        out = L.chunked_attention(q, xk, xv, causal=False,
                                  unroll=cfg.scan_unroll)
        x = x + jnp.einsum("bshk,hkd->bsd", out,
                           layer["xattn"]["wo"].astype(dtype))
        x = x + L.mlp(layer["mlp"],
                      L.rmsnorm(layer["ln2"], x, cfg.norm_eps), cfg)
        return x, (k_c, v_c)

    x, (k_new, v_new) = L.scan_layers(
        cfg, body, x, (params["decoder"], cache["k"], cache["v"],
                       cache["xk"], cache["xv"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits[:, 0], dict(cache, k=k_new, v=v_new)
