"""State-space / recurrent blocks: Mamba2 (chunked SSD), mLSTM, sLSTM.

TPU adaptation notes (DESIGN.md §5): the Mamba2 CUDA kernel's chunked SSD
algorithm maps naturally onto the MXU — intra-chunk work is batched
[chunk x chunk] matmuls, inter-chunk work is a short ``lax.scan`` over
chunk states.  Chunk length defaults to 128 (MXU-aligned).  The same
chunked machinery drives the mLSTM (matrix-memory, per-head keys/queries);
the sLSTM is inherently sequential (its own paper says so) and runs as a
``lax.scan`` over time.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import normal, rmsnorm
from repro.parallel import ctx

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Shared chunked linear-recurrence scan:
#   h_t = exp(loga_t) h_{t-1} + B_t (x_t)^T ;  y_t = C_t . h_t
# shapes: x [b,s,h,p], B/C [b,s,h,n], loga [b,s,h]
# ---------------------------------------------------------------------------
def chunked_linear_scan(x: jax.Array, B: jax.Array, C: jax.Array,
                        loga: jax.Array, chunk: int,
                        h0: Optional[jax.Array] = None,
                        unroll: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk != 0:  # pad to a chunk multiple (masked by zero decay-in)
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        s_pad = s + pad
    else:
        s_pad = s
    nc = s_pad // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, h, n)
    Cc = C.reshape(b, nc, chunk, h, n)
    la = loga.reshape(b, nc, chunk, h).astype(jnp.float32)

    cum = jnp.cumsum(la, axis=2)                       # [b,nc,cl,h]
    total = cum[:, :, -1]                              # [b,nc,h]

    # --- intra-chunk (quadratic within chunk, like attention) -------------
    G = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc)       # [b,nc,h,cl,cl]
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    cum_t = cum.transpose(0, 1, 3, 2)                  # [b,nc,h,cl]
    decay = jnp.exp(jnp.clip(cum_t[:, :, :, :, None] -
                             cum_t[:, :, :, None, :],
                             -60.0, 0.0))               # [b,nc,h,cl,cl]
    M = (G.astype(jnp.float32) * decay *
         causal[None, None, None]).astype(x.dtype)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, xc)

    # --- chunk states ------------------------------------------------------
    w_out = jnp.exp(jnp.clip(total[:, :, None, :] - cum, -60.0, 0.0))
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp",
                        w_out.astype(x.dtype), Bc, xc)  # [b,nc,h,n,p]

    # --- inter-chunk recurrence over nc ------------------------------------
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def body(carry, inp):
        state_c, total_c = inp
        h_prev = carry
        h_new = jnp.exp(jnp.clip(total_c, -60.0, 0.0))[..., None, None] * \
            h_prev + state_c.astype(jnp.float32)
        return h_new, h_prev

    # probe unroll capped at 32 bodies: the inter-chunk recurrence is a few
    # elementwise ops per chunk (negligible FLOPs next to the fully-counted
    # intra-chunk matmuls), and a 500k-token probe would otherwise unroll
    # 4096 bodies per layer (compile blow-up)
    h_final, h_prevs = jax.lax.scan(
        body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)),
        unroll=min(nc, 32) if unroll else 1)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # [b,nc,h,n,p]

    # --- inter-chunk contribution ------------------------------------------
    w_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))
    y_inter = jnp.einsum("bcihn,bchnp,bcih->bcihp", Cc,
                         h_prevs.astype(x.dtype),
                         w_in.astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, s_pad, h, p)[:, :s]
    return y, h_final


def linear_scan_step(h: jax.Array, x: jax.Array, B: jax.Array, C: jax.Array,
                     loga: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. h [b,hh,n,p]; x [b,hh,p]; B/C [b,hh,n]; loga [b,hh]."""
    decay = jnp.exp(jnp.clip(loga, -60.0, 0.0))[..., None, None]
    h = decay * h.astype(jnp.float32) + jnp.einsum(
        "bhn,bhp->bhnp", B, x).astype(jnp.float32)
    y = jnp.einsum("bhn,bhnp->bhp", C.astype(jnp.float32), h)
    return h, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba2_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    headdim = 64
    n_heads = d_in // headdim
    return d_in, headdim, n_heads, cfg.ssm_state


CONV_WIDTH = 4


def init_mamba2(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, hd, h, n = mamba2_dims(cfg)
    conv_dim = d_in + 2 * n
    keys = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    return {
        # projections to z (gate), x, B, C, dt
        "in_proj": normal(keys[0], (d, 2 * d_in + 2 * n + h), scale,
                          cfg.pdtype()),
        "conv_w": normal(keys[1], (CONV_WIDTH, conv_dim), 0.1, cfg.pdtype()),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype()),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), cfg.pdtype()),
        "out_proj": normal(keys[2], (d_in, d),
                           1.0 / math.sqrt(2 * d_in * cfg.n_layers),
                           cfg.pdtype()),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x [b,s,c]; w [W,c]; state [b,W-1,c]."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(width))
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(out + b[None, None]), new_state


def _mamba2_project(params: Params, x: jax.Array, cfg: ArchConfig):
    d_in, hd, h, n = mamba2_dims(cfg)
    dtype = cfg.cdtype()
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dtype))
    z, xin, Bv, Cv, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return ctx.constrain_ffn(z), ctx.constrain_ffn(xin), Bv, Cv, dt


def mamba2_forward(params: Params, x: jax.Array, cfg: ArchConfig
                   ) -> jax.Array:
    b, s, _ = x.shape
    d_in, hd, h, n = mamba2_dims(cfg)
    dtype = cfg.cdtype()
    z, xin, Bv, Cv, dt = _mamba2_project(params, x, cfg)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    conv_out, _ = _causal_conv(conv_in, params["conv_w"].astype(dtype),
                               params["conv_b"].astype(dtype))
    xin, Bv, Cv = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None])      # [b,s,h]
    a = -jnp.exp(params["A_log"])                            # [h]
    loga = dt * a[None, None]
    xh = xin.reshape(b, s, h, hd) * dt[..., None].astype(dtype)
    Bh = jnp.broadcast_to(Bv[:, :, None, :], (b, s, h, n)).astype(dtype)
    Ch = jnp.broadcast_to(Cv[:, :, None, :], (b, s, h, n)).astype(dtype)
    y, _ = chunked_linear_scan(xh, Bh, Ch, loga, cfg.ssm_chunk,
                               unroll=cfg.scan_unroll)
    y = y + params["D_skip"][None, None, :, None].astype(dtype) * \
        xin.reshape(b, s, h, hd)
    y = y.reshape(b, s, d_in) * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dtype))


def mamba2_init_state(cfg: ArchConfig, batch: int) -> Params:
    d_in, hd, h, n = mamba2_dims(cfg)
    conv_dim = d_in + 2 * n
    return {"h": jnp.zeros((batch, h, n, hd), jnp.float32),
            "conv": jnp.zeros((batch, CONV_WIDTH - 1, conv_dim),
                              cfg.cdtype())}


def mamba2_step(params: Params, x: jax.Array, state: Params,
                cfg: ArchConfig) -> Tuple[jax.Array, Params]:
    """One-token decode. x [b,1,d]."""
    b = x.shape[0]
    d_in, hd, h, n = mamba2_dims(cfg)
    dtype = cfg.cdtype()
    z, xin, Bv, Cv, dt = _mamba2_project(params, x, cfg)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, params["conv_w"].astype(dtype),
        params["conv_b"].astype(dtype), state["conv"])
    xin, Bv, Cv = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                         params["dt_bias"][None])            # [b,h]
    a = -jnp.exp(params["A_log"])
    loga = dt * a[None]
    xh = xin[:, 0].reshape(b, h, hd) * dt[..., None].astype(dtype)
    Bh = jnp.broadcast_to(Bv[:, 0, None, :], (b, h, n)).astype(dtype)
    Ch = jnp.broadcast_to(Cv[:, 0, None, :], (b, h, n)).astype(dtype)
    h_new, y = linear_scan_step(state["h"], xh, Bh, Ch, loga)
    y = y + params["D_skip"][None, :, None].astype(dtype) * \
        xin[:, 0].reshape(b, h, hd)
    y = y.reshape(b, 1, d_in) * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dtype))
    return out, {"h": h_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory C += i v k^T with forget decay
# ---------------------------------------------------------------------------
def xlstm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd = d_in // h
    return d_in, h, hd


def init_mlstm(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, h, hd = xlstm_dims(cfg)
    keys = jax.random.split(key, 7)
    scale = 1.0 / math.sqrt(d)
    return {
        "up_proj": normal(keys[0], (d, 2 * d_in), scale, cfg.pdtype()),
        "wq": normal(keys[1], (d_in, h, hd), 1 / math.sqrt(d_in),
                     cfg.pdtype()),
        "wk": normal(keys[2], (d_in, h, hd), 1 / math.sqrt(d_in),
                     cfg.pdtype()),
        "wv": normal(keys[3], (d_in, h, hd), 1 / math.sqrt(d_in),
                     cfg.pdtype()),
        "w_igate": normal(keys[4], (d_in, h), 1 / math.sqrt(d_in),
                          jnp.float32),
        "w_fgate": normal(keys[5], (d_in, h), 1 / math.sqrt(d_in),
                          jnp.float32),
        "fgate_bias": jnp.full((h,), 3.0, jnp.float32),  # open at init
        "norm_scale": jnp.ones((d_in,), cfg.pdtype()),
        "down_proj": normal(keys[6], (d_in, d),
                            1 / math.sqrt(2 * d_in * cfg.n_layers),
                            cfg.pdtype()),
    }


def _mlstm_gates(params: Params, xu: jax.Array):
    """Stabilized gating: sigmoid forget in log space, exp input gate folded
    into the key scaling (chunk-stable simplification of xLSTM eq. 19-27)."""
    logf = jax.nn.log_sigmoid(
        xu.astype(jnp.float32) @ params["w_fgate"] +
        params["fgate_bias"][None, None])                   # [b,s,h] < 0
    igate = jax.nn.sigmoid(xu.astype(jnp.float32) @ params["w_igate"])
    return logf, igate


def mlstm_forward(params: Params, x: jax.Array, cfg: ArchConfig
                  ) -> jax.Array:
    b, s, _ = x.shape
    d_in, h, hd = xlstm_dims(cfg)
    dtype = cfg.cdtype()
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dtype))
    xu, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", xu, params["wq"].astype(dtype))
    k = jnp.einsum("bse,ehk->bshk", xu, params["wk"].astype(dtype)) / \
        math.sqrt(hd)
    v = jnp.einsum("bse,ehk->bshk", xu, params["wv"].astype(dtype))
    logf, igate = _mlstm_gates(params, xu)
    k = k * igate[..., None].astype(dtype)
    y, _ = chunked_linear_scan(v, k, q, logf, cfg.ssm_chunk,
                               unroll=cfg.scan_unroll)
    y = y.reshape(b, s, d_in) * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(dtype))


def mlstm_init_state(cfg: ArchConfig, batch: int) -> jax.Array:
    d_in, h, hd = xlstm_dims(cfg)
    return jnp.zeros((batch, h, hd, hd), jnp.float32)


def mlstm_step(params: Params, x: jax.Array, state: jax.Array,
               cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    b = x.shape[0]
    d_in, h, hd = xlstm_dims(cfg)
    dtype = cfg.cdtype()
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dtype))
    xu, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", xu, params["wq"].astype(dtype))[:, 0]
    k = jnp.einsum("bse,ehk->bshk", xu, params["wk"].astype(dtype))[:, 0] / \
        math.sqrt(hd)
    v = jnp.einsum("bse,ehk->bshk", xu, params["wv"].astype(dtype))[:, 0]
    logf, igate = _mlstm_gates(params, xu)
    k = k * igate[:, 0][..., None].astype(dtype)
    state, y = linear_scan_step(state, v, k, q, logf[:, 0])
    y = y.reshape(b, 1, d_in) * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y,
                      params["down_proj"].astype(dtype)), state


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, sequential over time
# ---------------------------------------------------------------------------
def init_slstm(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    keys = jax.random.split(key, 3)
    return {
        "w_in": normal(keys[0], (d, 4, h, hd), 1 / math.sqrt(d),
                       cfg.pdtype()),
        "r": normal(keys[1], (4, h, hd, hd), 1 / math.sqrt(hd),
                    cfg.pdtype()),
        "bias": jnp.zeros((4, h, hd), jnp.float32),
        "norm_scale": jnp.ones((d,), cfg.pdtype()),
        "out_proj": normal(keys[2], (d, d),
                           1 / math.sqrt(2 * d * cfg.n_layers),
                           cfg.pdtype()),
    }


def slstm_init_state(cfg: ArchConfig, batch: int) -> Params:
    h = cfg.n_heads
    hd = cfg.d_model // h
    zero = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": zero, "n": zero, "hid": zero,
            "m": jnp.zeros((batch, h, hd), jnp.float32)}


def _slstm_cell(params: Params, xt: jax.Array, state: Params
                ) -> Tuple[Params, jax.Array]:
    """xt: [b, 4, h, hd] pre-activation from input projection."""
    c, n, hid, m = state["c"], state["n"], state["hid"], state["m"]
    rec = jnp.einsum("bhk,ghkl->bghl", hid.astype(params["r"].dtype),
                     params["r"]).astype(jnp.float32)
    pre = xt.astype(jnp.float32) + rec + params["bias"][None]
    zt = jnp.tanh(pre[:, 0])
    it = pre[:, 1]
    ft = pre[:, 2]
    ot = jax.nn.sigmoid(pre[:, 3])
    # stabilized exponential gating
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    hid_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return {"c": c_new, "n": n_new, "hid": hid_new, "m": m_new}, hid_new


def slstm_forward(params: Params, x: jax.Array, cfg: ArchConfig
                  ) -> jax.Array:
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    dtype = cfg.cdtype()
    xt = jnp.einsum("bsd,dghk->bsghk", x, params["w_in"].astype(dtype))
    state = slstm_init_state(cfg, b)

    def body(state, x_step):
        state, out = _slstm_cell(params, x_step, state)
        return state, out

    _, outs = jax.lax.scan(body, state, jnp.moveaxis(xt, 1, 0))
    y = jnp.moveaxis(outs, 0, 1).reshape(b, s, d).astype(dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(dtype))


def slstm_step(params: Params, x: jax.Array, state: Params,
               cfg: ArchConfig) -> Tuple[jax.Array, Params]:
    b, _, d = x.shape
    dtype = cfg.cdtype()
    xt = jnp.einsum("bsd,dghk->bsghk", x, params["w_in"].astype(dtype))[:, 0]
    state, out = _slstm_cell(params, xt, state)
    y = out.reshape(b, 1, d).astype(dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y,
                      params["out_proj"].astype(dtype)), state
