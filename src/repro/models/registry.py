"""Model registry: family dispatch for init / forward / decode.

The single entry point the trainer, server and dry-run use:

    model = registry.build(cfg)
    params = model.init(rng)
    logits, aux = model.forward(params, tokens, embeds=...)
    cache = model.init_cache(batch, max_len)
    logits, cache = model.decode_step(params, cache, token, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, transformer, xlstm

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    _init: Callable
    _forward: Callable
    _init_cache: Callable
    _decode_step: Callable
    _prefill: Optional[Callable] = None

    def init(self, rng) -> Params:
        return self._init(rng, self.cfg)

    def init_abstract(self, rng=None) -> Params:
        """Shapes without allocation (dry-run path)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self._init(k, self.cfg), rng)

    def forward(self, params, tokens, embeds=None, hidden=False
                ) -> Tuple[jax.Array, jax.Array]:
        return self._forward(params, tokens, self.cfg, embeds=embeds,
                             hidden=hidden)

    def init_cache(self, batch: int, max_len: int, **kw) -> Params:
        return self._init_cache(self.cfg, batch, max_len, **kw)

    def decode_step(self, params, cache, token, pos):
        return self._decode_step(params, cache, token, pos, self.cfg)

    def prefill(self, params, tokens, max_len, embeds=None):
        assert self._prefill is not None
        return self._prefill(params, tokens, self.cfg, max_len,
                             embeds=embeds)


def build(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(cfg, transformer.init_params, transformer.forward,
                     transformer.init_cache, transformer.decode_step,
                     transformer.prefill)
    if cfg.family == "hybrid":
        return Model(cfg, hybrid.init_params, hybrid.forward,
                     hybrid.init_cache, hybrid.decode_step)
    if cfg.family == "ssm":
        return Model(cfg, xlstm.init_params, xlstm.forward,
                     xlstm.init_cache, xlstm.decode_step)
    if cfg.family == "audio":
        return Model(cfg, encdec.init_params, encdec.forward,
                     encdec.init_cache, encdec.decode_step)
    raise ValueError(f"unknown family {cfg.family!r}")
