"""Shared building blocks: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure-functional JAX: params are nested dicts of arrays; every forward is a
function of (params, inputs).  Layer stacks are scanned with stacked
params (leading layer axis) for small HLO and fast 512-device compiles.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel import ctx

Params = Dict[str, Any]


def normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def scan_layers(cfg: ArchConfig, body, init, xs, length: Optional[int] = None):
    """lax.scan over the layer stack; fully unrolled for dry-run cost probes
    (XLA HloCostAnalysis counts while bodies once — see launch/dryrun.py)."""
    n = length if length is not None else cfg.n_layers
    unroll = n if cfg.scan_unroll else 1
    return jax.lax.scan(body, init, xs, unroll=max(unroll, 1))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style rotate-half)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, head_dim]; positions: broadcastable to [..., S]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                    # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..,S,hd/2]
    cos = jnp.cos(angles)[..., None, :]                          # [..,S,1,hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (train/prefill full-sequence path + one-token decode path)
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ArchConfig, d_model: Optional[int] = None
                   ) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    keys = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    out_scale = 1.0 / math.sqrt(h * hd * 2 * cfg.n_layers)
    params = {
        "wq": normal(keys[0], (d, h, hd), scale, cfg.pdtype()),
        "wk": normal(keys[1], (d, k, hd), scale, cfg.pdtype()),
        "wv": normal(keys[2], (d, k, hd), scale, cfg.pdtype()),
        "wo": normal(keys[3], (h, hd, d), out_scale, cfg.pdtype()),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h, hd), cfg.pdtype())
        params["bk"] = jnp.zeros((k, hd), cfg.pdtype())
        params["bv"] = jnp.zeros((k, hd), cfg.pdtype())
    return params


def _qkv(params: Params, x: jax.Array, cfg: ArchConfig,
         positions: jax.Array, rope: bool = True
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dtype = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return (ctx.constrain_heads(q), ctx.constrain_heads(k),
            ctx.constrain_heads(v))


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      q_offset: int = 0, unroll: bool = False) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (O(S·chunk) memory).

    q: [B, Sq, H, hd]; k/v: [B, Skv, K, hd] with H % K == 0.  This is both
    the dry-run lowering path (bounded HBM temps at 32k+ context) and the
    oracle for the Pallas kernel (kernels/flash_attention/ref.py wraps it).
    """
    b, sq, h, hd = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / math.sqrt(hd)
    q = q.reshape(b, sq, kh, g, hd) * scale

    if unroll:
        # dry-run cost probes: HloCostAnalysis counts while bodies once, so
        # the scans below must be unrolled — but the *algorithm* must stay
        # chunked (a one-shot S^2 softmax would charge quadratic HBM bytes
        # the real pipeline never moves).  Cap the body count at ~8x8 by
        # widening chunks for long sequences.
        q_chunk = max(q_chunk, sq // 8)
        kv_chunk = max(kv_chunk, skv // 8)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = sq // q_chunk if sq % q_chunk == 0 else -1
    nkv = skv // kv_chunk if skv % kv_chunk == 0 else -1
    if nq < 0 or nkv < 0:  # ragged fallback (tests with odd lengths)
        scores = jnp.einsum("bikgh,bjkh->bkgij", q, k).astype(jnp.float32)
        if causal:
            qi = jnp.arange(sq)[:, None] + q_offset
            kj = jnp.arange(skv)[None, :]
            scores = jnp.where(qi >= kj, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgij,bjkh->bikgh", probs, v)
        return out.reshape(b, sq, h, hd)

    qc = q.reshape(b, nq, q_chunk, kh, g, hd)
    kc = k.reshape(b, nkv, kv_chunk, kh, hd)
    vc = v.reshape(b, nkv, kv_chunk, kh, hd)

    def per_q_chunk(qi, q_blk):
        # online softmax over kv chunks
        acc0 = jnp.zeros((b, q_chunk, kh, g, hd), jnp.float32)
        m0 = jnp.full((b, q_chunk, kh, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kh, g), jnp.float32)

        def body(carry, inputs):
            acc, m, l = carry
            kj, k_blk, v_blk = inputs
            s = jnp.einsum("bikgh,bjkh->bikgj", q_blk,
                           k_blk).astype(jnp.float32)
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
                kpos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
                mask = qpos >= kpos
                s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bikgj,bjkh->bikgh", p.astype(v_blk.dtype),
                v_blk).astype(jnp.float32)
            return (acc, m_new, l), None

        ks = jnp.arange(nkv)
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (ks, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
            unroll=nkv if unroll else 1)
        return acc / jnp.maximum(l[..., None], 1e-30)

    def outer(_, args):
        return None, per_q_chunk(*args)

    _, out = jax.lax.scan(outer, None,
                          (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)),
                          unroll=nq if unroll else 1)
    out = jnp.moveaxis(out, 0, 1)  # [B, nq, qc, kh, g, hd]
    return out.reshape(b, sq, h, hd).astype(v.dtype)


def attention(params: Params, x: jax.Array, cfg: ArchConfig,
              positions: jax.Array, causal: bool = True,
              kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              rope: bool = True) -> jax.Array:
    """Full-sequence attention. ``kv`` overrides keys/values (cross-attn)."""
    dtype = cfg.cdtype()
    q, k, v = _qkv(params, x, cfg, positions, rope=rope)
    if kv is not None:
        k, v = kv
        causal = False
    if cfg.attn_impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention_bshd
        out = flash_attention_bshd(q, k, v, causal=causal)
    elif cfg.attn_impl == "skip":
        # §Perf ablation probe: identity in place of the score/PV chain —
        # the bytes/FLOPs delta vs "xla" measures the attention-internal
        # HBM traffic a VMEM-resident flash kernel eliminates
        out = q
    else:
        out = chunked_attention(q, k, v, causal=causal,
                                unroll=cfg.scan_unroll)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def decode_attention(params: Params, x: jax.Array, cfg: ArchConfig,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, cache_len: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: x [B, 1, D]; caches [B, S, K, hd]; pos [B]."""
    dtype = cfg.cdtype()
    q, k, v = _qkv(params, x, cfg, pos[:, None])
    # insert new kv at per-batch position
    b = x.shape[0]
    k_cache = _scatter_time(k_cache, k, pos)
    v_cache = _scatter_time(v_cache, v, pos)
    h, kh = cfg.n_heads, cfg.n_kv_heads
    g = h // kh
    hd = cfg.resolved_head_dim
    qg = q.reshape(b, 1, kh, g, hd) / math.sqrt(hd)
    scores = jnp.einsum("bikgh,bjkh->bkgij", qg,
                        k_cache.astype(dtype)).astype(jnp.float32)
    t = jnp.arange(cache_len)
    mask = t[None, :] <= pos[:, None]                     # [B, S]
    scores = jnp.where(mask[:, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgij,bjkh->bikgh", probs, v_cache.astype(dtype))
    out = out.reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, k_cache, v_cache


def _scatter_time(cache: jax.Array, new: jax.Array, pos: jax.Array
                  ) -> jax.Array:
    """cache [B,S,...] <- new [B,1,...] at per-batch position ``pos``."""
    s = cache.shape[1]
    onehot = jax.nn.one_hot(pos, s, dtype=cache.dtype)    # [B, S]
    onehot = onehot.reshape(onehot.shape + (1,) * (cache.ndim - 2))
    return cache * (1 - onehot) + new.astype(cache.dtype) * onehot


# ---------------------------------------------------------------------------
# SwiGLU MLP (and plain MLP when d_ff holds GELU stacks — seamless uses GLU
# too in practice; we use SwiGLU uniformly, noted in DESIGN.md)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(d)
    out_scale = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    return {
        "w_gate": normal(keys[0], (d, f), scale, cfg.pdtype()),
        "w_up": normal(keys[1], (d, f), scale, cfg.pdtype()),
        "w_down": normal(keys[2], (f, d), out_scale, cfg.pdtype()),
    }


def mlp(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dtype = cfg.cdtype()
    gate = ctx.constrain_ffn(
        jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype)))
    up = ctx.constrain_ffn(
        jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dtype)))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up,
                      params["w_down"].astype(dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 2)
    params = {"tok": normal(keys[0], (cfg.vocab_size, cfg.d_model), 0.02,
                            cfg.pdtype())}
    if not cfg.tie_embeddings:
        params["head"] = normal(keys[1], (cfg.d_model, cfg.vocab_size),
                                1.0 / math.sqrt(cfg.d_model), cfg.pdtype())
    return params


def embed(params: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    return params["tok"].astype(cfg.cdtype())[tokens]


def unembed(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dtype = cfg.cdtype()
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["tok"].astype(dtype))
    return jnp.einsum("bsd,dv->bsv", x, params["head"].astype(dtype))
