"""Zamba2-style hybrid: Mamba2 backbone + weight-shared attention block.

The Mamba2 layers are scanned with stacked params; the single shared
attention+MLP block (one param set, Zamba2's signature design) is applied
every ``shared_attn_every`` layers via ``lax.cond`` inside the scan —
weights are loop-invariant, so SPMD sharding sees one copy.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.parallel import ctx

Params = Dict[str, Any]


def n_shared_applications(cfg: ArchConfig) -> int:
    k = max(cfg.shared_attn_every, 1)
    return (cfg.n_layers + k - 1) // k


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 5)
    stacked = jax.vmap(lambda k: {
        "ln": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
        "mamba": S.init_mamba2(k, cfg),
    })(jax.random.split(keys[0], cfg.n_layers))
    shared = {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
        "attn": L.init_attention(keys[1], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
        "mlp": L.init_mlp(keys[2], cfg),
    }
    return {
        "embed": L.init_embed(keys[3], cfg),
        "layers": stacked,
        "shared": shared,
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
    }


def _shared_block(shared: Params, x: jax.Array, cfg: ArchConfig,
                  positions: jax.Array) -> jax.Array:
    x = x + L.attention(shared["attn"],
                        L.rmsnorm(shared["ln1"], x, cfg.norm_eps),
                        cfg, positions)
    return x + L.mlp(shared["mlp"],
                     L.rmsnorm(shared["ln2"], x, cfg.norm_eps), cfg)


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    x = L.embed(params["embed"], tokens, cfg) if embeds is None else \
        embeds.astype(cfg.cdtype())
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    shared = params["shared"]
    k = max(cfg.shared_attn_every, 1)

    def body(carry, inputs):
        x = carry
        i, layer = inputs
        x = x + S.mamba2_forward(layer["mamba"],
                                 L.rmsnorm(layer["ln"], x, cfg.norm_eps),
                                 cfg)
        x = jax.lax.cond(i % k == 0,
                         lambda x: _shared_block(shared, x, cfg, positions),
                         lambda x: x, x)
        return ctx.constrain_residual(x), jnp.zeros((), jnp.float32)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_layers(cfg, body, x,
                         (jnp.arange(cfg.n_layers), params["layers"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if hidden:
        return x, jnp.zeros((), jnp.float32)
    return L.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode: python-unrolled layer loop (heterogeneous per-layer state).
# Mamba states are O(1) in context; only the shared-attn applications carry
# KV caches ([n_apps, B, S, K, hd] — sequence dim shardable for long_500k).
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    hd = cfg.resolved_head_dim
    n_apps = n_shared_applications(cfg)
    mamba_states = jax.vmap(lambda _: S.mamba2_init_state(cfg, batch))(
        jnp.arange(cfg.n_layers))
    return {
        "mamba": mamba_states,
        "k": jnp.zeros((n_apps, batch, max_len, cfg.n_kv_heads, hd),
                       cfg.cdtype()),
        "v": jnp.zeros((n_apps, batch, max_len, cfg.n_kv_heads, hd),
                       cfg.cdtype()),
    }


def decode_step(params: Params, cache: Params, token: jax.Array,
                pos: jax.Array, cfg: ArchConfig
                ) -> Tuple[jax.Array, Params]:
    x = L.embed(params["embed"], token[:, None], cfg)
    max_len = cache["k"].shape[2]
    k_mamba = max(cfg.shared_attn_every, 1)
    shared = params["shared"]
    new_mamba: List[Params] = []
    k_caches, v_caches = [], []
    app = 0
    for i in range(cfg.n_layers):
        layer = jax.tree.map(lambda p, i=i: p[i], params["layers"])
        state = jax.tree.map(lambda p, i=i: p[i], cache["mamba"])
        h = L.rmsnorm(layer["ln"], x, cfg.norm_eps)
        y, state = S.mamba2_step(layer["mamba"], h, state, cfg)
        x = x + y
        new_mamba.append(state)
        if i % k_mamba == 0:
            h = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
            y, k_new, v_new = L.decode_attention(
                shared["attn"], h, cfg, cache["k"][app], cache["v"][app],
                pos, max_len)
            x = x + y
            x = x + L.mlp(shared["mlp"],
                          L.rmsnorm(shared["ln2"], x, cfg.norm_eps), cfg)
            k_caches.append(k_new)
            v_caches.append(v_new)
            app += 1
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
        "k": jnp.stack(k_caches),
        "v": jnp.stack(v_caches),
    }
    return logits[:, 0], new_cache
