"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

Gather/scatter dispatch (sort-free): per expert, the assigned token ids are
extracted with a top-capacity selection, tokens gathered to [E, C, D],
expert FFNs applied batched over the expert axis (shardable along the
"model"/expert axis = EP), and outputs combined with a scatter-add weighted
by the router gates.  FLOPs match the active-parameter count
(top_k · d · d_ff per token), unlike dense all-experts compute.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import normal
from repro.parallel import ctx

Params = Dict[str, Any]


def init_moe(key, cfg: ArchConfig) -> Params:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    keys = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    out_scale = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    return {
        "router": normal(keys[0], (d, e), scale, jnp.float32),
        "w_gate": normal(keys[1], (e, d, f), scale, cfg.pdtype()),
        "w_up": normal(keys[2], (e, d, f), scale, cfg.pdtype()),
        "w_down": normal(keys[3], (e, f, d), out_scale, cfg.pdtype()),
    }


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    moe = cfg.moe
    c = int(math.ceil(n_tokens * moe.top_k * moe.capacity_factor /
                      moe.n_experts))
    return max(min(c, n_tokens), 1)


def moe_ffn(params: Params, x: jax.Array, cfg: ArchConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss). Capacity-dropped tokens pass through
    (residual connection supplies identity)."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.n_experts, moe.top_k
    c = capacity(t, cfg)
    dtype = cfg.cdtype()
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # assignment mask and per-expert gate weight
    assign = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # [T, k, E]
    mask = assign.sum(axis=1)                                  # [T, E]
    combine_w = (assign * gate_vals[..., None]).sum(axis=1)    # [T, E]

    # load-balancing auxiliary loss (Switch-style)
    density = mask.mean(axis=0)
    density_proxy = probs.mean(axis=0)
    aux_loss = (density * density_proxy).sum() * (e ** 2) / e

    # top-C token selection per expert: rank tokens by assignment priority
    # (mask desc, then token order) — one argsort of [E, T]
    priority = mask.T * (t * 2.0) - jnp.arange(t, dtype=jnp.float32)[None, :]
    token_ids = jax.lax.top_k(priority, c)[1]                  # [E, C]
    gathered_mask = jnp.take_along_axis(mask.T, token_ids, axis=1)  # [E, C]

    xg = xt[token_ids.reshape(-1)].reshape(e, c, d).astype(dtype)
    xg = xg * gathered_mask[..., None].astype(dtype)           # zero padding
    # expert-parallel dispatch: [E, C, D] sharded on the expert axis — XLA
    # lowers the gather/scatter across it to the MoE all-to-all pattern
    xg = ctx.constrain_experts(xg)
    gate = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", xg, params["w_up"].astype(dtype))
    out = ctx.constrain_experts(
        jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                   params["w_down"].astype(dtype)))

    # combine: scatter-add expert outputs weighted by router gates
    w = jnp.take_along_axis(combine_w.T, token_ids, axis=1)    # [E, C]
    flat_out = (out * w[..., None].astype(dtype)).reshape(e * c, d)
    y = jnp.zeros((t, d), dtype).at[token_ids.reshape(-1)].add(
        flat_out, mode="drop")
    return y.reshape(b, s, d), aux_loss.astype(jnp.float32)
