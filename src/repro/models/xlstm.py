"""xLSTM language model: alternating mLSTM / sLSTM blocks.

Blocks are heterogeneous (every ``slstm_every``-th is an sLSTM), so the
stack is python-unrolled with per-layer param dicts rather than scanned.
mLSTM state is a constant-size matrix memory => long_500k decode applies.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.parallel import ctx

Params = Dict[str, Any]


def layer_kinds(cfg: ArchConfig) -> List[str]:
    k = cfg.slstm_every
    return ["slstm" if (k > 0 and (i + 1) % k == 0) else "mlstm"
            for i in range(cfg.n_layers)]


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    for i, kind in enumerate(layer_kinds(cfg)):
        ln = L.init_rmsnorm(cfg.d_model, cfg.pdtype())
        if kind == "slstm":
            blocks.append({"ln": ln, "slstm": S.init_slstm(keys[i], cfg)})
        else:
            blocks.append({"ln": ln, "mlstm": S.init_mlstm(keys[i], cfg)})
    return {
        "embed": L.init_embed(keys[-2], cfg),
        "blocks": tuple(blocks),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
    }


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    x = L.embed(params["embed"], tokens, cfg) if embeds is None else \
        embeds.astype(cfg.cdtype())

    def block_fn(block, x):
        h = L.rmsnorm(block["ln"], x, cfg.norm_eps)
        if "slstm" in block:
            return ctx.constrain_residual(
                x + S.slstm_forward(block["slstm"], h, cfg))
        return ctx.constrain_residual(
            x + S.mlstm_forward(block["mlstm"], h, cfg))

    for block in params["blocks"]:
        if cfg.remat:
            x = jax.checkpoint(block_fn)(block, x)
        else:
            x = block_fn(block, x)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if hidden:
        return x, jnp.zeros((), jnp.float32)
    return L.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    states = []
    for kind in layer_kinds(cfg):
        if kind == "slstm":
            states.append(S.slstm_init_state(cfg, batch))
        else:
            states.append(S.mlstm_init_state(cfg, batch))
    return {"states": tuple(states)}


def decode_step(params: Params, cache: Params, token: jax.Array,
                pos: jax.Array, cfg: ArchConfig
                ) -> Tuple[jax.Array, Params]:
    x = L.embed(params["embed"], token[:, None], cfg)
    new_states = []
    for block, state in zip(params["blocks"], cache["states"]):
        h = L.rmsnorm(block["ln"], x, cfg.norm_eps)
        if "slstm" in block:
            y, state = S.slstm_step(block["slstm"], h, state, cfg)
        else:
            y, state = S.mlstm_step(block["mlstm"], h, state, cfg)
        x = x + y
        new_states.append(state)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits[:, 0], {"states": tuple(new_states)}
