"""Decoder-only transformer (dense / MoE / VLM backbone).

Layers are scanned with stacked params (leading layer axis): small HLO,
fast 512-device SPMD compiles, and one large leaf per weight matrix for
FSDP sharding.  ``remat`` wraps the layer body with jax.checkpoint.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.parallel import ctx

Params = Dict[str, Any]


def _layer_keys(key, n: int):
    return jax.random.split(key, n)


def init_layer(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 2)
    params = {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
        "attn": L.init_attention(keys[0], cfg),
    }
    if cfg.moe:
        params["moe"] = M.init_moe(keys[1], cfg)
    else:
        params["mlp"] = L.init_mlp(keys[1], cfg)
    return params


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(
        _layer_keys(keys[0], cfg.n_layers))
    return {
        "embed": L.init_embed(keys[1], cfg),
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype()),
    }


def layer_forward(layer: Params, x: jax.Array, cfg: ArchConfig,
                  positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    x = ctx.constrain_residual(
        x + L.attention(layer["attn"], L.rmsnorm(layer["ln1"], x,
                                                 cfg.norm_eps),
                        cfg, positions))
    h = L.rmsnorm(layer["ln2"], x, cfg.norm_eps)
    if cfg.moe:
        y, aux = M.moe_ffn(layer["moe"], h, cfg)
    else:
        y = L.mlp(layer["mlp"], h, cfg)
    return ctx.constrain_residual(x + y), aux


def forward(params: Params, tokens: Optional[jax.Array], cfg: ArchConfig,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward: returns (logits [B,S,V], aux_loss).

    ``hidden=True`` returns the post-final-norm hidden states instead of
    logits — the trainer's chunked cross-entropy path, which never
    materializes the [B,S,V] logits tensor (at 405B/128k-vocab scale the
    full logits are ~1 TB/chip of temps; see EXPERIMENTS.md §Perf)."""
    if embeds is None:
        x = L.embed(params["embed"], tokens, cfg)
    else:
        x = embeds.astype(cfg.cdtype())
        if tokens is not None:  # VLM: patch embeds ++ token embeds
            x = jnp.concatenate(
                [x, L.embed(params["embed"], tokens, cfg)], axis=1)
    b, s, _ = x.shape
    x = ctx.constrain_residual(x)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, layer):
        x, aux = layer_forward(layer, x, cfg, positions)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = L.scan_layers(cfg, body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if hidden:
        return x, auxs.sum()
    logits = L.unembed(params["embed"], x, cfg)
    return logits, auxs.sum()


# ---------------------------------------------------------------------------
# Decode (KV cache) path
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, cfg.cdtype()),
            "v": jnp.zeros(shape, cfg.cdtype())}


def decode_step(params: Params, cache: Params, token: jax.Array,
                pos: jax.Array, cfg: ArchConfig
                ) -> Tuple[jax.Array, Params]:
    """token [B] at per-sequence position ``pos`` [B] against the cache."""
    x = L.embed(params["embed"], token[:, None], cfg)
    max_len = cache["k"].shape[2]

    def body(x, inputs):
        layer, k_cache, v_cache = inputs
        h = L.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        y, k_cache, v_cache = L.decode_attention(
            layer["attn"], h, cfg, k_cache, v_cache, pos, max_len)
        x = x + y
        h = L.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        if cfg.moe:
            y, _ = M.moe_ffn(layer["moe"], h, cfg)
        else:
            y = L.mlp(layer["mlp"], h, cfg)
        return x + y, (k_cache, v_cache)

    x, (k_new, v_new) = L.scan_layers(
        cfg, body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits[:, 0], {"k": k_new, "v": v_new}


def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig,
            max_len: int, embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Params]:
    """Run the full-sequence forward while materializing the KV cache."""
    if embeds is None:
        x = L.embed(params["embed"], tokens, cfg)
    else:
        x = embeds.astype(cfg.cdtype())
        if tokens is not None:
            x = jnp.concatenate(
                [x, L.embed(params["embed"], tokens, cfg)], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, layer):
        h = L.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(layer["attn"], h, cfg, positions)
        if cfg.attn_impl == "flash":
            from repro.kernels.flash_attention.ops import \
                flash_attention_bshd
            out = flash_attention_bshd(q, k, v, causal=True)
        elif cfg.attn_impl == "skip":   # §Perf ablation (see layers.py)
            out = q
        else:
            out = L.chunked_attention(q, k, v, causal=True,
                                      unroll=cfg.scan_unroll)
        y = jnp.einsum("bshk,hkd->bsd", out,
                       layer["attn"]["wo"].astype(cfg.cdtype()))
        x = ctx.constrain_residual(x + y)
        h = L.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        if cfg.moe:
            y, _ = M.moe_ffn(layer["moe"], h, cfg)
        else:
            y = L.mlp(layer["mlp"], h, cfg)
        # pad kv to max_len for the cache
        pad = max_len - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return ctx.constrain_residual(x + y), (k, v)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = L.scan_layers(cfg, body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)
    return logits[:, 0], {"k": ks, "v": vs}
