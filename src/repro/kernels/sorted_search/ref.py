"""Pure-jnp oracle for the sorted-search kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sorted_search_ref(keys: jax.Array, queries: jax.Array) -> jax.Array:
    """rank[q] = #{i : keys[i] <= q}  (numpy searchsorted side='right')."""
    return jnp.searchsorted(keys, queries, side="right").astype(jnp.int32)
