"""Sorted Search — the paper's Level-2 access primitive, TPU-native.

Hardware adaptation (DESIGN.md §5): on a CPU the optimal sorted search is a
branching binary search (the paper's log-linear Level-2 model).  On the TPU
VPU, data-dependent branching serializes and random VMEM indexing wastes
the 8x128 lanes, so the idiomatic equivalent is a *branchless compare-count
search*: rank(q) = sum_i [keys_i <= q], computed as a tiled all-compare
over VMEM-resident key blocks.  O(N) comparisons instead of O(log N) — but
they run 8x128 per cycle with zero divergence, which beats bisection for
any node that fits VMEM (exactly the node sizes the Data Calculator's
elements describe).  This is the paper's "cross-pollination" story: a new
Level-2 implementation slots under the same Level-1 primitive.

Grid: (num_query_blocks, num_key_blocks); key blocks stream through VMEM
while the per-query rank accumulates in the int32 output (innermost grid
dim is sequential on TPU, so read-modify-write of o_ref is safe).
"""
from __future__ import annotations

import functools

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _search_kernel(keys_ref, queries_ref, o_ref, *, block_k: int):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def init():
        o_ref[...] = jnp.zeros_like(o_ref)

    keys = keys_ref[...]                      # [block_k]
    queries = queries_ref[...]                # [block_q]
    # all-pairs compare on the VPU: [block_q, block_k] predicate tile
    le = keys[None, :] <= queries[:, None]
    o_ref[...] += le.sum(axis=1).astype(jnp.int32)


def sorted_search_kernel(keys: jax.Array, queries: jax.Array, *,
                         block_q: int = 256, block_k: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """keys: [N] sorted ascending; queries: [Q].

    Returns rank[q] = #{i : keys[i] <= q} — the searchsorted-right index.
    N and Q must divide by the block sizes (ops.py pads with +inf keys /
    repeated queries).
    """
    n, q = keys.shape[0], queries.shape[0]
    assert n % block_k == 0 and q % block_q == 0, (n, q)

    kernel = functools.partial(_search_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(q // block_q, n // block_k),
        in_specs=[
            pl.BlockSpec((block_k,), lambda qi, kj: (kj,)),
            pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(keys, queries)
