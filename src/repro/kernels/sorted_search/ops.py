"""Jit'd public wrapper for the sorted-search kernel: padding + lookup.

``sorted_search`` returns searchsorted-right ranks; ``sorted_get`` layers a
point lookup on top (the Data Calculator's Get over an ODP terminal node).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.runtime import resolve_interpret
from repro.kernels.sorted_search.kernel import sorted_search_kernel


def _pad1(x: jax.Array, mult: int, value) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def sorted_search(keys: jax.Array, queries: jax.Array,
                  block_q: int = 256, block_k: int = 512,
                  interpret: Optional[bool] = None) -> jax.Array:
    """searchsorted(keys, queries, side='right') via the Pallas kernel.

    keys must be sorted ascending.  Padding keys are +inf-like (dtype max),
    so they never count toward a rank; padded queries are sliced away.
    """
    interpret = resolve_interpret(interpret)
    n, q = keys.shape[0], queries.shape[0]
    if jnp.issubdtype(keys.dtype, jnp.floating):
        big = jnp.inf
    else:
        big = jnp.iinfo(keys.dtype).max
    keys_p = _pad1(keys, block_k, big)
    queries_p = _pad1(queries, block_q, queries[0] if q else 0)
    ranks = sorted_search_kernel(keys_p, queries_p, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    # dtype-max padding keys satisfy key <= q when q is also dtype max;
    # clamp to the true length
    return jnp.minimum(ranks[:q], n)


def sorted_get(keys: jax.Array, values: jax.Array, queries: jax.Array,
               interpret: Optional[bool] = None):
    """Point Get over a sorted columnar node: (found mask, values).

    The Data Calculator's ``SortedSearch(ColumnStore) + RandomAccess(value)``
    sequence as one fused TPU op.
    """
    ranks = sorted_search(keys, queries, interpret=interpret)
    idx = jnp.clip(ranks - 1, 0, keys.shape[0] - 1)
    found = keys[idx] == queries
    return found, jnp.where(found, values[idx], 0)
