"""Pure-numpy/jnp oracle for the hash-probe kernel (and table builder)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NOT_FOUND = np.int32(2147483647)
EMPTY_KEY = np.int32(-2147483648)  # sentinel: never a valid key


def multiply_shift_np(x: np.ndarray, a: int, s: int) -> np.ndarray:
    return ((x.astype(np.uint32) * np.uint32(a | 1)) >>
            np.uint32(32 - s)).astype(np.int64)


def build_table(keys: np.ndarray, values: np.ndarray, s: int, a: int,
                cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """Bucket-major [2^s, cap] table; overflowing entries are dropped (the
    kernel models a fixed-capacity bucket, like the paper's page-5 bucket
    lists; callers size cap for the load factor)."""
    nb = 1 << s
    tkeys = np.full((nb, cap), EMPTY_KEY, np.int32)
    tvals = np.zeros((nb, cap), np.int32)
    fill = np.zeros(nb, np.int64)
    buckets = multiply_shift_np(keys, a, s)
    for key, val, b in zip(keys.tolist(), values.tolist(), buckets.tolist()):
        if fill[b] < cap:
            tkeys[b, fill[b]] = key
            tvals[b, fill[b]] = val
            fill[b] += 1
    return tkeys, tvals


def hash_probe_ref(table_keys: np.ndarray, table_values: np.ndarray,
                   queries: np.ndarray, a: int, s: int):
    """(flat slot pos | NOT_FOUND, value | 0) per query."""
    nb, cap = table_keys.shape
    buckets = multiply_shift_np(np.asarray(queries), a, s)
    pos = np.full(len(queries), NOT_FOUND, np.int32)
    val = np.zeros(len(queries), table_values.dtype)
    for i, (query, b) in enumerate(zip(np.asarray(queries).tolist(),
                                       buckets.tolist())):
        row = table_keys[b]
        hits = np.flatnonzero(row == query)
        if hits.size:
            pos[i] = b * cap + hits[0]
            val[i] = table_values[b, hits[0]]
    return pos, val
