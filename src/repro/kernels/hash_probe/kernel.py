"""Hash Probe — the paper's Level-2 hash primitive, TPU-native.

The CPU version (Appendix D benchmark 11) is a dependent random memory
access: hash, then chase the bucket pointer.  TPUs have no cheap scalar
pointer chase — random access inside VMEM is the one paper primitive with
no direct analogue (DESIGN.md §5).  The adaptation keeps the *algorithmic
content* of hashing (restricting each probe to one bucket) but replaces the
pointer dereference with dataflow the VPU executes densely: the bucketized
table [NB, CAP] streams through VMEM block by block, and a probe matches a
slot iff (its bucket == hash(q)) AND (its key == q).  The hash does not
reduce comparisons on a single core the way it does on a CPU — it pays off
when buckets are sharded across chips/grid rows so each query block only
meets its resident shard (the distributed hash-partitioning the Data
Calculator's Hash element describes).

Multiply-shift family (Dietzfelbinger [25], as in the paper):
    h(x) = (a * x) >> (32 - s),  buckets = 2^s, a odd (32-bit wrap).
"""
from __future__ import annotations

import functools

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NOT_FOUND = 2147483647  # int32 max; plain int so kernels don't capture it


def multiply_shift(x: jax.Array, a: int, s: int) -> jax.Array:
    """Bucket id in [0, 2^s): 32-bit multiply-shift hash."""
    xu = x.astype(jnp.uint32)
    return (xu * jnp.uint32(a | 1)) >> jnp.uint32(32 - s)


def _probe_kernel(tkeys_ref, tvals_ref, queries_ref, pos_ref, val_ref, *,
                  cap: int, block_nb: int, a: int, s: int):
    bj = pl.program_id(1)

    @pl.when(bj == 0)
    def init():
        pos_ref[...] = jnp.full_like(pos_ref, NOT_FOUND)
        val_ref[...] = jnp.zeros_like(val_ref)

    tkeys = tkeys_ref[...]                 # [block_nb, cap]
    tvals = tvals_ref[...]                 # [block_nb, cap]
    queries = queries_ref[...]             # [block_q]
    bucket = multiply_shift(queries, a, s).astype(jnp.int32)  # [block_q]

    base = bj * block_nb
    nb_idx = base + jax.lax.broadcasted_iota(
        jnp.int32, (queries.shape[0], block_nb, cap), 1)
    slot = jax.lax.broadcasted_iota(
        jnp.int32, (queries.shape[0], block_nb, cap), 2)
    match = (nb_idx == bucket[:, None, None]) & \
        (tkeys[None] == queries[:, None, None])
    flat_pos = jnp.where(match, nb_idx * cap + slot, NOT_FOUND)
    hit_pos = flat_pos.min(axis=(1, 2))
    hit_val = jnp.where(match, tvals[None], 0).sum(axis=(1, 2))
    better = hit_pos < pos_ref[...]
    pos_ref[...] = jnp.where(better, hit_pos, pos_ref[...])
    val_ref[...] = jnp.where(better, hit_val, val_ref[...])


def hash_probe_kernel(table_keys: jax.Array, table_values: jax.Array,
                      queries: jax.Array, *, a: int, s: int,
                      block_q: int = 256, block_nb: int = 64,
                      interpret: Optional[bool] = None):
    """table_keys/values: [NB, CAP] bucket-major (NB = 2^s; empty slots hold
    a sentinel key that never matches); queries: [Q].

    Returns (pos, val): pos = flat slot index of the match (NOT_FOUND if
    absent), val = matched value (0 if absent).
    """
    nb, cap = table_keys.shape
    q = queries.shape[0]
    assert nb == 1 << s and nb % block_nb == 0 and q % block_q == 0
    kernel = functools.partial(_probe_kernel, cap=cap, block_nb=block_nb,
                               a=a, s=s)
    return pl.pallas_call(
        kernel,
        grid=(q // block_q, nb // block_nb),
        in_specs=[
            pl.BlockSpec((block_nb, cap), lambda qi, bj: (bj, 0)),
            pl.BlockSpec((block_nb, cap), lambda qi, bj: (bj, 0)),
            pl.BlockSpec((block_q,), lambda qi, bj: (qi,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda qi, bj: (qi,)),
            pl.BlockSpec((block_q,), lambda qi, bj: (qi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), table_values.dtype),
        ],
        interpret=resolve_interpret(interpret),
    )(table_keys, table_values, queries)
