"""Jit'd wrapper for the hash-probe kernel: padding + Get helper."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.hash_probe.kernel import NOT_FOUND, hash_probe_kernel
from repro.kernels.runtime import resolve_interpret

#: default multiply-shift coefficient (odd, from a fixed PRNG draw — the
#: paper draws a randomly per run; determinism helps tests)
DEFAULT_A = 0x9E3779B1  # Knuth's 32-bit golden ratio, odd


def _pad1(x: jax.Array, mult: int, value) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("a", "s", "block_q", "block_nb",
                                             "interpret"))
def hash_probe(table_keys: jax.Array, table_values: jax.Array,
               queries: jax.Array, s: int, a: int = DEFAULT_A,
               block_q: int = 256, block_nb: int = 64,
               interpret: Optional[bool] = None):
    """(found mask, values) for point probes against a bucketized table."""
    interpret = resolve_interpret(interpret)
    q = queries.shape[0]
    nb = table_keys.shape[0]
    block_nb = min(block_nb, nb)
    queries_p = _pad1(queries, block_q, jnp.asarray(NOT_FOUND - 1,
                                                    queries.dtype))
    pos, val = hash_probe_kernel(table_keys, table_values, queries_p,
                                 a=a, s=s, block_q=block_q,
                                 block_nb=block_nb, interpret=interpret)
    found = pos[:q] != NOT_FOUND
    return found, jnp.where(found, val[:q], 0)
