"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  sm_scale: Optional[float] = None) -> jax.Array:
    """q: [B, H, Sq, D]; k/v: [B, KH, Skv, D].  Naive softmax attention."""
    b, h, sq, d = q.shape
    _, kh, skv, _ = k.shape
    g = h // kh
    scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale
    qg = q.reshape(b, kh, g, sq, d).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgid,bkjd->bkgij", qg, kf)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgij,bkjd->bkgid", p, vf)
    return out.reshape(b, h, sq, d).astype(q.dtype)
