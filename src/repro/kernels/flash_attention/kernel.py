"""Flash attention forward kernel (pl.pallas_call + BlockSpec VMEM tiling).

Online-softmax attention tiled for the TPU memory hierarchy: Q/K/V blocks
are staged HBM->VMEM by the BlockSpec pipeline; the [block_q, block_kv]
score tile and the float32 (acc, m, l) running state live in VMEM scratch;
the score/PV matmuls hit the MXU with 128-aligned tiles.

Grid layout: (batch * q_heads, num_q_blocks, num_kv_blocks) with the KV
block as the innermost (sequential on TPU) dimension, so the online-softmax
carry in scratch is valid across KV iterations.  GQA folds the head group
into the index maps (KV blocks are re-read per grouped Q head — the same
trade the XLA path makes; K/V tiles stay VMEM-resident across the group).

Causal masking skips fully-masked tiles with a cheap predicated branch
(@pl.when), the Pallas analogue of flash attention's block skipping.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_kv: int, num_kv: int, causal: bool,
                  sm_scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: tiles entirely above the diagonal contribute nothing
    q_lo = qi * block_q
    k_lo = kj * block_kv
    run = (not causal) or (q_lo + block_q - 1 >= k_lo)

    @pl.when(jnp.asarray(run))
    def body():
        q = q_ref[0].astype(jnp.float32) * sm_scale      # [bq, d]
        k = k_ref[0].astype(jnp.float32)                 # [bkv, d]
        v = v_ref[0].astype(jnp.float32)                 # [bkv, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
            cols = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_ref[...]                               # [bq]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # fully-masked rows: s == NEG_INF everywhere -> p ~ exp(0) on the
        # max col; guard by zeroing rows whose max is NEG_INF
        dead = m_new <= NEG_INF / 2
        p = jnp.where(dead[:, None], 0.0, p)
        corr = jnp.where(dead, 1.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == num_kv - 1)
    def finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           block_q: int = 128, block_kv: int = 128,
                           sm_scale: Optional[float] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q: [B, H, Sq, D]; k/v: [B, KH, Skv, D] with H % KH == 0.

    Returns [B, H, Sq, D].  Sq/Skv must divide by the block sizes (ops.py
    pads); D should be MXU-aligned (128) for the target, any D works in
    interpret mode.
    """
    b, h, sq, d = q.shape
    _, kh, skv, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    nq, nkv = sq // block_q, skv // block_kv
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * kh, skv, d)
    vf = v.reshape(b * kh, skv, d)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_kv=block_kv, num_kv=nkv,
        causal=causal, sm_scale=sm_scale)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),     # l (running sum)
        ],
        interpret=resolve_interpret(interpret),
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
