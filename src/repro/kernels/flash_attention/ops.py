"""Jit'd public wrapper around the flash attention kernel.

Handles padding to block multiples, the BSHD<->BHSD layout used by the
model stack, and a custom VJP whose backward differentiates the reference
implementation (forward stays on the kernel; backward is the standard
rematerialized attention pullback XLA already fuses well).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.runtime import resolve_interpret


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: Optional[bool] = None
                    ) -> jax.Array:
    """Flash attention, [B, H, S, D] layout (see ops_bshd for model layout).

    Pads Sq/Skv up to block multiples; padded KV columns are masked out by
    an explicit -inf bias only when non-causal (under causal masking the
    padded query rows never attend to padded keys beyond their position,
    and padded rows are sliced away from the output).
    """
    return _forward(q, k, v, causal, block_q, block_kv, interpret)


def _forward(q, k, v, causal, block_q, block_kv, interpret):
    interpret = resolve_interpret(interpret)
    sq, skv = q.shape[2], k.shape[2]
    qp = _pad_to(q, 2, block_q)
    kp = _pad_to(k, 2, block_kv)
    vp = _pad_to(v, 2, block_kv)
    if not causal and kp.shape[2] != skv:
        # mask padded keys by pushing them to -inf via a large-negative key
        # contribution: zero keys give score 0, so instead slice-safe path:
        # append a bias row is not expressible per-block — use ref fallback.
        return attention_ref(q, k, v, causal=False)
    out = flash_attention_kernel(qp, kp, vp, causal=causal,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=interpret)
    return out[:, :, :sq]


def _fwd(q, k, v, causal, block_q, block_kv, interpret):
    return _forward(q, k, v, causal, block_q, block_kv, interpret), (q, k, v)


def _bwd(causal, block_q, block_kv, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: attention_ref(q, k, v, causal=causal),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


def flash_attention_bshd(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True, interpret: Optional[bool] = None
                         ) -> jax.Array:
    """Model-stack layout: q [B, S, H, D]; k/v [B, S, KH, D]."""
    out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal,
                          128, 128, interpret)
    return out.transpose(0, 2, 1, 3)
