"""Pure-jnp oracle for the scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NOT_FOUND = 2147483647


def scan_filter_ref(keys: jax.Array, queries: jax.Array,
                    lo: jax.Array, hi: jax.Array):
    """(first equal-match position | NOT_FOUND, range-match count)."""
    eq = keys[None, :] == queries[:, None]
    idx = jnp.arange(keys.shape[0], dtype=jnp.int32)[None, :]
    pos = jnp.where(eq, idx, NOT_FOUND).min(axis=1)
    in_range = (keys[None, :] >= lo[:, None]) & (keys[None, :] < hi[:, None])
    return pos, in_range.sum(axis=1).astype(jnp.int32)
