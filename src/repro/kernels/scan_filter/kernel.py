"""Scan — the paper's Level-2 scan primitives (equal + range), TPU-native.

The paper's SIMD-AVX scan (Appendix D benchmarks 5/6) maps directly onto
the VPU: a predicated compare over 8x128 lanes per cycle.  Where the CPU
version breaks on first match, the TPU version evaluates the whole block
branchlessly and reduces — on the VPU the "wasted" comparisons are free
relative to a divergent early exit (the same argument as sorted_search).

Two outputs per key block: the per-query match position (argmax of the
equal-predicate, for Get) and the per-query count of range matches (for
selectivity / range sizing).  Grid: (query_blocks, key_blocks); key blocks
stream HBM->VMEM; running state accumulates in the outputs (innermost grid
dim sequential).
"""
from __future__ import annotations

import functools

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NOT_FOUND = 2147483647  # int32 max; plain int so kernels don't capture it


def _scan_kernel(keys_ref, queries_ref, lo_ref, hi_ref, pos_ref, cnt_ref, *,
                 block_k: int):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def init():
        pos_ref[...] = jnp.full_like(pos_ref, NOT_FOUND)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    keys = keys_ref[...]                          # [block_k]
    queries = queries_ref[...]                    # [block_q]
    lo = lo_ref[...]
    hi = hi_ref[...]
    base = kj * block_k
    idx = base + jax.lax.broadcasted_iota(jnp.int32,
                                          (queries.shape[0], block_k), 1)

    eq = keys[None, :] == queries[:, None]        # equality predicate tile
    first = jnp.where(eq, idx, NOT_FOUND).min(axis=1)
    pos_ref[...] = jnp.minimum(pos_ref[...], first)

    in_range = (keys[None, :] >= lo[:, None]) & (keys[None, :] < hi[:, None])
    cnt_ref[...] += in_range.sum(axis=1).astype(jnp.int32)


def scan_filter_kernel(keys: jax.Array, queries: jax.Array,
                       lo: jax.Array, hi: jax.Array, *,
                       block_q: int = 256, block_k: int = 512,
                       interpret: Optional[bool] = None):
    """keys: [N] unsorted; queries/lo/hi: [Q].

    Returns (pos, count): pos[q] = first index with keys[i] == queries[q]
    (NOT_FOUND if absent); count[q] = #{i : lo[q] <= keys[i] < hi[q]}.
    """
    n, q = keys.shape[0], queries.shape[0]
    assert n % block_k == 0 and q % block_q == 0, (n, q)
    kernel = functools.partial(_scan_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(q // block_q, n // block_k),
        in_specs=[
            pl.BlockSpec((block_k,), lambda qi, kj: (kj,)),
            pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
            pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
            pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
            pl.BlockSpec((block_q,), lambda qi, kj: (qi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(keys, queries, lo, hi)
