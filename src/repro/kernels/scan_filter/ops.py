"""Jit'd wrapper for the scan kernel: padding + Get/RangeCount helpers."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.runtime import resolve_interpret
from repro.kernels.scan_filter.kernel import NOT_FOUND, scan_filter_kernel


def _pad1(x: jax.Array, mult: int, value) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def scan_filter(keys: jax.Array, queries: jax.Array,
                lo: jax.Array, hi: jax.Array,
                block_q: int = 256, block_k: int = 512,
                interpret: Optional[bool] = None):
    """(first-match pos | NOT_FOUND, range count) over an unsorted node."""
    interpret = resolve_interpret(interpret)
    n, q = keys.shape[0], queries.shape[0]
    if jnp.issubdtype(keys.dtype, jnp.floating):
        big = jnp.inf
    else:
        big = jnp.iinfo(keys.dtype).max
    keys_p = _pad1(keys, block_k, big)   # never equal, never in range
    queries_p = _pad1(queries, block_q, big)
    lo_p = _pad1(lo, block_q, big)
    hi_p = _pad1(hi, block_q, big)
    pos, cnt = scan_filter_kernel(keys_p, queries_p, lo_p, hi_p,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
    # dtype-max padding keys match dtype-max queries: mask out-of-range hits
    pos = jnp.where(pos >= n, NOT_FOUND, pos)
    return pos[:q], cnt[:q]


def scan_get(keys: jax.Array, values: jax.Array, queries: jax.Array,
             interpret: Optional[bool] = None):
    """Point Get over an unsorted node (the paper's UDP terminal)."""
    pos, _ = scan_filter(keys, queries, queries, queries,
                         interpret=interpret)
    found = pos != NOT_FOUND
    idx = jnp.where(found, pos, 0)
    return found, jnp.where(found, values[idx], 0)
