"""Jit'd wrapper for the bloom-probe kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.runtime import resolve_interpret

#: deterministic odd multipliers (the paper draws them randomly per run)
DEFAULT_COEFFS = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                           0x165667B1], np.uint32) | np.uint32(1)


def _pad1(x: jax.Array, mult: int, value) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("s", "num_hashes", "block_q",
                                             "block_w", "interpret"))
def bloom_probe(words: jax.Array, queries: jax.Array, s: int,
                num_hashes: int = 2, block_q: int = 256, block_w: int = 256,
                interpret: Optional[bool] = None) -> jax.Array:
    """Membership mask for ``queries`` against a 2^s-bit bloom filter."""
    from repro.kernels.bloom_probe.kernel import bloom_probe_kernel
    interpret = resolve_interpret(interpret)
    q = queries.shape[0]
    w = words.shape[0]
    block_w = min(block_w, w)
    coeffs = jnp.asarray(DEFAULT_COEFFS[:num_hashes])
    queries_p = _pad1(queries, block_q, queries[0] if q else 0)
    hits = bloom_probe_kernel(words, queries_p, coeffs, s=s,
                              block_q=block_q, block_w=block_w,
                              interpret=interpret)
    return (hits[:q] == 1).all(axis=1)
