"""Bloom Filter Probe — the paper's Level-2 bloom primitive, TPU-native.

CPU version (Appendix D benchmarks 13/14): k multiply-shift hashes, k
dependent bit tests.  TPU adaptation: the filter's uint32 words stream
through VMEM in blocks; each (query, hash) pair tests its bit against the
word block it falls in via a predicated compare — the same
gather-to-dataflow rewrite as hash_probe.  Output accumulates the number
of set bits per (query, hash); membership = all k bits set (combined in
ops.py).

Hash family: h_j(x) = (a_j * x) >> (32 - s) over n_bits = 2^s bits.
"""
from __future__ import annotations

import functools

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _bloom_kernel(words_ref, queries_ref, coeffs_ref, hits_ref, *,
                  block_w: int, s: int):
    wj = pl.program_id(1)

    @pl.when(wj == 0)
    def init():
        hits_ref[...] = jnp.zeros_like(hits_ref)

    words = words_ref[...]                     # [block_w] uint32
    queries = queries_ref[...]                 # [block_q]
    coeffs = coeffs_ref[...]                   # [k] uint32 (odd)

    xu = queries.astype(jnp.uint32)
    hv = (xu[:, None] * coeffs[None, :]) >> jnp.uint32(32 - s)  # [q, k]
    word_idx = (hv >> jnp.uint32(5)).astype(jnp.int32)
    bit_idx = (hv & jnp.uint32(31)).astype(jnp.uint32)

    base = wj * block_w
    w_iota = base + jax.lax.broadcasted_iota(
        jnp.int32, (queries.shape[0], coeffs.shape[0], block_w), 2)
    in_block = word_idx[:, :, None] == w_iota
    bits = (words[None, None, :] >> bit_idx[:, :, None]) & jnp.uint32(1)
    hit = (in_block & (bits == 1)).any(axis=2)
    hits_ref[...] += hit.astype(jnp.int32)


def bloom_probe_kernel(words: jax.Array, queries: jax.Array,
                       coeffs: jax.Array, *, s: int,
                       block_q: int = 256, block_w: int = 256,
                       interpret: Optional[bool] = None) -> jax.Array:
    """words: [W] uint32 filter (W = 2^s / 32); queries: [Q];
    coeffs: [k] uint32 odd hash multipliers.

    Returns hits [Q, k]: 1 where hash j's bit is set for query q.
    """
    w, q = words.shape[0], queries.shape[0]
    assert w == (1 << s) // 32 and w % block_w == 0 and q % block_q == 0
    k = coeffs.shape[0]
    kernel = functools.partial(_bloom_kernel, block_w=block_w, s=s)
    return pl.pallas_call(
        kernel,
        grid=(q // block_q, w // block_w),
        in_specs=[
            pl.BlockSpec((block_w,), lambda qi, wj: (wj,)),
            pl.BlockSpec((block_q,), lambda qi, wj: (qi,)),
            pl.BlockSpec((k,), lambda qi, wj: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, k), lambda qi, wj: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, k), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(words, queries, coeffs)
