"""Pure-numpy oracle for the bloom-probe kernel (and filter builder)."""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _hashes(x: np.ndarray, coeffs: np.ndarray, s: int) -> np.ndarray:
    """[len(x), k] bit positions."""
    xu = x.astype(np.uint32)
    return ((xu[:, None] * coeffs[None, :].astype(np.uint32)) >>
            np.uint32(32 - s)).astype(np.int64)


def build_filter(keys: np.ndarray, coeffs: np.ndarray, s: int) -> np.ndarray:
    """uint32 word array of a bloom filter with 2^s bits."""
    words = np.zeros((1 << s) // 32, np.uint32)
    hv = _hashes(np.asarray(keys), coeffs, s).reshape(-1)
    np.bitwise_or.at(words, hv >> 5, np.uint32(1) << (hv & 31).astype(np.uint32))
    return words


def bloom_probe_ref(words: np.ndarray, queries: np.ndarray,
                    coeffs: np.ndarray, s: int) -> np.ndarray:
    """member mask [Q]: True iff every hash's bit is set."""
    hv = _hashes(np.asarray(queries), coeffs, s)
    bits = (words[hv >> 5] >> (hv & 31).astype(np.uint32)) & 1
    return bits.all(axis=1)
