"""Pallas TPU kernels for the paper's access primitives + attention.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper), ref.py (pure-jnp/numpy oracle).  Validated in interpret
mode (tests/test_kernels.py sweeps shapes and dtypes against the oracles);
BlockSpecs tile for VMEM with 128-aligned MXU dims on the real target.

Kernel inventory (the paper's Level-2 access primitives, TPU-adapted, plus
the framework's attention hot-spot):
  flash_attention  online-softmax attention, causal block skipping
  sorted_search    branchless compare-count search (paper: Sorted Search)
  scan_filter      predicated equal/range scan     (paper: Scan)
  hash_probe       multiply-shift bucket probe     (paper: Hash Probe)
  bloom_probe      k-hash bit test                 (paper: Bloom Probe)
"""
