"""Backend selection for the Pallas kernels.

The kernel wrappers historically hardcoded ``interpret=True`` (the Pallas
interpreter runs anywhere, so CPU CI stayed deterministic) — which also
meant a real TPU silently ran the interpreter.  ``default_interpret``
auto-detects: compile to Mosaic only when a TPU backend is attached,
interpret otherwise.  Every wrapper takes ``interpret: Optional[bool]``
with ``None`` meaning "resolve via this module"; passing an explicit bool
still forces either mode (tests pin ``interpret=True`` where they must be
deterministic on CPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax


@functools.lru_cache(maxsize=None)
def default_interpret() -> bool:
    """True (interpret) unless a real TPU backend is attached."""
    try:
        return jax.default_backend() != "tpu"
    except Exception:  # no backend at all -> interpreter is the only option
        return True


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Map the wrappers' ``interpret=None`` default to the detected mode."""
    return default_interpret() if interpret is None else bool(interpret)
