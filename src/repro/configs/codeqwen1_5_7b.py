"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B; hf] — qwen1.5 arch, MHA."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
        vocab_size=92416, qkv_bias=True, param_dtype="bfloat16",
        source="hf:Qwen/CodeQwen1.5-7B; hf")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="codeqwen1.5-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=256, qkv_bias=True, param_dtype="float32", remat=False)
