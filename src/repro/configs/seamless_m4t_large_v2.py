"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — enc-dec, multimodal.

Backbone only: the speech/text frontend is a stub; ``input_specs`` feeds
precomputed frame embeddings to the 24L encoder, and the 24L decoder
cross-attends to encoder output.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
        vocab_size=256206, n_encoder_layers=24,
        source="arXiv:2308.11596; hf")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="seamless-m4t-large-v2-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, n_encoder_layers=2, param_dtype="float32",
        remat=False)
