"""Config registry: ``--arch <id>`` resolution for all assigned archs."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs import (codeqwen1_5_7b, granite_moe_1b, llama3_405b,
                           phi3_5_moe_42b, pixtral_12b, qwen1_5_32b,
                           qwen2_1_5b, seamless_m4t_large_v2, xlstm_350m,
                           zamba2_1_2b)
from repro.configs.base import (ArchConfig, RunConfig, ShapeConfig, SHAPES,
                                shape_applies)

_MODULES = {
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "qwen1.5-32b": qwen1_5_32b,
    "qwen2-1.5b": qwen2_1_5b,
    "llama3-405b": llama3_405b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "xlstm-350m": xlstm_350m,
    "zamba2-1.2b": zamba2_1_2b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "pixtral-12b": pixtral_12b,
}

ARCH_IDS = tuple(_MODULES.keys())


def get_config(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].config()


def get_smoke_config(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].smoke()
