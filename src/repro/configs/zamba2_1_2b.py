"""zamba2-1.2b [arXiv:2411.15242; hf] — Mamba2 backbone + shared attn block."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=32000, ssm_state=64, ssm_expand=2, shared_attn_every=6,
        source="arXiv:2411.15242; hf")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="zamba2-1.2b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_state=16, ssm_expand=2, shared_attn_every=2,
        param_dtype="float32", remat=False)
