"""xlstm-350m [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

d_ff=0 per the assignment: blocks carry their own up/down projections
(mLSTM projection factor 2) rather than a separate FFN.  Every 4th block is
an sLSTM (scalar memory, sequential scan); the rest are mLSTM (matrix
memory, chunked-parallel) — the paper's mixed-block configuration.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50304, ssm_state=0, ssm_expand=2, slstm_every=4,
        source="arXiv:2405.04517; unverified")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="xlstm-350m-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0,
        vocab_size=256, ssm_state=0, ssm_expand=2, slstm_every=2,
        param_dtype="float32", remat=False)
