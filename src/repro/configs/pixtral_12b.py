"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified].

Backbone only (mistral-nemo style decoder, head_dim 160, GQA kv=8); the
pixtral-ViT frontend is a stub — ``input_specs`` provides precomputed patch
embeddings prepended to the token sequence.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=131072, head_dim=160, n_patches=256,
        param_dtype="bfloat16",
        source="hf:mistralai/Pixtral-12B-2409; unverified")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="pixtral-12b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, n_patches=8, param_dtype="float32",
        remat=False)
