"""llama3-405b [arXiv:2407.21783; unverified] — GQA kv=8, 128k vocab."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
        vocab_size=128256, head_dim=128, param_dtype="bfloat16",
        rope_theta=5e5, source="arXiv:2407.21783; unverified")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="llama3-405b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        vocab_size=256, head_dim=16, param_dtype="float32", remat=False)
