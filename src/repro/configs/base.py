"""Architecture + run configuration system.

One ``ArchConfig`` per assigned architecture (see siblings in this package)
with the exact published hyper-parameters, plus ``smoke()`` reduced
variants for CPU tests.  Shapes are the four assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A model architecture; families: dense|moe|ssm|hybrid|audio|vlm."""

    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0                     # mamba2 state size N
    ssm_expand: int = 2                    # d_inner = expand * d_model
    ssm_chunk: int = 128                   # SSD chunk length
    #: hybrid (zamba2): apply the shared attention block every k-th layer
    shared_attn_every: int = 0
    #: xlstm: every k-th layer is an sLSTM block (rest mLSTM); 0 = all mLSTM
    slstm_every: int = 0
    #: enc-dec (seamless): number of encoder layers (decoder = n_layers)
    n_encoder_layers: int = 0
    #: vlm (pixtral): number of prepended image-patch embeddings
    n_patches: int = 0
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    max_seq: int = 1 << 20
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    #: "xla" = chunked online-softmax lowered by XLA (dry-run path);
    #: "flash" = the Pallas kernel (VMEM-resident score tiles — the real-TPU
    #: fast path; interpret-mode on CPU, so tests only use it at toy sizes)
    attn_impl: str = "xla"
    #: fully unroll layer scans (dry-run cost probes — XLA's cost_analysis
    #: counts while bodies once, so probes must not use while loops)
    scan_unroll: bool = False
    #: notes on published-source + verification tier
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state => long_500k applies (ssm/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> float:
        """Approximate total parameter count (embedding included)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
        elif self.family == "ssm":
            d_in = self.ssm_expand * d
            ffn = 0.0
            attn = 2 * d * d_in + 2 * d * self.ssm_state * 2 + d_in * d
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        total = L * per_layer + 2 * self.vocab_size * d
        if self.is_encdec:
            total += self.n_encoder_layers * per_layer
        return float(total)

    def n_active_params(self) -> float:
        """Active params per token (MoE counts top-k experts only)."""
        if not self.moe:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d
        ffn = self.moe.top_k * 3 * d * f + d * self.moe.n_experts
        return float(L * (attn + ffn + 2 * d) + 2 * self.vocab_size * d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applies(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a shape cell applies to an arch (with skip reason)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: O(L^2) attention at 512k "
                       "has no published sub-quadratic variant — skipped "
                       "per assignment note")
    return True, ""


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training-run hyper-parameters (launcher-level)."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    microbatch: int = 0          # 0 = no gradient accumulation
    #: cast gradients to bf16 before the cross-replica reduction (halves
    #: grad all-reduce/reduce-scatter bytes; clip + Adam math stay fp32)
    grad_compression: bool = False
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
