"""qwen1.5-32b [hf:Qwen/Qwen1.5-0.5B family; hf] — QKV bias, MHA kv=40."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
        vocab_size=152064, qkv_bias=True, param_dtype="bfloat16",
        source="hf:Qwen/Qwen1.5-0.5B; hf")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen1.5-32b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, qkv_bias=True, param_dtype="float32", remat=False)
