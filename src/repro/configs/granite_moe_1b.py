"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
        vocab_size=49155, moe=MoEConfig(n_experts=32, top_k=8),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="granite-moe-1b-a400m-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=256, moe=MoEConfig(n_experts=8, top_k=4),
        param_dtype="float32", remat=False)
