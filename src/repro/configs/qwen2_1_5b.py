"""qwen2-1.5b [arXiv:2407.10671; hf] — GQA kv=2, QKV bias."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
        vocab_size=151936, qkv_bias=True, tie_embeddings=True,
        source="arXiv:2407.10671; hf")


def smoke() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-1.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, qkv_bias=True, tie_embeddings=True,
        param_dtype="float32", remat=False)
