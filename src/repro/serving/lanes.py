"""Priority lanes: bounded per-lane queues with weighted dequeue.

The coalescing worker used to drain one unbounded FIFO — so a burst of
bulk sweeps ahead of an interactive what-if delayed it by the whole
burst's scoring time.  :class:`LaneScheduler` replaces that queue:

* **Two lanes** — :data:`INTERACTIVE` (what-if questions, small
  auto-completions) and :data:`BULK` (sweeps, large completions) — each
  a bounded FIFO.  ``put`` on a full lane raises
  :class:`~repro.serving.admission.RejectedError` immediately: shed on
  overload, never an unbounded backlog, never a blocked producer.
* **Weighted dequeue** — ``get`` serves lanes by weighted round-robin
  (default 4 interactive : 1 bulk).  While both lanes hold work, at
  most ``1/(w_i+w_b)`` of a coalescing window is bulk; when the
  interactive lane is empty, bulk flows at full rate.  An interactive
  arrival therefore waits on at most the *currently scoring* call, not
  on the bulk backlog.
* **Shutdown** — ``close()`` stops admission (``put`` raises
  :class:`~repro.serving.admission.ServiceStoppedError` carrying the
  lane depth as the would-be queue position) while ``get`` keeps
  draining; once both lanes are empty a closed scheduler hands back
  :data:`CLOSED`.  ``drain()`` empties what is left (used to fail
  stragglers when the worker is already gone), reporting each item's
  queue position.

Single condition variable, no per-lane threads; the worker's coalescing
window logic is unchanged — it just asks the scheduler instead of a
``queue.Queue``.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.admission import RejectedError, ServiceStoppedError

#: the latency-sensitive lane: what-if questions, small completions
INTERACTIVE = "interactive"
#: the throughput lane: workload sweeps, large completions
BULK = "bulk"
#: lanes in priority order (ties in the weighted round go left-first)
LANES: Tuple[str, ...] = (INTERACTIVE, BULK)

#: returned by :meth:`LaneScheduler.get` once closed and fully drained
CLOSED = object()


class LaneScheduler:
    """Bounded multi-lane queue with weighted round-robin dequeue."""

    def __init__(self, capacities: Optional[Dict[str, int]] = None,
                 weights: Optional[Dict[str, int]] = None,
                 lanes: Sequence[str] = LANES) -> None:
        self.lanes = tuple(lanes)
        self.capacities = {lane: int((capacities or {}).get(lane, 1024))
                           for lane in self.lanes}
        self.weights = {lane: max(int((weights or {}).get(lane, 1)), 1)
                        for lane in self.lanes}
        self._queues: Dict[str, collections.deque] = {
            lane: collections.deque() for lane in self.lanes}
        self._credits = dict(self.weights)
        self._cond = threading.Condition()
        self._closed = False

    # -- producers -----------------------------------------------------------
    def put(self, item, lane: str = INTERACTIVE) -> int:
        """Enqueue on ``lane``; returns the queue position (0 = head).

        Raises :class:`RejectedError` when the lane is at capacity and
        :class:`ServiceStoppedError` after :meth:`close`."""
        if lane not in self._queues:
            raise KeyError(f"unknown lane: {lane!r}")
        with self._cond:
            q = self._queues[lane]
            if self._closed:
                raise ServiceStoppedError(
                    f"service stopped; not accepting {lane} requests",
                    queue_position=len(q))
            cap = self.capacities[lane]
            if len(q) >= cap:
                raise RejectedError(
                    f"{lane} lane full ({len(q)}/{cap}); request shed",
                    lane=lane, depth=len(q), limit=cap)
            q.append(item)
            self._cond.notify()
            return len(q) - 1

    # -- the worker ----------------------------------------------------------
    def _pick(self, allowed: Optional[Sequence[str]] = None) -> Optional[str]:
        """The next lane to serve, by weighted round-robin with priority
        tie-break (must hold the condition)."""
        ready = [lane for lane in (allowed or self.lanes)
                 if self._queues[lane]]
        if not ready:
            return None
        with_credit = [lane for lane in ready if self._credits[lane] > 0]
        if not with_credit:
            # everyone ready spent their round: start a fresh one
            self._credits = dict(self.weights)
            with_credit = ready
        return with_credit[0]

    def get(self, timeout: Optional[float] = None,
            lanes: Optional[Sequence[str]] = None):
        """The next item by lane weight; ``None`` on timeout;
        :data:`CLOSED` once closed and drained.

        ``lanes`` restricts the pick to a subset — the worker uses it to
        cap how much bulk a single coalescing window may absorb while
        still accepting interactive arrivals until the window closes."""
        with self._cond:
            while True:
                lane = self._pick(lanes)
                if lane is not None:
                    self._credits[lane] -= 1
                    return self._queues[lane].popleft()
                if self._closed:
                    # an unrestricted pick that found nothing means fully
                    # drained; a restricted one must NOT report CLOSED
                    # while other lanes still hold work to drain
                    if lanes is None or not any(
                            len(q) for q in self._queues.values()):
                        return CLOSED
                    return None
                if not self._cond.wait(timeout):
                    return None

    # -- lifecycle / introspection -------------------------------------------
    def close(self) -> None:
        """Stop admission; the worker drains what is queued, then sees
        :data:`CLOSED`."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def reopen(self) -> None:
        """Accept traffic again (a restarted service reuses its scheduler)."""
        with self._cond:
            self._closed = False
            self._credits = dict(self.weights)

    def drain(self) -> List[Tuple[object, str, int]]:
        """Empty every lane: ``(item, lane, queue_position)`` per item."""
        with self._cond:
            out: List[Tuple[object, str, int]] = []
            for lane in self.lanes:
                q = self._queues[lane]
                pos = 0
                while q:
                    out.append((q.popleft(), lane, pos))
                    pos += 1
            return out

    def depth(self, lane: Optional[str] = None) -> int:
        with self._cond:
            if lane is not None:
                return len(self._queues[lane])
            return sum(len(q) for q in self._queues.values())
