"""Admission control for the serving tier: errors, cost pricing, budgets.

The Data Calculator's serving promise is *interactive* answers, and the
service coalesces aggressively — but coalescing without backpressure
means one bulk ``submit_sweep`` flood can absorb every worker cycle
while interactive what-ifs rot in the queue.  This module is the
admission edge in front of the coalescing worker:

* **Typed rejections.**  Every way a request can fail *without being
  served* gets its own exception so load-test clients (and real ones)
  can tell the regimes apart: :class:`RejectedError` (bounded queue
  full — shed on overload), :class:`BudgetExceeded` (the session's
  token bucket is dry — a :class:`RejectedError` subclass, so "shed"
  handlers catch both), :class:`DeadlineExceeded` (admitted, but the
  deadline passed before/while serving), and
  :class:`ServiceStoppedError` (shutdown raced the request — carries
  the queue position so clients can distinguish shutdown from
  overload).
* **Cost pricing.**  A request is priced in *cells* — estimated
  designs x workload-points scored (:func:`request_cost`) — so a
  640-design x 8-workload sweep pays 5120x what a single what-if pays,
  proportionally to the scoring work it will occupy.
* **Per-session token buckets.**  :class:`SessionBudgets` hands each
  session a :class:`TokenBucket` (capacity + refill rate in
  cells/second).  A request whose cost cannot be acquired is rejected
  *at submit time* — before it holds a queue slot.

Semantics are documented in ``docs/serving.md``; exercised by
``tests/test_admission.py`` and ``benchmarks/load_bench.py``.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional


class ServiceError(RuntimeError):
    """Base class for serving-tier request failures."""


class RejectedError(ServiceError):
    """Shed on overload: a bounded lane queue (or budget) refused the
    request.  The request was never queued — retry later or back off."""

    def __init__(self, message: str, *, lane: Optional[str] = None,
                 depth: Optional[int] = None,
                 limit: Optional[int] = None) -> None:
        super().__init__(message)
        self.lane = lane
        self.depth = depth      # queue depth observed at rejection
        self.limit = limit      # the lane's configured bound


class BudgetExceeded(RejectedError):
    """The session's token-bucket cost budget cannot cover the request."""

    def __init__(self, message: str, *, session: str, cost: float,
                 available: float) -> None:
        super().__init__(message)
        self.session = session
        self.cost = cost
        self.available = available


class DeadlineExceeded(ServiceError):
    """The request's deadline passed before it could be (fully) served."""

    def __init__(self, message: str, *, deadline_s: float,
                 late_by_s: float) -> None:
        super().__init__(message)
        self.deadline_s = deadline_s    # the relative deadline requested
        self.late_by_s = late_by_s      # how far past it we noticed


class WorkerCrashed(ServiceError):
    """The serving worker died while this request was in flight.

    The supervisor restarts the worker (bounded restarts with backoff —
    see ``docs/serving.md``), but the crashed window's requests are NOT
    replayed: the client gets this typed error immediately and may
    resubmit.  ``cause`` carries the exception that killed the worker;
    ``restarts`` is the worker's restart count at failure time."""

    def __init__(self, message: str, *,
                 cause: Optional[BaseException] = None,
                 restarts: int = 0) -> None:
        super().__init__(message)
        self.cause = cause
        self.restarts = restarts


class ServiceStoppedError(ServiceError):
    """The service stopped before serving this request.

    ``queue_position`` is where the request sat when shutdown caught it
    (0 = head of its lane), so clients can tell an orderly shutdown from
    an overload shed (:class:`RejectedError`)."""

    def __init__(self, message: str,
                 queue_position: Optional[int] = None) -> None:
        super().__init__(message)
        self.queue_position = queue_position


def request_cost(n_designs: int, n_points: int = 1) -> float:
    """Price a request in *cells*: designs x workload points scored.

    This is the unit the fused scorer's work actually scales with — a
    flat what-if is ~2 cells, an auto-completion pays its frontier size,
    a sweep pays its whole grid."""
    return float(max(n_designs, 1) * max(n_points, 1))


class TokenBucket:
    """A classic token bucket in *cells* (thread-safe).

    ``capacity`` bounds the burst a session can land at once;
    ``refill_per_s`` is the sustained cells/second it may consume.
    ``try_acquire`` never blocks — admission control sheds, it does not
    queue debtors."""

    def __init__(self, capacity: float, refill_per_s: float,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if capacity <= 0 or refill_per_s <= 0:
            raise ValueError("capacity and refill_per_s must be positive")
        self.capacity = float(capacity)
        self.refill_per_s = float(refill_per_s)
        self._clock = clock
        self._tokens = float(capacity)
        self._stamp = clock()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        elapsed = max(now - self._stamp, 0.0)
        self._stamp = now
        self._tokens = min(self.capacity,
                           self._tokens + elapsed * self.refill_per_s)

    def available(self) -> float:
        with self._lock:
            self._refill(self._clock())
            return self._tokens

    def try_acquire(self, cost: float) -> bool:
        with self._lock:
            self._refill(self._clock())
            if cost > self._tokens:
                return False
            self._tokens -= cost
            return True


class SessionBudgets:
    """Per-session :class:`TokenBucket`s, created on first use.

    Sessionless requests share the ``"_anonymous"`` bucket, so an
    unidentified flood still cannot starve identified sessions."""

    ANONYMOUS = "_anonymous"

    def __init__(self, capacity: float, refill_per_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.capacity = float(capacity)
        #: default sustained rate: one full budget per second
        self.refill_per_s = float(refill_per_s if refill_per_s is not None
                                  else capacity)
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        self._lock = threading.Lock()

    def bucket(self, session: Optional[str]) -> TokenBucket:
        name = session or self.ANONYMOUS
        with self._lock:
            bucket = self._buckets.get(name)
            if bucket is None:
                bucket = TokenBucket(self.capacity, self.refill_per_s,
                                     clock=self._clock)
                self._buckets[name] = bucket
        return bucket

    def admit(self, session: Optional[str], cost: float) -> None:
        """Charge ``cost`` to the session or raise :class:`BudgetExceeded`."""
        bucket = self.bucket(session)
        if not bucket.try_acquire(cost):
            name = session or self.ANONYMOUS
            raise BudgetExceeded(
                f"session {name!r} budget exhausted: request costs "
                f"{cost:.0f} cells, {bucket.available():.0f} available "
                f"(capacity {bucket.capacity:.0f}, refill "
                f"{bucket.refill_per_s:.0f}/s)",
                session=name, cost=cost, available=bucket.available())
