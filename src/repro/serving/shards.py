"""Scoring-shard pool: one window's fused scoring, routed across devices.

:class:`~repro.serving.service.DesignCalculatorService` coalesces a
window into one spliced scoring product per (hardware profile,
sweep-point axis) group — and until this module, that product always
dispatched onto device 0 while every other local device idled.
:class:`ScoringShardPool` is the routing layer in between: it partitions
each group's product into contiguous slices
(:meth:`~repro.core.batchcost.PackedFrontier.split` segment ranges for
flat frontiers, :meth:`~repro.core.batchcost.PackedSweep.split` design
ranges for sweeps), dispatches every partition's fused call onto its own
device from a dedicated thread (``device=`` routing in
:func:`repro.core.devicecost.score_frontier` /
:func:`repro.core.devicecost.score_sweep` — banks committed per device
once, inputs placed explicitly, so concurrent dispatches never contend
on one device queue), and merges the partition totals back into the
single grid the worker slices per request.

Merged results are **bit-identical** to the unsharded call: partitions
cut on tile-aligned segment / design boundaries, so every reduction runs
over exactly the records it would have seen in the flat call, in the
same order (asserted in ``tests/test_sharded.py``).

Deadline composition: the worker passes a ``before_dispatch`` probe that
runs *between* shard dispatches — the PR 6 contract that deadlines are
checked between scoring calls extends to checks between the shards of
one call.  When the probe reports nothing left alive, remaining
dispatches are skipped and the group returns ``None``.

Self-healing (PR 8).  A hung or failed device call must cost one part,
not the window:

* every part-wait carries a **deadline-derived timeout** (the window's
  furthest-out owner deadline, bounded by ``part_timeout_s`` always);
* a failed / non-finite part gets **one bounded retry on a different
  device** (transient corruption rarely follows the part to a second
  device); a *timed-out* part instead races a **hedged duplicate** on a
  different device against the original — first acceptable result wins
  — so a spurious timeout (slow, not hung) costs epsilon, not a full
  serially awaited recompute;
* devices accrue **consecutive-failure counts**; at
  ``quarantine_after`` the device is quarantined for ``quarantine_s``
  (routed around), then **half-open**: the next pick is a probe whose
  success closes the breaker and whose failure re-opens it;
* a part that exhausts its retries gets a **last-resort flat in-thread
  rescore** before the group is declared dead;
* timed-out parts cannot be cancelled (a wedged device call holds its
  executor thread) — they are **abandoned and accounted**
  (``abandoned_parts``), and the executor is replaced when wedged
  threads exhaust its capacity, so the pool never deadlocks behind its
  own casualties.

Faults are injected at the ``shards.dispatch`` seam
(:mod:`repro.testing.faults`, keyed by device id); with no plan active
the steady-state dispatch path is unchanged.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from concurrent.futures import wait as futures_wait
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.batchcost import PackedFrontier, PackedSweep
from repro.core.hardware import HardwareProfile
from repro.testing import faults

#: below this many cells per partition, splitting costs more dispatch
#: overhead than it recovers — one shard serves the whole product
DEFAULT_MIN_CELLS_PER_SHARD = 4096

#: hard upper bound on any one part-wait when no window deadline exists —
#: "a hung device call blocks the worker loop forever" must be impossible
DEFAULT_PART_TIMEOUT_S = 60.0


class ShardTimeout(TimeoutError):
    """One partition's device call exceeded its deadline-derived timeout."""

    def __init__(self, message: str, *, part: int,
                 timeout_s: float) -> None:
        super().__init__(message)
        self.part = part
        self.timeout_s = timeout_s


class NonFiniteScore(RuntimeError):
    """A scoring call produced non-finite totals (corrupt banks or a
    device fault) — caught by the serving tier's engine-fallback chain,
    never surfaced to a client."""


def _swallow(future) -> None:
    """Done-callback for abandoned parts: retrieve and drop the outcome."""
    try:
        future.exception()
    except Exception:
        pass


class ScoringShardPool:
    """Partition, dispatch, heal and merge one scoring product across
    devices (see module docstring).

    ``n_shards=None`` takes every local device; an explicit count is
    clamped to ``[1, len(jax.local_devices())]``.  With one shard — and
    no deadline or active fault plan — the pool degenerates to a plain
    in-thread ``packed.score`` call: no executor hop, byte-for-byte the
    pre-shard service behavior (the default on single-device hosts).
    A window deadline or an active :class:`~repro.testing.faults.
    FaultPlan` routes even a single part through the executor so the
    timeout / retry / rescore machinery applies.
    """

    def __init__(self, n_shards: Optional[int] = None, *,
                 min_cells_per_shard: int = DEFAULT_MIN_CELLS_PER_SHARD,
                 part_timeout_s: float = DEFAULT_PART_TIMEOUT_S,
                 retries: int = 1,
                 quarantine_after: int = 3,
                 quarantine_s: float = 30.0) -> None:
        devices = jax.local_devices()
        wanted = len(devices) if n_shards is None else int(n_shards)
        self.devices = devices[:max(min(wanted, len(devices)), 1)]
        self.n_shards = len(self.devices)
        self.min_cells_per_shard = max(int(min_cells_per_shard), 1)
        self.part_timeout_s = float(part_timeout_s)
        self.retries = max(int(retries), 0)
        self.quarantine_after = max(int(quarantine_after), 1)
        self.quarantine_s = float(quarantine_s)
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {
            "shard_timeouts": 0, "abandoned_parts": 0,
            "shard_retries": 0, "shard_rescored": 0,
            "shard_nonfinite": 0, "device_quarantines": 0,
            "device_probes": 0, "device_recoveries": 0}
        #: recent healing events, newest last: ("retry", part, from_dev,
        #: to_dev) / ("quarantine"|"probe"|"recover", dev) — test and
        #: health() visibility into routing decisions
        self.events: "collections.deque" = collections.deque(maxlen=64)
        #: consecutive failures + breaker state per device
        self._state = [{"fails": 0, "open_until": 0.0}
                       for _ in self.devices]
        # headroom beyond one thread per device: retries need a free
        # thread while the original part is still in flight, and every
        # abandoned (timed-out, uncancellable) part wedges a thread for
        # as long as its device call runs — too little slack funnels the
        # healthy dispatch stream behind casualties, and the queue wait
        # then trips part timeouts on parts that never even started
        self._workers = self.n_shards + 3
        self._lost = 0        # executor threads wedged behind abandoned parts
        self._epoch = 0       # bumped when the executor is replaced
        self._pool = ThreadPoolExecutor(
            max_workers=self._workers, thread_name_prefix="scoring-shard")

    # -- observability ------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Snapshot of the pool's failure-handling counters."""
        with self._lock:
            return dict(self._counters)

    def device_health(self) -> List[Dict]:
        """Per-device breaker state: ``ok`` / ``quarantined`` (routed
        around) / ``half-open`` (next pick is a probe)."""
        now = time.monotonic()
        out = []
        with self._lock:
            for device, st in zip(self.devices, self._state):
                if st["fails"] < self.quarantine_after:
                    state = "ok"
                elif st["open_until"] > now:
                    state = "quarantined"
                else:
                    state = "half-open"
                out.append({"device": device.id, "state": state,
                            "consecutive_failures": st["fails"],
                            "reopen_in_s": max(st["open_until"] - now,
                                               0.0)})
        return out

    def recent_events(self) -> List[Tuple]:
        with self._lock:
            return list(self.events)

    def _count(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._counters[key] += n

    # -- device breaker bookkeeping -----------------------------------------
    def _device_ok(self, dev: int) -> None:
        with self._lock:
            st = self._state[dev]
            if st["fails"] >= self.quarantine_after:
                self._counters["device_recoveries"] += 1
                self.events.append(("recover", dev))
            st["fails"] = 0
            st["open_until"] = 0.0

    def _device_fail(self, dev: int) -> None:
        now = time.monotonic()
        with self._lock:
            st = self._state[dev]
            st["fails"] += 1
            if st["fails"] >= self.quarantine_after \
                    and st["open_until"] <= now:
                st["open_until"] = now + self.quarantine_s
                self._counters["device_quarantines"] += 1
                self.events.append(("quarantine", dev))

    def _pick_device(self, i: int, exclude: Tuple[int, ...] = ()) -> int:
        """Round-robin from ``i`` over healthy devices; quarantined ones
        are routed around until their window lapses, at which point the
        first pick is a half-open probe.  Falls back to the least-bad
        device when everything is excluded or quarantined (scoring must
        go *somewhere*; the retry/rescore ladder covers a bad pick)."""
        now = time.monotonic()
        with self._lock:
            order = [(i + k) % self.n_shards
                     for k in range(self.n_shards)]
            usable = [d for d in order if d not in exclude]
            closed = [d for d in usable
                      if self._state[d]["fails"] < self.quarantine_after]
            if closed:
                return closed[0]
            half_open = [d for d in usable
                         if self._state[d]["open_until"] <= now]
            if half_open:
                dev = half_open[0]
                self._counters["device_probes"] += 1
                self.events.append(("probe", dev))
                return dev
            return usable[0] if usable else order[0]

    # -- executor management ------------------------------------------------
    def _executor(self) -> ThreadPoolExecutor:
        """The live executor — replaced (old one leaked deliberately to
        its wedged threads) once abandoned parts hold every worker."""
        with self._lock:
            if self._lost >= self._workers:
                self._pool.shutdown(wait=False)
                self._pool = ThreadPoolExecutor(
                    max_workers=self._workers,
                    thread_name_prefix="scoring-shard")
                self._lost = 0
                self._epoch += 1
            return self._pool

    def _abandon(self, futures: List) -> None:
        """Cancel what still can be; account for in-flight parts that
        cannot (they keep a device and an executor thread busy invisibly
        — the counter is the visibility) and swallow their results."""
        for f in futures:
            if f.cancel():
                continue
            if f.done():
                _swallow(f)
                continue
            with self._lock:
                self._counters["abandoned_parts"] += 1
                self._lost += 1
                epoch = self._epoch

            def _done(fut, _epoch=epoch):
                with self._lock:
                    if self._epoch == _epoch and self._lost > 0:
                        self._lost -= 1
                _swallow(fut)
            f.add_done_callback(_done)

    # -- dispatch and healing -----------------------------------------------
    def partitions(self, cells: int) -> int:
        """How many partitions a product of ``cells`` would occupy."""
        if self.n_shards == 1 or cells <= 0:
            return 1
        return max(min(self.n_shards,
                       cells // self.min_cells_per_shard), 1)

    def _timeout_for(self, deadline: Optional[float]) -> float:
        """One part-wait's budget: the window deadline's remaining time
        (floored so a just-expired deadline still lets an already-done
        future deliver), bounded by ``part_timeout_s`` either way."""
        if deadline is None:
            return self.part_timeout_s
        return max(min(self.part_timeout_s,
                       deadline - time.monotonic()), 0.01)

    def _submit(self, part, hw: HardwareProfile, engine: str, dev: int):
        device = self.devices[dev]

        def _run():
            faults.check("shards.dispatch", device.id)
            return part.score(hw, engine=engine, shard=False,
                              device=device)
        return self._executor().submit(_run)

    def _await(self, future, deadline: Optional[float]):
        """``("ok", totals)`` / ``("timeout", seconds)`` /
        ``("nonfinite", None)`` / ``("error", exception)``."""
        timeout = self._timeout_for(deadline)
        try:
            value = future.result(timeout=timeout)
        except FutureTimeout:
            return "timeout", timeout
        except Exception as exc:
            return "error", exc
        if not np.isfinite(value).all():
            return "nonfinite", None
        return "ok", value

    def _note_failure(self, status, detail, dev: int, future,
                      abandon: bool = True) -> None:
        self._device_fail(dev)
        if status == "timeout":
            self._count("shard_timeouts")
            if abandon:
                self._abandon([future])
        elif status == "nonfinite":
            self._count("shard_nonfinite")

    def _hedge(self, idx: int, part, hw: HardwareProfile, engine: str,
               dev: int, original, deadline: Optional[float]):
        """Race a timed-out part against a hedged duplicate on another
        device; the first acceptable result wins and the straggler is
        abandoned.  A *spurious* timeout — the original was merely slow
        under scheduling noise or CPU contention, not hung — then costs
        the wait already paid plus epsilon, instead of a full serially
        awaited recompute (which on a small host cascades: the abandoned
        part still burns the core its duplicate needs)."""
        retry_dev = self._pick_device(idx + 1, exclude=(dev,)) \
            if self.n_shards > 1 else dev
        self._count("shard_retries")
        with self._lock:
            self.events.append(("retry", idx, dev, retry_dev))
        pending = {original: dev,
                   self._submit(part, hw, engine, retry_dev): retry_dev}
        # the race gets twice the per-part budget (deadline-capped): the
        # duplicate needs room for its own compute under contention —
        # a too-tight window here turns every hedge into a flat rescore
        # on top of two abandoned still-running computes
        budget = 2 * self.part_timeout_s
        if deadline is not None:
            budget = max(min(budget, deadline - time.monotonic()), 0.01)
        end = time.monotonic() + budget
        while pending:
            done, _ = futures_wait(list(pending),
                                   timeout=max(end - time.monotonic(), 0.0),
                                   return_when=FIRST_COMPLETED)
            if not done:
                self._count("shard_timeouts")
                break
            for f in done:
                d = pending.pop(f)
                try:
                    value = f.result()
                except Exception:
                    self._device_fail(d)
                    continue
                if not np.isfinite(value).all():
                    self._count("shard_nonfinite")
                    self._device_fail(d)
                    continue
                self._device_ok(d)
                self._abandon(list(pending))
                return value
            if set(pending) == {original}:
                # the duplicate died and only the original — which
                # already blew its timeout once — is left: bail to the
                # flat rescore now instead of sleeping out the rest of
                # the hedge budget on a part that is likely hung
                break
        for d in pending.values():
            self._device_fail(d)
        self._abandon(list(pending))
        return None

    def _heal_part(self, idx: int, part, hw: HardwareProfile, engine: str,
                   dev: int, future, deadline: Optional[float]):
        """Await one part; a timed-out part races a hedged duplicate on
        another device (first acceptable result back wins), other
        failures get one bounded retry on a different device, and both
        ladders fall back to a flat in-thread rescore of just this part."""
        status, detail = self._await(future, deadline)
        if status == "ok":
            self._device_ok(dev)
            return detail
        hedging = status == "timeout" and self.retries > 0
        self._note_failure(status, detail, dev, future,
                           abandon=not hedging)
        last_error = detail if status == "error" else None
        if hedging:
            value = self._hedge(idx, part, hw, engine, dev, future,
                                deadline)
            if value is not None:
                return value
        else:
            for _ in range(self.retries):
                retry_dev = self._pick_device(idx + 1, exclude=(dev,)) \
                    if self.n_shards > 1 else dev
                self._count("shard_retries")
                with self._lock:
                    self.events.append(("retry", idx, dev, retry_dev))
                future = self._submit(part, hw, engine, retry_dev)
                status, detail = self._await(future, deadline)
                if status == "ok":
                    self._device_ok(retry_dev)
                    return detail
                self._note_failure(status, detail, retry_dev, future)
                if status == "error":
                    last_error = detail
                dev = retry_dev
        # last resort: rescore ONLY this part, flat, in the worker thread
        self._count("shard_rescored")
        try:
            value = part.score(hw, engine=engine, shard=False)
        except Exception:
            if last_error is not None:
                raise last_error
            if status == "timeout":
                raise ShardTimeout(
                    f"part {idx} timed out on-device and failed its flat "
                    f"rescore", part=idx, timeout_s=detail) from None
            raise
        if not np.isfinite(value).all():
            self._count("shard_nonfinite")
            raise NonFiniteScore(
                f"part {idx} totals non-finite after retry and flat "
                f"rescore (corrupt parameter banks?)")
        return value

    def _score_parts(self, parts: List, hw: HardwareProfile, engine: str,
                     before_dispatch: Optional[Callable[[int], bool]],
                     deadline: Optional[float]) -> Optional[List]:
        if len(parts) == 1 and deadline is None \
                and faults.active() is None:
            # steady-state single-part fast path: in-thread, no executor
            # hop — byte-for-byte the pre-shard service behavior
            if before_dispatch is not None and not before_dispatch(0):
                return None
            value = parts[0].score(hw, engine=engine)
            if not np.isfinite(value).all():
                self._count("shard_nonfinite")
                raise NonFiniteScore(
                    "totals non-finite (corrupt parameter banks?)")
            return [value]
        entries = []
        for i, part in enumerate(parts):
            if before_dispatch is not None and not before_dispatch(i):
                self._abandon([f for _, f in entries])
                return None
            dev = self._pick_device(i)
            entries.append((dev, self._submit(part, hw, engine, dev)))
        return [self._heal_part(i, parts[i], hw, engine, dev, fut,
                                deadline)
                for i, (dev, fut) in enumerate(entries)]

    # -- the scoring entry points -------------------------------------------
    def score_frontier(self, packed: PackedFrontier, hw: HardwareProfile,
                       engine: str = "fused",
                       before_dispatch: Optional[Callable[[int], bool]]
                       = None, deadline: Optional[float] = None
                       ) -> Tuple[Optional[np.ndarray], int]:
        """``(per-design totals, shards used)`` for a spliced frontier.

        Totals are ``None`` only when ``before_dispatch`` aborted the
        group (every owner already expired).  ``deadline`` is the
        window's absolute ``time.monotonic()`` deadline: every part-wait
        is bounded by its remaining time (and by ``part_timeout_s``
        regardless), raising :class:`ShardTimeout` instead of blocking
        the worker loop forever behind a hung device call."""
        n = self.partitions(packed.n_segments) if engine == "fused" else 1
        parts = packed.split(n)
        results = self._score_parts(list(parts), hw, engine,
                                    before_dispatch, deadline)
        if results is None:
            return None, 0
        if len(results) == 1:
            return results[0], 1
        return np.concatenate(results), len(parts)

    def score_sweep(self, sweep: PackedSweep, hw: HardwareProfile,
                    engine: str = "fused",
                    before_dispatch: Optional[Callable[[int], bool]]
                    = None, deadline: Optional[float] = None
                    ) -> Tuple[Optional[np.ndarray], int]:
        """``([points, designs] grid, shards used)`` for a spliced sweep.

        Partitions cut the design axis (every coalesced sweep in the
        group shares the point axis); the merged grid stacks partition
        columns back in order.  ``deadline`` bounds part-waits exactly
        as in :meth:`score_frontier`."""
        n = self.partitions(sweep.n_points * sweep.n_designs) \
            if engine == "fused" else 1
        parts = sweep.split(min(n, max(sweep.n_designs, 1)))
        results = self._score_parts(list(parts), hw, engine,
                                    before_dispatch, deadline)
        if results is None:
            return None, 0
        if len(results) == 1:
            return results[0], 1
        return np.concatenate(results, axis=1), len(parts)

    def close(self) -> None:
        with self._lock:
            pool = self._pool
        if pool is not None:
            pool.shutdown(wait=False)
