"""Scoring-shard pool: one window's fused scoring, routed across devices.

:class:`~repro.serving.service.DesignCalculatorService` coalesces a
window into one spliced scoring product per (hardware profile,
sweep-point axis) group — and until this module, that product always
dispatched onto device 0 while every other local device idled.
:class:`ScoringShardPool` is the routing layer in between: it partitions
each group's product into contiguous slices
(:meth:`~repro.core.batchcost.PackedFrontier.split` segment ranges for
flat frontiers, :meth:`~repro.core.batchcost.PackedSweep.split` design
ranges for sweeps), dispatches every partition's fused call onto its own
device from a dedicated thread (``device=`` routing in
:func:`repro.core.devicecost.score_frontier` /
:func:`repro.core.devicecost.score_sweep` — banks committed per device
once, inputs placed explicitly, so concurrent dispatches never contend
on one device queue), and merges the partition totals back into the
single grid the worker slices per request.

Merged results are **bit-identical** to the unsharded call: partitions
cut on tile-aligned segment / design boundaries, so every reduction runs
over exactly the records it would have seen in the flat call, in the
same order (asserted in ``tests/test_sharded.py``).

Deadline composition: the worker passes a ``before_dispatch`` probe that
runs *between* shard dispatches — the PR 6 contract that deadlines are
checked between scoring calls extends to checks between the shards of
one call.  When the probe reports nothing left alive, remaining
dispatches are skipped and the group returns ``None``.

Every wait on a dispatched part is **bounded**: ``part_timeout_s``
(capped by the group's remaining ``deadline`` when one is set) turns a
hung device into a typed :class:`ShardTimeout` instead of a worker
thread blocked forever on ``Future.result()``.  Parts the pool walks
away from — a timed-out sibling, an aborted group — cannot always be
cancelled (`concurrent.futures` futures already running are
uncancellable): those are *abandoned*, their eventual results swallowed
and their count surfaced in ``stats()["abandoned_parts"]``, because an
invisible thread still occupying a device is exactly the kind of state
an operator needs to see.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.batchcost import PackedFrontier, PackedSweep
from repro.core.hardware import HardwareProfile

#: below this many cells per partition, splitting costs more dispatch
#: overhead than it recovers — one shard serves the whole product
DEFAULT_MIN_CELLS_PER_SHARD = 4096

#: generous default bound on one part's device call — the point is that
#: a wait is never *unbounded*, not that 60s is a good serving deadline
#: (the service derives much tighter per-part budgets from its window
#: deadlines)
DEFAULT_PART_TIMEOUT_S = 60.0


class ShardTimeout(TimeoutError):
    """One partition's device call exceeded its deadline-derived timeout."""

    def __init__(self, message: str, *, part: int,
                 timeout_s: float) -> None:
        super().__init__(message)
        self.part = part
        self.timeout_s = timeout_s


def _swallow(future) -> None:
    """Done-callback for abandoned parts: retrieve and drop the outcome."""
    try:
        future.exception()
    except Exception:
        pass


class ScoringShardPool:
    """Partition, dispatch and merge one scoring product across devices.

    ``n_shards=None`` takes every local device; an explicit count is
    clamped to ``[1, len(jax.local_devices())]``.  With one shard the
    pool degenerates to a plain in-thread ``packed.score`` call — no
    executor, no partitioning, byte-for-byte the pre-shard service
    behavior (the default on single-device hosts).
    """

    def __init__(self, n_shards: Optional[int] = None, *,
                 min_cells_per_shard: int = DEFAULT_MIN_CELLS_PER_SHARD,
                 part_timeout_s: float = DEFAULT_PART_TIMEOUT_S) -> None:
        devices = jax.local_devices()
        wanted = len(devices) if n_shards is None else int(n_shards)
        self.devices = devices[:max(min(wanted, len(devices)), 1)]
        self.n_shards = len(self.devices)
        self.min_cells_per_shard = max(int(min_cells_per_shard), 1)
        self.part_timeout_s = float(part_timeout_s)
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {
            "shard_timeouts": 0,
            "abandoned_parts": 0,
        }
        self._pool = ThreadPoolExecutor(
            max_workers=self.n_shards,
            thread_name_prefix="scoring-shard") \
            if self.n_shards > 1 else None

    def stats(self) -> Dict[str, int]:
        """Snapshot of the pool's failure-handling counters."""
        with self._lock:
            return dict(self._counters)

    def _count(self, key: str, by: int = 1) -> None:
        with self._lock:
            self._counters[key] += by

    def _timeout_for(self, deadline: Optional[float]) -> float:
        """One part-wait's budget: the window deadline's remaining time
        (floored so a just-expired deadline still lets an already-done
        future deliver), bounded by ``part_timeout_s`` either way."""
        if deadline is None:
            return self.part_timeout_s
        return max(min(self.part_timeout_s,
                       deadline - time.monotonic()), 0.01)

    def _abandon(self, futures: List) -> None:
        """Cancel what still can be; account for in-flight parts that
        cannot (they keep a device and an executor thread busy invisibly
        — the counter is the visibility) and swallow their results."""
        for f in futures:
            if f.cancel():
                continue
            if f.done():
                _swallow(f)
                continue
            self._count("abandoned_parts")
            f.add_done_callback(_swallow)

    def _gather(self, futures: List, deadline: Optional[float]) -> List:
        """Await every part with a bounded wait; a timeout abandons the
        stragglers and raises a typed :class:`ShardTimeout`."""
        results = []
        for i, f in enumerate(futures):
            timeout = self._timeout_for(deadline)
            try:
                results.append(f.result(timeout=timeout))
            except FutureTimeout:
                self._count("shard_timeouts")
                self._abandon(futures[i:])
                raise ShardTimeout(
                    f"part {i} exceeded its {timeout:.3f}s bounded wait",
                    part=i, timeout_s=timeout) from None
        return results

    def partitions(self, cells: int) -> int:
        """How many partitions a product of ``cells`` would occupy."""
        if self._pool is None or cells <= 0:
            return 1
        return max(min(self.n_shards,
                       cells // self.min_cells_per_shard), 1)

    def score_frontier(self, packed: PackedFrontier, hw: HardwareProfile,
                       engine: str = "fused",
                       before_dispatch: Optional[Callable[[int], bool]]
                       = None,
                       deadline: Optional[float] = None
                       ) -> Tuple[Optional[np.ndarray], int]:
        """``(per-design totals, shards used)`` for a spliced frontier.

        Totals are ``None`` only when ``before_dispatch`` aborted the
        group (every owner already expired)."""
        n = self.partitions(packed.n_segments) if engine == "fused" else 1
        parts = packed.split(n)
        if len(parts) <= 1:
            if before_dispatch is not None and not before_dispatch(0):
                return None, 0
            return packed.score(hw, engine=engine), 1
        futures = self._dispatch(parts, hw, engine, before_dispatch)
        if futures is None:
            return None, 0
        return np.concatenate(self._gather(futures, deadline)), len(parts)

    def score_sweep(self, sweep: PackedSweep, hw: HardwareProfile,
                    engine: str = "fused",
                    before_dispatch: Optional[Callable[[int], bool]]
                    = None,
                    deadline: Optional[float] = None
                    ) -> Tuple[Optional[np.ndarray], int]:
        """``([points, designs] grid, shards used)`` for a spliced sweep.

        Partitions cut the design axis (every coalesced sweep in the
        group shares the point axis); the merged grid stacks partition
        columns back in order."""
        n = self.partitions(sweep.n_points * sweep.n_designs) \
            if engine == "fused" else 1
        parts = sweep.split(min(n, max(sweep.n_designs, 1)))
        if len(parts) <= 1:
            if before_dispatch is not None and not before_dispatch(0):
                return None, 0
            return sweep.score(hw, engine=engine), 1
        futures = self._dispatch(parts, hw, engine, before_dispatch)
        if futures is None:
            return None, 0
        return np.concatenate(self._gather(futures, deadline),
                              axis=1), len(parts)

    def _dispatch(self, parts: List, hw: HardwareProfile, engine: str,
                  before_dispatch: Optional[Callable[[int], bool]]):
        """Submit one device-routed score per partition; ``None`` when
        the probe aborts.  Already-submitted shards are cancelled where
        possible — a running future ignores ``cancel()``, so those are
        abandoned-and-accounted, not silently leaked."""
        futures = []
        for i, part in enumerate(parts):
            if before_dispatch is not None and not before_dispatch(i):
                self._abandon(futures)
                return None
            device = self.devices[i % self.n_shards]
            futures.append(self._pool.submit(
                part.score, hw, engine=engine, shard=False, device=device))
        return futures

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
