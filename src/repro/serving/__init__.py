"""Concurrent what-if serving (the ROADMAP's async-serving milestone).

A :class:`~repro.serving.service.DesignCalculatorService` is a long-lived
scoring service: it holds the device-resident parameter banks of its
registered hardware profiles plus the packed-frontier/segment caches, and
answers concurrent what-if (design / hardware / workload), workload-sweep
and auto-completion questions by coalescing a window of them into one
fused scoring call per hardware profile (see ``docs/serving.md``).

Production traffic hardening (PR 6): requests are admitted through
bounded priority lanes (interactive vs bulk) with optional per-session
cost budgets, carry deadlines, and shed explicitly under overload
(:mod:`repro.serving.admission`, :mod:`repro.serving.lanes`); the
service warm-restarts from an on-disk snapshot of the synthesis memos.

Multi-device routing (PR 7): a scoring-shard pool
(:mod:`repro.serving.shards`) partitions each coalesced window's spliced
frontier/sweep across local devices, dispatches the partitions
concurrently with deadlines probed between shard dispatches, and merges
bit-identical totals before any future resolves.
"""
from repro.serving.admission import (BudgetExceeded, DeadlineExceeded,
                                     RejectedError, ServiceError,
                                     ServiceStoppedError, SessionBudgets,
                                     TokenBucket, request_cost)
from repro.serving.lanes import BULK, INTERACTIVE, LaneScheduler
from repro.serving.service import (DesignCalculatorService, ServiceSession,
                                   ServiceStats)
from repro.serving.shards import ScoringShardPool

__all__ = [
    "DesignCalculatorService", "ServiceSession", "ServiceStats",
    "ServiceError", "RejectedError", "BudgetExceeded", "DeadlineExceeded",
    "ServiceStoppedError", "TokenBucket", "SessionBudgets", "request_cost",
    "LaneScheduler", "INTERACTIVE", "BULK", "ScoringShardPool",
]
