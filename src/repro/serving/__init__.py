"""Concurrent what-if serving (the ROADMAP's async-serving milestone).

A :class:`~repro.serving.service.DesignCalculatorService` is a long-lived
scoring service: it holds the device-resident parameter banks of its
registered hardware profiles plus the packed-frontier/segment caches, and
answers concurrent what-if (design / hardware / workload), workload-sweep
and auto-completion questions by coalescing a window of them into one
fused scoring call per hardware profile (see ``docs/serving.md``).
"""
from repro.serving.service import (DesignCalculatorService, ServiceSession,
                                   ServiceStats)

__all__ = ["DesignCalculatorService", "ServiceSession", "ServiceStats"]
