"""Concurrent what-if serving (the ROADMAP's async-serving milestone).

A :class:`~repro.serving.service.DesignCalculatorService` is a long-lived
scoring service: it holds the device-resident parameter banks of its
registered hardware profiles plus the packed-frontier/segment caches, and
answers concurrent what-if (design / hardware / workload), workload-sweep
and auto-completion questions by coalescing a window of them into one
fused scoring call per hardware profile (see ``docs/serving.md``).

Production traffic hardening (PR 6): requests are admitted through
bounded priority lanes (interactive vs bulk) with optional per-session
cost budgets, carry deadlines, and shed explicitly under overload
(:mod:`repro.serving.admission`, :mod:`repro.serving.lanes`); the
service warm-restarts from an on-disk snapshot of the synthesis memos.

Multi-device routing (PR 7): a scoring-shard pool
(:mod:`repro.serving.shards`) partitions each coalesced window's spliced
frontier/sweep across local devices, dispatches the partitions
concurrently with deadlines probed between shard dispatches, and merges
bit-identical totals before any future resolves.

Self-healing (PR 8): failed/timed-out shard parts retry on a different
device behind a per-device circuit breaker; non-finite fused results
fall back fused-sharded -> fused-flat -> grouped oracle per evaluation
(answers carry the producing ``engine`` tag); a supervisor resurrects a
crashed worker, failing in-flight futures with the typed
:class:`~repro.serving.admission.WorkerCrashed`.  Fault-tolerance state
is observable via ``Service.health()`` and the ``stats()`` counters, and
exercisable deterministically with :mod:`repro.testing.faults`.
"""
from repro.serving.admission import (BudgetExceeded, DeadlineExceeded,
                                     RejectedError, ServiceError,
                                     ServiceStoppedError, SessionBudgets,
                                     TokenBucket, WorkerCrashed,
                                     request_cost)
from repro.serving.lanes import BULK, INTERACTIVE, LaneScheduler
from repro.serving.service import (DesignCalculatorService, ServiceSession,
                                   ServiceStats)
from repro.serving.shards import (NonFiniteScore, ScoringShardPool,
                                  ShardTimeout)

__all__ = [
    "DesignCalculatorService", "ServiceSession", "ServiceStats",
    "ServiceError", "RejectedError", "BudgetExceeded", "DeadlineExceeded",
    "ServiceStoppedError", "WorkerCrashed", "TokenBucket", "SessionBudgets",
    "request_cost", "LaneScheduler", "INTERACTIVE", "BULK",
    "ScoringShardPool", "ShardTimeout", "NonFiniteScore",
]
