"""Micro-batching what-if serving engine.

The paper's headline promise is *interactive* design questions — answers
"on the order of a few seconds or minutes" — and the access pattern of a
design session (Learning Key-Value Store Design, Idreos et al.) is long
runs of many small, related questions against a shared design continuum.
Served naively, every question pays a full fused-scorer dispatch, and
concurrent designers hammer the module-level synthesis memos from many
threads.

:class:`DesignCalculatorService` is the long-lived serving loop those
sessions talk to:

* **Resident state.**  Registered :class:`~repro.core.hardware.
  HardwareProfile`s keep their device parameter banks built
  (:func:`repro.core.devicecost.device_table`), so no question ever pays
  bank construction; the packed-frontier/segment caches of
  :mod:`repro.core.batchcost` (thread-safe via
  :mod:`repro.core.memo`) persist across questions.
* **Micro-batching.**  Requests are submitted from any thread and return
  :class:`concurrent.futures.Future`s.  A single worker drains the queue:
  the first request opens a coalescing window (``window_s``), everything
  arriving inside it joins the batch, and the batch is served by splicing
  every question's packed frontier into **one**
  :func:`~repro.core.batchcost.concat_frontiers` frontier per distinct
  hardware profile — one fused scoring call each.  A hardware-variant
  question contributes the *same* packed frontier to two profile groups:
  a pure parameter-table swap, zero recompilation.
* **Per-session frontier reuse.**  A :class:`ServiceSession` pins the
  packed frontiers of its recent questions, so a designer iterating on
  one baseline never re-packs it — even if a burst of unrelated traffic
  evicts it from the global LRU caches.
* **Workload sweeps** (PR 5).  ``submit_sweep`` serves whole
  (designs x workloads) grids — read/write-ratio or skew continuums —
  through the :func:`repro.core.batchcost.pack_sweep` engine.  Sweeps in
  one window sharing a workload-point axis splice along the design axis
  (``concat_sweeps``) and score as ONE fused sweep call per hardware
  profile, exactly like flat questions coalesce via
  ``concat_frontiers``; retained sweeps pin in sessions like frontiers.

Answers are exactly :class:`~repro.core.whatif.WhatIfAnswer` /
:class:`~repro.core.whatif.WorkloadSweepAnswer` /
:class:`~repro.core.autocomplete.SearchResult`; parity with the serial
scalar oracle (to the fused engine's documented 1e-6) is asserted in
``tests/test_serving.py``, ``tests/test_sweep.py`` and
``benchmarks/serving_bench.py``.  Semantics are documented in
``docs/serving.md``.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import devicecost
from repro.core.autocomplete import SearchResult, enumerate_frontier
from repro.core.batchcost import (PackedFrontier, PackedSweep,
                                  concat_frontiers, concat_sweeps,
                                  normalize_points, pack_frontier,
                                  pack_sweep)
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.synthesis import Workload
from repro.core.whatif import (WhatIfAnswer, WorkloadSweepAnswer,
                               question_design, question_hardware,
                               question_sweep, question_workload)


@dataclasses.dataclass
class ServiceStats:
    """Serving counters (snapshot with :meth:`DesignCalculatorService.stats`)."""

    questions: int = 0          # requests submitted
    answered: int = 0           # futures resolved successfully
    failed: int = 0             # futures resolved with an exception
    batches: int = 0            # non-empty coalescing windows served
    empty_windows: int = 0      # windows that closed with no requests
    coalesced: int = 0          # requests that shared a batch with others
    score_calls: int = 0        # fused/grouped scoring calls issued
    max_batch: int = 0          # largest batch served
    session_frontier_hits: int = 0
    sweeps: int = 0             # workload-sweep requests submitted


@dataclasses.dataclass
class _Evaluation:
    """One frontier-under-one-profile scoring unit of a request.

    Requests decompose into evaluations; the batcher groups evaluations
    by (hardware profile, sweep points) and scores each group in one
    fused call.  After scoring, ``totals`` holds this evaluation's
    per-design slice (flat questions) or its ``[points, designs]`` grid
    columns (sweeps, where ``points`` is set and ``workload``/``mix``
    are unused).
    """

    specs: Tuple[DataStructureSpec, ...]
    workload: Optional[Workload]
    mix: Optional[Dict[str, float]]
    hw_name: str
    session: Optional[str] = None
    points: Optional[Tuple] = None      # sweep evaluations only
    packed: Optional[PackedFrontier] = None   # PackedSweep for sweeps
    totals: Optional[np.ndarray] = None
    error: Optional[Exception] = None   # this evaluation's scoring failure


@dataclasses.dataclass
class _Request:
    evals: List[_Evaluation]
    finalize: Callable[[float], object]   # elapsed-seconds -> answer
    future: Future
    t0: float


class _SessionState:
    """Packed frontiers pinned by one session (worker-thread only)."""

    def __init__(self, maxsize: int = 64) -> None:
        self.frontiers: "collections.OrderedDict" = collections.OrderedDict()
        self.maxsize = maxsize

    def get(self, key) -> Optional[PackedFrontier]:
        packed = self.frontiers.get(key)
        if packed is not None:
            self.frontiers.move_to_end(key)
        return packed

    def put(self, key, packed: PackedFrontier) -> None:
        self.frontiers[key] = packed
        if len(self.frontiers) > self.maxsize:
            self.frontiers.popitem(last=False)


@dataclasses.dataclass
class ServiceSession:
    """A designer's handle on the service: same questions, pinned frontiers."""

    service: "DesignCalculatorService"
    name: str

    def what_if_design(self, spec, variant, workload, hw, mix=None):
        return self.service.what_if_design(spec, variant, workload, hw, mix,
                                           session=self.name)

    def what_if_hardware(self, spec, workload, hw, new_hw, mix=None):
        return self.service.what_if_hardware(spec, workload, hw, new_hw, mix,
                                             session=self.name)

    def what_if_workload(self, spec, workload, new_workload, hw, mix=None):
        return self.service.what_if_workload(spec, workload, new_workload,
                                             hw, mix, session=self.name)

    def complete_design(self, partial, workload, hw, **kwargs):
        return self.service.complete_design(partial, workload, hw,
                                            session=self.name, **kwargs)

    def workload_sweep(self, specs, workloads, hw, mixes=None):
        return self.service.workload_sweep(specs, workloads, hw, mixes,
                                           session=self.name)


class DesignCalculatorService:
    """Long-lived concurrent what-if server (see module docstring).

    Parameters
    ----------
    profiles:
        Hardware profiles to register up front (device banks are built
        immediately; more can be registered later, or implicitly by
        asking a question about an unregistered profile object).
    window_s:
        The coalescing window: how long the worker keeps a batch open
        after its first request arrives.
    max_batch:
        Hard cap on requests per batch (the window closes early).
    engine:
        ``"fused"`` (default) or ``"grouped"`` — every scoring call goes
        through :meth:`PackedFrontier.score` with this engine.
    """

    def __init__(self, profiles: Sequence[HardwareProfile] = (), *,
                 window_s: float = 0.002, max_batch: int = 1024,
                 engine: str = "fused", start: bool = True) -> None:
        if engine not in ("fused", "grouped"):
            raise ValueError(f"unknown serving engine: {engine!r}")
        self._engine = engine
        self._window = window_s
        self._max_batch = max_batch
        self._profiles: Dict[str, HardwareProfile] = {}
        self._sessions: Dict[str, _SessionState] = {}
        self._session_counter = itertools.count()
        self._queue: "queue.Queue[Optional[_Request]]" = queue.Queue()
        self._lock = threading.Lock()      # profiles/sessions/stats registry
        self._stats = ServiceStats()
        self._thread: Optional[threading.Thread] = None
        for hw in profiles:
            self.register_hardware(hw)
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="design-calculator-serving")
        self._thread.start()

    def stop(self, timeout: Optional[float] = None) -> None:
        """Drain already-queued requests, then stop the worker.

        Requests that slip in behind the shutdown sentinel are failed
        (never left with a forever-pending future).  If ``timeout``
        expires with the worker still running, the service stays
        stoppable/startable — the thread is only forgotten once dead."""
        if self._thread is None:
            return
        self._queue.put(None)
        self._thread.join(timeout)
        if self._thread.is_alive():    # timed out; try again later
            return
        self._thread = None
        self._fail_pending()

    def _fail_pending(self) -> None:
        """Fail every request still queued after the worker has exited."""
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is None:
                continue
            req.future.set_exception(
                RuntimeError("service stopped before serving this request"))
            with self._lock:
                self._stats.failed += 1

    close = stop

    def __enter__(self) -> "DesignCalculatorService":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- registry -----------------------------------------------------------
    def register_hardware(self, hw: HardwareProfile) -> str:
        """Register a profile and build its device parameter banks now, so
        the first question about it pays no bank construction."""
        with self._lock:
            self._profiles[hw.name] = hw
        devicecost.device_table(hw)
        return hw.name

    def _profile_name(self, hw) -> str:
        if isinstance(hw, str):
            if hw not in self._profiles:
                raise KeyError(f"unregistered hardware profile: {hw!r}")
            return hw
        if self._profiles.get(hw.name) is not hw:
            self.register_hardware(hw)
        return hw.name

    def session(self, name: Optional[str] = None) -> ServiceSession:
        """Open (or re-attach to) a designer session with pinned frontiers."""
        name = name or f"session-{next(self._session_counter)}"
        with self._lock:
            self._sessions.setdefault(name, _SessionState())
        return ServiceSession(self, name)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(dataclasses.asdict(self._stats))

    # -- submission (any thread) --------------------------------------------
    def submit_design(self, spec: DataStructureSpec,
                      variant: DataStructureSpec, workload: Workload, hw,
                      mix: Optional[Dict[str, float]] = None,
                      session: Optional[str] = None) -> Future:
        hw_name = self._profile_name(hw)
        ev = _Evaluation((spec, variant), workload, mix, hw_name, session)

        def finalize(elapsed: float) -> WhatIfAnswer:
            return WhatIfAnswer(question_design(spec, variant),
                                float(ev.totals[0]), float(ev.totals[1]),
                                elapsed)
        return self._submit([ev], finalize)

    def submit_hardware(self, spec: DataStructureSpec, workload: Workload,
                        hw, new_hw,
                        mix: Optional[Dict[str, float]] = None,
                        session: Optional[str] = None) -> Future:
        base_hw = self._profiles[self._profile_name(hw)]
        var_hw = self._profiles[self._profile_name(new_hw)]
        # identical (specs, workload, mix): both evaluations resolve to the
        # SAME packed frontier, scored under two profile groups — the
        # what-if-hardware table swap, now amortized across a whole batch
        base = _Evaluation((spec,), workload, mix, base_hw.name, session)
        var = _Evaluation((spec,), workload, mix, var_hw.name, session)

        def finalize(elapsed: float) -> WhatIfAnswer:
            return WhatIfAnswer(question_hardware(base_hw, var_hw),
                                float(base.totals[0]), float(var.totals[0]),
                                elapsed)
        return self._submit([base, var], finalize)

    def submit_workload(self, spec: DataStructureSpec, workload: Workload,
                        new_workload: Workload, hw,
                        mix: Optional[Dict[str, float]] = None,
                        session: Optional[str] = None) -> Future:
        hw_name = self._profile_name(hw)
        base = _Evaluation((spec,), workload, mix, hw_name, session)
        var = _Evaluation((spec,), new_workload, mix, hw_name, session)

        def finalize(elapsed: float) -> WhatIfAnswer:
            return WhatIfAnswer(question_workload(workload, new_workload),
                                float(base.totals[0]), float(var.totals[0]),
                                elapsed)
        return self._submit([base, var], finalize)

    def submit_complete(self, partial: Sequence[Element],
                        workload: Workload, hw,
                        candidates: Optional[Sequence[Element]] = None,
                        terminals: Optional[Sequence[Element]] = None,
                        mix: Optional[Dict[str, float]] = None,
                        max_depth: int = 3, name: str = "auto",
                        session: Optional[str] = None) -> Future:
        hw_name = self._profile_name(hw)
        # enumeration is structural and memoized — do it at submit time so
        # the whole window's frontiers are known when the batch closes
        frontier = enumerate_frontier(partial, candidates, terminals,
                                      max_depth, name)
        if not frontier:
            with self._lock:   # counted like any other failed question
                self._stats.questions += 1
                self._stats.failed += 1
            fut: Future = Future()
            fut.set_exception(RuntimeError("no valid completion found"))
            return fut
        ev = _Evaluation(frontier, workload, mix, hw_name, session)

        def finalize(elapsed: float) -> SearchResult:
            best = int(np.argmin(ev.totals))
            return SearchResult(frontier[best], float(ev.totals[best]),
                                len(frontier), elapsed)
        return self._submit([ev], finalize)

    def submit_sweep(self, specs: Sequence[DataStructureSpec],
                     workloads: Sequence[Workload], hw,
                     mixes=None,
                     session: Optional[str] = None) -> Future:
        """A (designs x workloads) grid as one request.

        Sweeps over the same workload-point axis arriving in one
        coalescing window splice along the design axis and score as one
        fused sweep call (a distinct axis or profile starts its own
        group); the answer is a
        :class:`~repro.core.whatif.WorkloadSweepAnswer`."""
        hw_name = self._profile_name(hw)
        specs = tuple(specs)
        points = normalize_points(workloads, mixes)
        ev = _Evaluation(specs, None, None, hw_name, session,
                         points=points)
        with self._lock:
            self._stats.sweeps += 1

        def finalize(elapsed: float) -> WorkloadSweepAnswer:
            return WorkloadSweepAnswer(
                question_sweep(points, len(specs)), specs, points,
                np.asarray(ev.totals), elapsed)
        return self._submit([ev], finalize)

    # -- synchronous conveniences -------------------------------------------
    def what_if_design(self, *args, **kwargs) -> WhatIfAnswer:
        return self.submit_design(*args, **kwargs).result()

    def what_if_hardware(self, *args, **kwargs) -> WhatIfAnswer:
        return self.submit_hardware(*args, **kwargs).result()

    def what_if_workload(self, *args, **kwargs) -> WhatIfAnswer:
        return self.submit_workload(*args, **kwargs).result()

    def complete_design(self, *args, **kwargs) -> SearchResult:
        return self.submit_complete(*args, **kwargs).result()

    def workload_sweep(self, *args, **kwargs) -> WorkloadSweepAnswer:
        return self.submit_sweep(*args, **kwargs).result()

    # -- the serving loop (worker thread) -----------------------------------
    def _submit(self, evals: List[_Evaluation],
                finalize: Callable[[float], object]) -> Future:
        thread = self._thread
        if thread is None or not thread.is_alive():
            raise RuntimeError("service is not running (call start())")
        fut: Future = Future()
        with self._lock:
            self._stats.questions += 1
        self._queue.put(_Request(evals, finalize, fut, time.perf_counter()))
        # close the submit/stop race: if the worker died between the check
        # above and the put, nothing will ever serve the queue — fail the
        # stragglers (including ours) instead of hanging their futures
        if not thread.is_alive():
            self._fail_pending()
        return fut

    def _loop(self) -> None:
        while True:
            head = self._queue.get()
            if head is None:
                return
            batch = [head]
            stop = False
            deadline = time.monotonic() + self._window
            while len(batch) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is None:
                    stop = True
                    break
                batch.append(nxt)
            try:
                self._serve_batch(batch)
            except Exception as exc:   # defensive: never kill the loop
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(exc)
            if stop:
                return

    def _pack(self, ev: _Evaluation) -> PackedFrontier:
        chains = tuple(s.chain for s in ev.specs)
        if ev.points is not None:
            key: Tuple = (chains, ev.points)
        else:
            mix_key = tuple(ev.mix.items()) if ev.mix else None
            key = (chains, ev.workload, mix_key)
        state = self._sessions.get(ev.session) if ev.session else None
        if state is not None:
            packed = state.get(key)
            if packed is not None:
                with self._lock:
                    self._stats.session_frontier_hits += 1
                return packed
        if ev.points is not None:
            packed = pack_sweep(ev.specs, [p[0] for p in ev.points],
                                [dict(p[1]) for p in ev.points])
        else:
            packed = pack_frontier(ev.specs, ev.workload, ev.mix)
        if state is not None:
            state.put(key, packed)
        return packed

    def _serve_batch(self, batch: List[_Request]) -> None:
        """Answer one coalescing window: splice every evaluation into one
        frontier per (hardware profile, sweep-point axis), score each
        group with one fused call, slice the per-design totals (or
        per-grid columns) back out, resolve the futures."""
        if not batch:
            with self._lock:
                self._stats.empty_windows += 1
            return
        groups: Dict[Tuple, List[_Evaluation]] = {}
        live: List[_Request] = []
        for req in batch:
            try:
                for ev in req.evals:
                    ev.packed = self._pack(ev)
                for ev in req.evals:
                    groups.setdefault((ev.hw_name, ev.points),
                                      []).append(ev)
                live.append(req)
            except Exception as exc:
                req.future.set_exception(exc)
                with self._lock:
                    self._stats.failed += 1
        score_calls = 0
        for (hw_name, points), evals in groups.items():
            hw = self._profiles[hw_name]
            try:
                if points is not None:   # sweeps splice along designs
                    sweep = concat_sweeps([ev.packed for ev in evals])
                    grid = sweep.score(hw, engine=self._engine)
                    score_calls += 1
                    offset = 0
                    for ev in evals:
                        n = ev.packed.n_designs
                        ev.totals = grid[:, offset:offset + n]
                        offset += n
                    continue
                combined = concat_frontiers([ev.packed for ev in evals])
                totals = combined.score(hw, engine=self._engine)
                score_calls += 1
            except Exception as exc:
                for ev in evals:   # each group keeps its own failure
                    ev.error = exc
                continue
            offset = 0
            for ev in evals:
                n = ev.packed.n_segments
                ev.totals = totals[offset:offset + n]
                offset += n
        answered = failed = 0
        for req in live:
            try:
                for ev in req.evals:
                    if ev.error is not None:
                        raise ev.error
                req.future.set_result(
                    req.finalize(time.perf_counter() - req.t0))
                answered += 1
            except Exception as exc:
                req.future.set_exception(exc)
                failed += 1
        with self._lock:
            st = self._stats
            st.batches += 1
            st.score_calls += score_calls
            st.answered += answered
            st.failed += failed
            st.max_batch = max(st.max_batch, len(batch))
            if len(batch) > 1:
                st.coalesced += len(batch)
