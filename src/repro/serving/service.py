"""Micro-batching what-if serving engine, hardened for production traffic.

The paper's headline promise is *interactive* design questions — answers
"on the order of a few seconds or minutes" — and the access pattern of a
design session (Learning Key-Value Store Design, Idreos et al.) is long
runs of many small, related questions against a shared design continuum.
Served naively, every question pays a full fused-scorer dispatch, and
concurrent designers hammer the module-level synthesis memos from many
threads.

:class:`DesignCalculatorService` is the long-lived serving loop those
sessions talk to:

* **Resident state.**  Registered :class:`~repro.core.hardware.
  HardwareProfile`s keep their device parameter banks built
  (:func:`repro.core.devicecost.device_table`), so no question ever pays
  bank construction; the packed-frontier/segment caches of
  :mod:`repro.core.batchcost` (thread-safe via
  :mod:`repro.core.memo`) persist across questions.
* **Micro-batching.**  Requests are submitted from any thread and return
  :class:`concurrent.futures.Future`s.  A single worker drains the
  lanes: the first request opens a coalescing window (``window_s``),
  everything arriving inside it joins the batch, and the batch is served
  by splicing every question's packed frontier into **one**
  :func:`~repro.core.batchcost.concat_frontiers` frontier per distinct
  hardware profile — one fused scoring call each.  A hardware-variant
  question contributes the *same* packed frontier to two profile groups:
  a pure parameter-table swap, zero recompilation.
* **Admission control and priority lanes** (PR 6).  Requests are priced
  in cells (:func:`repro.serving.admission.request_cost` — estimated
  designs x workload points) and admitted through bounded per-lane
  queues (:class:`repro.serving.lanes.LaneScheduler`): interactive
  what-ifs in one lane, bulk sweeps / large completions in the other,
  dequeued by weighted round-robin so a window never fills with bulk
  work while interactive questions wait.  A full lane sheds with
  :class:`~repro.serving.admission.RejectedError`; optional per-session
  token buckets (``budget_cells``) shed with
  :class:`~repro.serving.admission.BudgetExceeded` before a request
  holds a queue slot.  Within a batch, interactive groups score *first*
  and their futures resolve eagerly — an interactive answer never waits
  on a bulk group's scoring call.
* **Deadlines and cancellation.**  A per-request deadline
  (``deadline_s``) is checked when the batch is assembled and again
  between coalesced scoring calls; an expired request fails fast with
  :class:`~repro.serving.admission.DeadlineExceeded` instead of
  occupying a fused call.  ``Future.cancel()`` before the worker picks a
  request up drops it without scoring.
* **Warm restart.**  ``snapshot_path`` makes ``start()`` restore the
  template-statics and packed-segment memos from a versioned on-disk
  snapshot (:func:`repro.core.memo.restore_caches`;
  :meth:`DesignCalculatorService.save_snapshot` writes one), so a
  restarted service answers its first question from warm caches — and a
  corrupt or stale snapshot silently cold-starts, never crashes.
* **Per-session frontier reuse.**  A :class:`ServiceSession` pins the
  packed frontiers of its recent questions, so a designer iterating on
  one baseline never re-packs it — even if a burst of unrelated traffic
  evicts it from the global LRU caches.
* **Workload sweeps** (PR 5).  ``submit_sweep`` serves whole
  (designs x workloads) grids — read/write-ratio or skew continuums —
  through the :func:`repro.core.batchcost.pack_sweep` engine.  Sweeps in
  one window sharing a workload-point axis splice along the design axis
  (``concat_sweeps``) and score as ONE fused sweep call per hardware
  profile, exactly like flat questions coalesce via
  ``concat_frontiers``; retained sweeps pin in sessions like frontiers.

Answers are exactly :class:`~repro.core.whatif.WhatIfAnswer` /
:class:`~repro.core.whatif.WorkloadSweepAnswer` /
:class:`~repro.core.autocomplete.SearchResult`; parity with the serial
scalar oracle (to the fused engine's documented 1e-6) is asserted in
``tests/test_serving.py``, ``tests/test_sweep.py`` and
``benchmarks/serving_bench.py``; the hardened traffic behavior in
``tests/test_admission.py`` and ``benchmarks/load_bench.py``.  Semantics
are documented in ``docs/serving.md``.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import logging
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import devicecost, memo
from repro.core.autocomplete import SearchResult, enumerate_frontier
from repro.core.batchcost import (PackedFrontier, PackedSweep,
                                  concat_frontiers, concat_sweeps,
                                  normalize_points, pack_frontier,
                                  pack_sweep)
from repro.core.elements import DataStructureSpec, Element
from repro.core.hardware import HardwareProfile
from repro.core.synthesis import Workload
from repro.core.whatif import (WhatIfAnswer, WorkloadSweepAnswer,
                               question_design, question_hardware,
                               question_sweep, question_workload)
from repro.serving.admission import (BudgetExceeded, DeadlineExceeded,
                                     RejectedError, ServiceStoppedError,
                                     SessionBudgets, WorkerCrashed,
                                     request_cost)
from repro.serving.lanes import (BULK, CLOSED, INTERACTIVE, LaneScheduler)
from repro.serving.shards import NonFiniteScore, ScoringShardPool
from repro.testing import faults

_LOG = logging.getLogger("repro.serving")


@dataclasses.dataclass
class ServiceStats:
    """Serving counters (snapshot with :meth:`DesignCalculatorService.stats`)."""

    questions: int = 0          # requests submitted (admitted or not)
    answered: int = 0           # futures resolved successfully
    failed: int = 0             # futures resolved with an exception
    batches: int = 0            # non-empty coalescing windows served
    empty_windows: int = 0      # windows that closed with no requests
    coalesced: int = 0          # requests that shared a batch with others
    score_calls: int = 0        # fused/grouped scoring calls issued
    max_batch: int = 0          # largest batch served
    session_frontier_hits: int = 0
    sweeps: int = 0             # workload-sweep requests submitted
    searches: int = 0           # population-search requests submitted
    shed_interactive: int = 0   # interactive-lane overload rejections
    shed_bulk: int = 0          # bulk-lane overload rejections
    budget_rejected: int = 0    # session token-bucket rejections
    expired: int = 0            # requests failed with DeadlineExceeded
    cancelled: int = 0          # futures cancelled before serving
    stopped_requests: int = 0   # requests failed by shutdown
    snapshot_entries: int = 0   # cache entries restored on start()
    shard_dispatches: int = 0   # partitions dispatched by multi-shard groups
    # -- fault tolerance (PR 8; the shard pool's own retry/quarantine
    # counters merge into stats() from ScoringShardPool.stats()) --------
    nonfinite_groups: int = 0   # merged group totals that failed isfinite
    fallback_flat: int = 0      # groups served by the flat fused fallback
    fallback_grouped: int = 0   # groups served by the grouped oracle
    engine_degraded: int = 0    # profiles demoted off the fused engine
    engine_recovered: int = 0   # profiles recovered by a fused probe
    worker_restarts: int = 0    # supervisor resurrections of the worker
    snapshot_restored: int = 0  # warm restarts that loaded entries
    snapshot_discarded: int = 0  # snapshots discarded (corrupt/stale/error)
    snapshot_corrupt: int = 0   # the unreadable subset of discarded


@dataclasses.dataclass
class _Evaluation:
    """One frontier-under-one-profile scoring unit of a request.

    Requests decompose into evaluations; the batcher groups evaluations
    by (hardware profile, sweep points) and scores each group in one
    fused call.  After scoring, ``totals`` holds this evaluation's
    per-design slice (flat questions) or its ``[points, designs]`` grid
    columns (sweeps, where ``points`` is set and ``workload``/``mix``
    are unused).
    """

    specs: Tuple[DataStructureSpec, ...]
    workload: Optional[Workload]
    mix: Optional[Dict[str, float]]
    hw_name: str
    session: Optional[str] = None
    points: Optional[Tuple] = None      # sweep evaluations only
    packed: Optional[PackedFrontier] = None   # PackedSweep for sweeps
    totals: Optional[np.ndarray] = None
    error: Optional[Exception] = None   # this evaluation's scoring failure
    owner: Optional["_Request"] = None  # back-pointer, set at serve time
    engine: Optional[str] = None        # which engine produced totals


@dataclasses.dataclass
class _Request:
    evals: List[_Evaluation]
    finalize: Callable[[float], object]   # elapsed-seconds -> answer
    future: Future
    t0: float
    lane: str = INTERACTIVE
    deadline: Optional[float] = None      # absolute time.monotonic()
    deadline_s: Optional[float] = None    # the relative deadline requested
    cost: float = 1.0                     # admission price in cells
    remaining: int = 0                    # evals not yet scored/errored
    dead: bool = False                    # expired/cancelled mid-batch


class _SessionState:
    """Packed frontiers pinned by one session.

    Reads/writes go through an internal lock: the worker thread owns the
    steady-state traffic, but warm-restart plumbing and tests touch pins
    from other threads, and an unguarded ``OrderedDict``
    ``get``+``move_to_end`` is exactly the torn-bookkeeping pattern
    ``repro.core.memo`` exists to prevent."""

    def __init__(self, maxsize: int = 64) -> None:
        self.frontiers: "collections.OrderedDict" = collections.OrderedDict()
        self.maxsize = maxsize
        self._lock = threading.RLock()

    def get(self, key) -> Optional[PackedFrontier]:
        with self._lock:
            packed = self.frontiers.get(key)
            if packed is not None:
                self.frontiers.move_to_end(key)
            return packed

    def put(self, key, packed: PackedFrontier) -> None:
        with self._lock:
            self.frontiers[key] = packed
            if len(self.frontiers) > self.maxsize:
                self.frontiers.popitem(last=False)


@dataclasses.dataclass
class ServiceSession:
    """A designer's handle on the service: same questions, pinned frontiers."""

    service: "DesignCalculatorService"
    name: str

    def what_if_design(self, spec, variant, workload, hw, mix=None,
                       **kwargs):
        return self.service.what_if_design(spec, variant, workload, hw, mix,
                                           session=self.name, **kwargs)

    def what_if_hardware(self, spec, workload, hw, new_hw, mix=None,
                         **kwargs):
        return self.service.what_if_hardware(spec, workload, hw, new_hw, mix,
                                             session=self.name, **kwargs)

    def what_if_workload(self, spec, workload, new_workload, hw, mix=None,
                         **kwargs):
        return self.service.what_if_workload(spec, workload, new_workload,
                                             hw, mix, session=self.name,
                                             **kwargs)

    def complete_design(self, partial, workload, hw, **kwargs):
        return self.service.complete_design(partial, workload, hw,
                                            session=self.name, **kwargs)

    def workload_sweep(self, specs, workloads, hw, mixes=None, **kwargs):
        return self.service.workload_sweep(specs, workloads, hw, mixes,
                                           session=self.name, **kwargs)


class DesignCalculatorService:
    """Long-lived concurrent what-if server (see module docstring).

    Parameters
    ----------
    profiles:
        Hardware profiles to register up front (device banks are built
        immediately; more can be registered later, or implicitly by
        asking a question about an unregistered profile object).
    window_s:
        The coalescing window: how long the worker keeps a batch open
        after its first request arrives.
    max_batch:
        Hard cap on requests per batch (the window closes early).
    engine:
        ``"fused"`` (default) or ``"grouped"`` — every scoring call goes
        through :meth:`PackedFrontier.score` with this engine.
    lanes:
        ``True`` (default) runs the two-lane weighted scheduler with
        interactive-first group scoring.  ``False`` is the pre-hardening
        FIFO regime — one queue, no priority, futures resolve when the
        whole batch has scored — kept as the load-benchmark baseline.
    interactive_capacity / bulk_capacity:
        Bounded lane depths; a full lane sheds new requests with
        :class:`~repro.serving.admission.RejectedError`.
    lane_weights:
        Dequeues per lane per weighted round (default 4 interactive :
        1 bulk).
    bulk_threshold:
        Auto-completions whose enumerated frontier reaches this many
        designs ride the bulk lane (sweeps always do).
    bulk_per_window:
        When set, at most this many bulk requests join one coalescing
        window (excess bulk stays queued for later windows, and the
        window keeps accepting interactive arrivals until it closes) —
        the strict per-window occupancy bound for latency-critical
        deployments.  ``None`` (default) lets same-axis bulk work
        coalesce freely.
    budget_cells / budget_refill_per_s:
        When ``budget_cells`` is set, each session gets a token bucket
        of that capacity (refilling at ``budget_refill_per_s`` cells/s,
        default one capacity per second); requests are priced via
        :func:`repro.serving.admission.request_cost` and shed with
        :class:`~repro.serving.admission.BudgetExceeded` when the
        bucket is dry.
    default_deadline_s:
        Deadline applied to requests that do not pass their own.
    snapshot_path:
        When set, ``start()`` warm-restores the template-statics and
        packed-segment memos from this snapshot (if present and
        version-compatible) and :meth:`save_snapshot` writes it.
    scoring_shards / shard_min_cells:
        The scoring-shard pool (:class:`repro.serving.shards.
        ScoringShardPool`): each (profile, axis) group's spliced product
        partitions across up to ``scoring_shards`` local devices
        (default: all of them) once it spans ``shard_min_cells`` cells
        per partition, dispatches concurrently with deadlines probed
        between shard dispatches, and merges bit-identically before any
        future resolves.  On a single-device host the pool degenerates
        to the pre-shard in-thread call.
    """

    def __init__(self, profiles: Sequence[HardwareProfile] = (), *,
                 window_s: float = 0.002, max_batch: int = 1024,
                 engine: str = "fused", start: bool = True,
                 lanes: bool = True,
                 interactive_capacity: int = 4096,
                 bulk_capacity: int = 256,
                 lane_weights: Optional[Dict[str, int]] = None,
                 bulk_threshold: int = 64,
                 bulk_per_window: Optional[int] = None,
                 budget_cells: Optional[float] = None,
                 budget_refill_per_s: Optional[float] = None,
                 default_deadline_s: Optional[float] = None,
                 snapshot_path: Optional[str] = None,
                 scoring_shards: Optional[int] = None,
                 shard_min_cells: Optional[int] = None,
                 shard_part_timeout_s: Optional[float] = None,
                 shard_retries: Optional[int] = None,
                 shard_quarantine_after: Optional[int] = None,
                 shard_quarantine_s: Optional[float] = None,
                 fused_failure_threshold: int = 2,
                 engine_probe_s: float = 2.0,
                 max_worker_restarts: int = 8,
                 worker_backoff_s: float = 0.02) -> None:
        if engine not in ("fused", "grouped"):
            raise ValueError(f"unknown serving engine: {engine!r}")
        self._engine = engine
        self._window = window_s
        self._max_batch = max_batch
        self._lanes_enabled = lanes
        self._bulk_threshold = bulk_threshold
        self._bulk_per_window = bulk_per_window if lanes else None
        self._default_deadline = default_deadline_s
        self._snapshot_path = snapshot_path
        self._restored = False
        if lanes:
            self._sched = LaneScheduler(
                capacities={INTERACTIVE: interactive_capacity,
                            BULK: bulk_capacity},
                weights=lane_weights or {INTERACTIVE: 4, BULK: 1})
        else:   # FIFO baseline: one lane sized like the two combined
            self._sched = LaneScheduler(
                capacities={INTERACTIVE: interactive_capacity
                            + bulk_capacity},
                weights={INTERACTIVE: 1}, lanes=(INTERACTIVE,))
        self._budgets = (SessionBudgets(budget_cells, budget_refill_per_s)
                         if budget_cells is not None else None)
        pool_kwargs = {}
        for name, value in (("min_cells_per_shard", shard_min_cells),
                            ("part_timeout_s", shard_part_timeout_s),
                            ("retries", shard_retries),
                            ("quarantine_after", shard_quarantine_after),
                            ("quarantine_s", shard_quarantine_s)):
            if value is not None:
                pool_kwargs[name] = value
        self._shards = ScoringShardPool(scoring_shards, **pool_kwargs)
        self._fused_failure_threshold = max(int(fused_failure_threshold), 1)
        self._engine_probe_s = float(engine_probe_s)
        self._max_worker_restarts = max(int(max_worker_restarts), 0)
        self._worker_backoff_s = float(worker_backoff_s)
        #: per-profile fused-engine health (guarded by self._lock):
        #: name -> {"degraded": bool, "fails": int, "next_probe": float}
        self._engine_health: Dict[str, Dict] = {}
        self._snapshot_outcome = "disabled" if not snapshot_path \
            else "pending"
        self._inflight: List[_Request] = []
        self._profiles: Dict[str, HardwareProfile] = {}
        self._sessions: Dict[str, _SessionState] = {}
        self._session_counter = itertools.count()
        self._lock = threading.Lock()      # profiles/sessions/stats registry
        self._stats = ServiceStats()
        self._thread: Optional[threading.Thread] = None
        for hw in profiles:
            self.register_hardware(hw)
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        if self._snapshot_path and not self._restored:
            # warm restart: restore the statics/segment memos — never
            # raises, but the outcome (restored / missing / corrupt /
            # stale / error) is recorded, not swallowed
            report = memo.restore_caches_report(self._snapshot_path)
            self._restored = True
            self._snapshot_outcome = report.outcome
            with self._lock:
                self._stats.snapshot_entries = report.entries
                if report.outcome == "restored":
                    self._stats.snapshot_restored += 1
                elif report.outcome in ("corrupt", "stale", "error"):
                    self._stats.snapshot_discarded += 1
                    if report.outcome == "corrupt":
                        self._stats.snapshot_corrupt += 1
            if report.outcome in ("corrupt", "stale", "error"):
                _LOG.warning(
                    "discarded %s warm-restart snapshot at %s; "
                    "cold-starting", report.outcome, self._snapshot_path)
        self._sched.reopen()
        self._thread = threading.Thread(target=self._supervise, daemon=True,
                                        name="design-calculator-serving")
        self._thread.start()

    def stop(self, timeout: Optional[float] = None) -> None:
        """Drain already-queued requests, then stop the worker.

        Admission closes immediately: a submit that races shutdown fails
        with :class:`~repro.serving.admission.ServiceStoppedError`
        (carrying its would-be queue position) — distinguishable from an
        overload shed.  If ``timeout`` expires with the worker still
        running, the service stays stoppable/startable — the thread is
        only forgotten once dead."""
        if self._thread is None:
            return
        self._sched.close()
        self._thread.join(timeout)
        if self._thread.is_alive():    # timed out; try again later
            return
        self._thread = None
        self._fail_pending()

    def _fail_pending(self) -> None:
        """Fail every request still queued after the worker has exited."""
        failed = 0
        for req, lane, pos in self._sched.drain():
            if req.future.done():
                continue
            req.future.set_exception(ServiceStoppedError(
                f"service stopped before serving this request "
                f"(position {pos} in the {lane} lane)",
                queue_position=pos))
            failed += 1
        if failed:
            with self._lock:
                self._stats.failed += failed
                self._stats.stopped_requests += failed

    close = stop

    def __enter__(self) -> "DesignCalculatorService":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def save_snapshot(self, path: Optional[str] = None) -> int:
        """Persist the warm-restart snapshot (template statics + packed
        segments + the model-id interning table) atomically; returns the
        number of entries written."""
        path = path or self._snapshot_path
        if not path:
            raise ValueError("no snapshot path configured")
        return memo.snapshot_caches(path)

    # -- registry -----------------------------------------------------------
    def register_hardware(self, hw: HardwareProfile) -> str:
        """Register a profile and build its device parameter banks now, so
        the first question about it pays no bank construction."""
        with self._lock:
            self._profiles[hw.name] = hw
        devicecost.device_table(hw)
        return hw.name

    def _profile_name(self, hw) -> str:
        if isinstance(hw, str):
            if hw not in self._profiles:
                raise KeyError(f"unregistered hardware profile: {hw!r}")
            return hw
        if self._profiles.get(hw.name) is not hw:
            self.register_hardware(hw)
        return hw.name

    def session(self, name: Optional[str] = None) -> ServiceSession:
        """Open (or re-attach to) a designer session with pinned frontiers."""
        name = name or f"session-{next(self._session_counter)}"
        with self._lock:
            self._sessions.setdefault(name, _SessionState())
        return ServiceSession(self, name)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            out = dict(dataclasses.asdict(self._stats))
        out.update(self._shards.stats())
        for lane in self._sched.lanes:
            out[f"queued_{lane}"] = self._sched.depth(lane)
        return out

    def health(self) -> Dict:
        """One structured snapshot of the service's fault-tolerance
        state: worker liveness/restarts, per-profile engine health
        (degraded profiles serve from the grouped oracle until a fused
        probe succeeds), per-device breaker state, queue depths and the
        warm-restart snapshot outcome."""
        thread = self._thread
        now = time.monotonic()
        with self._lock:
            engines = {
                name: {"engine": "grouped" if st["degraded"]
                       else self._engine,
                       "degraded": st["degraded"],
                       "consecutive_failures": st["fails"],
                       "next_probe_in_s": max(st["next_probe"] - now, 0.0)
                       if st["degraded"] else 0.0}
                for name, st in self._engine_health.items()}
            restarts = self._stats.worker_restarts
            snapshot = {"outcome": self._snapshot_outcome,
                        "entries": self._stats.snapshot_entries}
        return {
            "worker_alive": bool(thread is not None and thread.is_alive()),
            "worker_restarts": restarts,
            "engines": engines,
            "devices": self._shards.device_health(),
            "queued": {lane: self._sched.depth(lane)
                       for lane in self._sched.lanes},
            "snapshot": snapshot,
        }

    # -- per-profile fused-engine health (the degraded-mode gate) -----------
    def _engine_state(self, name: str) -> Dict:
        # lint: unlocked(every caller already holds self._lock)
        return self._engine_health.setdefault(
            name, {"degraded": False, "fails": 0, "next_probe": 0.0})

    def _fused_allowed(self, name: str, now: float) -> Tuple[bool, bool]:
        """``(attempt fused?, is this attempt a recovery probe?)``."""
        with self._lock:
            st = self._engine_state(name)
            if not st["degraded"]:
                return True, False
            if now >= st["next_probe"]:
                # claim the probe slot so concurrent windows don't herd
                st["next_probe"] = now + self._engine_probe_s
                return True, True
            return False, False

    def _note_fused_ok(self, name: str) -> None:
        with self._lock:
            st = self._engine_state(name)
            if st["degraded"]:
                st["degraded"] = False
                self._stats.engine_recovered += 1
            st["fails"] = 0

    def _note_fused_failure(self, name: str) -> None:
        now = time.monotonic()
        with self._lock:
            st = self._engine_state(name)
            st["fails"] += 1
            if st["fails"] >= self._fused_failure_threshold \
                    and not st["degraded"]:
                st["degraded"] = True
                st["next_probe"] = now + self._engine_probe_s
                self._stats.engine_degraded += 1
                _LOG.warning(
                    "profile %r demoted to the grouped oracle after %d "
                    "consecutive fused failures (probing back every "
                    "%.1fs)", name, st["fails"], self._engine_probe_s)

    # -- submission (any thread) --------------------------------------------
    def submit_design(self, spec: DataStructureSpec,
                      variant: DataStructureSpec, workload: Workload, hw,
                      mix: Optional[Dict[str, float]] = None,
                      session: Optional[str] = None,
                      deadline_s: Optional[float] = None,
                      lane: Optional[str] = None) -> Future:
        hw_name = self._profile_name(hw)
        ev = _Evaluation((spec, variant), workload, mix, hw_name, session)

        def finalize(elapsed: float) -> WhatIfAnswer:
            return WhatIfAnswer(question_design(spec, variant),
                                float(ev.totals[0]), float(ev.totals[1]),
                                elapsed)
        return self._submit([ev], finalize, session=session,
                            cost=request_cost(2), deadline_s=deadline_s,
                            lane=lane or INTERACTIVE)

    def submit_hardware(self, spec: DataStructureSpec, workload: Workload,
                        hw, new_hw,
                        mix: Optional[Dict[str, float]] = None,
                        session: Optional[str] = None,
                        deadline_s: Optional[float] = None,
                        lane: Optional[str] = None) -> Future:
        base_hw = self._profiles[self._profile_name(hw)]
        var_hw = self._profiles[self._profile_name(new_hw)]
        # identical (specs, workload, mix): both evaluations resolve to the
        # SAME packed frontier, scored under two profile groups — the
        # what-if-hardware table swap, now amortized across a whole batch
        base = _Evaluation((spec,), workload, mix, base_hw.name, session)
        var = _Evaluation((spec,), workload, mix, var_hw.name, session)

        def finalize(elapsed: float) -> WhatIfAnswer:
            return WhatIfAnswer(question_hardware(base_hw, var_hw),
                                float(base.totals[0]), float(var.totals[0]),
                                elapsed)
        return self._submit([base, var], finalize, session=session,
                            cost=request_cost(2), deadline_s=deadline_s,
                            lane=lane or INTERACTIVE)

    def submit_workload(self, spec: DataStructureSpec, workload: Workload,
                        new_workload: Workload, hw,
                        mix: Optional[Dict[str, float]] = None,
                        session: Optional[str] = None,
                        deadline_s: Optional[float] = None,
                        lane: Optional[str] = None) -> Future:
        hw_name = self._profile_name(hw)
        base = _Evaluation((spec,), workload, mix, hw_name, session)
        var = _Evaluation((spec,), new_workload, mix, hw_name, session)

        def finalize(elapsed: float) -> WhatIfAnswer:
            return WhatIfAnswer(question_workload(workload, new_workload),
                                float(base.totals[0]), float(var.totals[0]),
                                elapsed)
        return self._submit([base, var], finalize, session=session,
                            cost=request_cost(2), deadline_s=deadline_s,
                            lane=lane or INTERACTIVE)

    def submit_complete(self, partial: Sequence[Element],
                        workload: Workload, hw,
                        candidates: Optional[Sequence[Element]] = None,
                        terminals: Optional[Sequence[Element]] = None,
                        mix: Optional[Dict[str, float]] = None,
                        max_depth: int = 3, name: str = "auto",
                        session: Optional[str] = None,
                        deadline_s: Optional[float] = None,
                        lane: Optional[str] = None) -> Future:
        hw_name = self._profile_name(hw)
        # enumeration is structural and memoized — do it at submit time so
        # the whole window's frontiers are known when the batch closes
        frontier = enumerate_frontier(partial, candidates, terminals,
                                      max_depth, name)
        if not frontier:
            with self._lock:   # counted like any other failed question
                self._stats.questions += 1
                self._stats.failed += 1
            fut: Future = Future()
            fut.set_exception(RuntimeError("no valid completion found"))
            return fut
        ev = _Evaluation(frontier, workload, mix, hw_name, session)

        def finalize(elapsed: float) -> SearchResult:
            best = int(np.argmin(ev.totals))
            return SearchResult(frontier[best], float(ev.totals[best]),
                                len(frontier), elapsed)
        if lane is None:   # big completions ride the bulk lane
            lane = BULK if len(frontier) >= self._bulk_threshold \
                else INTERACTIVE
        return self._submit([ev], finalize, session=session,
                            cost=request_cost(len(frontier)),
                            deadline_s=deadline_s, lane=lane)

    def submit_sweep(self, specs: Sequence[DataStructureSpec],
                     workloads: Sequence[Workload], hw,
                     mixes=None,
                     session: Optional[str] = None,
                     deadline_s: Optional[float] = None,
                     lane: Optional[str] = None) -> Future:
        """A (designs x workloads) grid as one request.

        Sweeps over the same workload-point axis arriving in one
        coalescing window splice along the design axis and score as one
        fused sweep call (a distinct axis or profile starts its own
        group); the answer is a
        :class:`~repro.core.whatif.WorkloadSweepAnswer`.  Sweeps ride
        the bulk lane and pay their whole (designs x points) grid in
        admission cells."""
        hw_name = self._profile_name(hw)
        specs = tuple(specs)
        points = normalize_points(workloads, mixes)
        ev = _Evaluation(specs, None, None, hw_name, session,
                         points=points)
        with self._lock:
            self._stats.sweeps += 1

        def finalize(elapsed: float) -> WorkloadSweepAnswer:
            return WorkloadSweepAnswer(
                question_sweep(points, len(specs)), specs, points,
                np.asarray(ev.totals), elapsed)
        return self._submit([ev], finalize, session=session,
                            cost=request_cost(len(specs), len(points)),
                            deadline_s=deadline_s, lane=lane or BULK)

    def submit_search(self, workload: Workload, hw,
                      mix: Optional[Dict[str, float]] = None, *,
                      budget_designs: int = 256,
                      workloads: Optional[Sequence[Workload]] = None,
                      mixes=None,
                      session: Optional[str] = None,
                      deadline_s: Optional[float] = None,
                      lane: Optional[str] = None,
                      **search_kwargs) -> Future:
        """Population-based design search as a served request.

        Runs :func:`repro.core.search.population_search` with every
        generation's scoring routed through :meth:`submit_sweep` on the
        bulk lane — so population search rides the same admission
        control, priority lanes, per-request deadlines and
        degraded-engine fault-healing chain as any other sweep traffic
        (an interactive what-if never waits behind a generation's fused
        call, and a NaN-poisoned bank heals mid-search without the
        search noticing anything but the answer's engine tag).

        Admission is priced up front for the *whole* designs-costed
        budget (``request_cost(budget_designs, points)``); the inner
        per-generation sweeps then ride free of session budgets, so a
        search is charged exactly once.  ``deadline_s`` bounds the whole
        search: each generation's sweep gets the remaining slice and the
        loop itself stops with :class:`DeadlineExceeded` once spent.
        The future resolves to the ``population_search`` result dict —
        discrete winner, oracle-verified, budget accounting included.
        ``search_kwargs`` pass through (``population``, ``generations``,
        ``seed``, ``templates``, ...).
        """
        from repro.core.search import SearchBudget, population_search
        thread = self._thread
        if thread is None or not thread.is_alive():
            raise RuntimeError("service is not running (call start())")
        hw_name = self._profile_name(hw)
        hw_profile = self._profiles[hw_name]
        wls = list(workloads) if workloads is not None else [workload]
        points = normalize_points(wls, mixes if mixes is not None else mix)
        with self._lock:
            self._stats.questions += 1
            self._stats.searches += 1
        if self._budgets is not None:
            try:
                self._budgets.admit(
                    session, request_cost(budget_designs, len(points)))
            except BudgetExceeded:
                with self._lock:
                    self._stats.budget_rejected += 1
                raise
        deadline_s = deadline_s if deadline_s is not None \
            else self._default_deadline
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        sweep_lane = lane or BULK
        fut: Future = Future()

        def score_fn(specs) -> np.ndarray:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0.0:
                    raise DeadlineExceeded(
                        "search deadline spent before the next "
                        "generation could score",
                        deadline_s=deadline_s, late_by_s=-remaining)
            # session=None: the search already paid its whole budget at
            # admission — generation sweeps must not double-charge it
            inner = self.submit_sweep(
                [s for s in specs], [w for w, _ in points], hw_profile,
                [dict(mi) for _, mi in points], session=None,
                deadline_s=remaining, lane=sweep_lane)
            # lint: untimed-wait(request deadline + supervisor bound the wait)
            answer = inner.result()
            return np.asarray(answer.totals, np.float64).mean(axis=0)

        def drive() -> None:
            if not fut.set_running_or_notify_cancel():
                return
            t0 = time.perf_counter()
            try:
                result = population_search(
                    workload, hw_profile, mix,
                    budget=SearchBudget(budget_designs),
                    workloads=wls,
                    mixes=mixes if mixes is not None else mix,
                    score_fn=score_fn, **search_kwargs)
            except Exception as exc:    # noqa: BLE001 — future carries it
                with self._lock:
                    self._stats.failed += 1
                fut.set_exception(exc)
                return
            with self._lock:
                self._stats.answered += 1
            result["elapsed_s"] = time.perf_counter() - t0
            fut.set_result(result)

        threading.Thread(target=drive, daemon=True,
                         name=f"repro-search-{id(fut):x}").start()
        return fut

    # -- synchronous conveniences -------------------------------------------
    # These deliberately block without a deadline: the request-level
    # deadline (deadline_s) plus the worker supervisor guarantee the
    # future resolves or fails, and stop() drains the queue.
    def what_if_design(self, *args, **kwargs) -> WhatIfAnswer:
        # lint: untimed-wait(request deadline + supervisor bound the wait)
        return self.submit_design(*args, **kwargs).result()

    def what_if_hardware(self, *args, **kwargs) -> WhatIfAnswer:
        # lint: untimed-wait(request deadline + supervisor bound the wait)
        return self.submit_hardware(*args, **kwargs).result()

    def what_if_workload(self, *args, **kwargs) -> WhatIfAnswer:
        # lint: untimed-wait(request deadline + supervisor bound the wait)
        return self.submit_workload(*args, **kwargs).result()

    def complete_design(self, *args, **kwargs) -> SearchResult:
        # lint: untimed-wait(request deadline + supervisor bound the wait)
        return self.submit_complete(*args, **kwargs).result()

    def workload_sweep(self, *args, **kwargs) -> WorkloadSweepAnswer:
        # lint: untimed-wait(request deadline + supervisor bound the wait)
        return self.submit_sweep(*args, **kwargs).result()

    def design_search(self, *args, **kwargs) -> Dict:
        # lint: untimed-wait(request deadline + supervisor bound the wait)
        return self.submit_search(*args, **kwargs).result()

    # -- the serving loop (worker thread) -----------------------------------
    def _submit(self, evals: List[_Evaluation],
                finalize: Callable[[float], object], *,
                lane: str = INTERACTIVE, cost: float = 1.0,
                session: Optional[str] = None,
                deadline_s: Optional[float] = None) -> Future:
        thread = self._thread
        if thread is None or not thread.is_alive():
            raise RuntimeError("service is not running (call start())")
        if not self._lanes_enabled:
            lane = INTERACTIVE          # FIFO baseline: one lane
        with self._lock:
            self._stats.questions += 1
        if self._budgets is not None:
            try:
                self._budgets.admit(session, cost)
            except BudgetExceeded:
                with self._lock:
                    self._stats.budget_rejected += 1
                raise
        deadline_s = deadline_s if deadline_s is not None \
            else self._default_deadline
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        fut: Future = Future()
        req = _Request(evals, finalize, fut, time.perf_counter(),
                       lane=lane, deadline=deadline, deadline_s=deadline_s,
                       cost=cost)
        try:
            self._sched.put(req, lane)
        except RejectedError:
            with self._lock:
                if lane == BULK:
                    self._stats.shed_bulk += 1
                else:
                    self._stats.shed_interactive += 1
            raise
        except ServiceStoppedError:
            with self._lock:
                self._stats.stopped_requests += 1
            raise
        # close the submit/stop race: if the worker died between the check
        # above and the put, nothing will ever serve the queue — fail the
        # stragglers (including ours) instead of hanging their futures
        if not thread.is_alive():
            self._fail_pending()
        return fut

    def _supervise(self) -> None:
        """Worker supervision: run the coalescing loop, resurrect it.

        A crash in the loop (a bug, a poisoned batch, an injected
        ``service.worker`` fault) used to be swallowed per-batch; now it
        propagates here, the in-flight window's futures fail with the
        typed :class:`~repro.serving.admission.WorkerCrashed`, and the
        loop restarts with exponential backoff — up to
        ``max_worker_restarts`` times, after which the service closes
        admission and fails everything still queued rather than
        restart-looping forever."""
        while True:
            try:
                self._loop()
                return                      # orderly CLOSED shutdown
            except BaseException as exc:    # noqa: BLE001 — supervisor
                with self._lock:
                    self._stats.worker_restarts += 1
                    restarts = self._stats.worker_restarts
                self._crash_inflight(exc, restarts)
                if restarts > self._max_worker_restarts:
                    _LOG.error(
                        "serving worker crashed %d times (limit %d); "
                        "giving up: %r", restarts,
                        self._max_worker_restarts, exc)
                    self._sched.close()
                    self._fail_pending()
                    return
                _LOG.warning(
                    "serving worker crashed (%r); restart %d/%d",
                    exc, restarts, self._max_worker_restarts)
                time.sleep(min(self._worker_backoff_s * 2 ** (restarts - 1),
                               1.0))

    def _crash_inflight(self, exc: BaseException, restarts: int) -> None:
        """Fail the crashed window's in-flight futures with WorkerCrashed."""
        inflight, self._inflight = self._inflight, []
        failed = 0
        for req in inflight:
            if req.future.done():
                continue
            req.future.set_exception(WorkerCrashed(
                f"serving worker crashed mid-window ({exc!r}); the "
                f"request was not served and will not be replayed — "
                f"resubmit if still wanted", cause=exc, restarts=restarts))
            failed += 1
        if failed:
            with self._lock:
                self._stats.failed += failed

    def _loop(self) -> None:
        while True:
            head = self._sched.get()
            if head is CLOSED:
                return
            if head is None:       # defensive: untimed get never times out
                continue
            batch = [head]
            bulk_taken = 1 if head.lane == BULK else 0
            closing = False
            deadline = time.monotonic() + self._window
            while len(batch) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                allowed = None
                if self._bulk_per_window is not None \
                        and bulk_taken >= self._bulk_per_window:
                    # this window's bulk share is spent: keep accepting
                    # interactive arrivals only; queued bulk waits for
                    # the next window
                    allowed = (INTERACTIVE,)
                nxt = self._sched.get(timeout=remaining, lanes=allowed)
                if nxt is None:
                    break
                if nxt is CLOSED:
                    closing = True
                    break
                if nxt.lane == BULK:
                    bulk_taken += 1
                batch.append(nxt)
            # in-flight tracking for the supervisor: a crash anywhere in
            # _serve_batch fails exactly this window's unresolved futures
            # with WorkerCrashed instead of hanging them (the old blanket
            # per-batch except hid crashes from restart accounting)
            self._inflight = batch
            self._serve_batch(batch)
            self._inflight = []
            if closing:
                return

    def _pack(self, ev: _Evaluation) -> PackedFrontier:
        chains = tuple(s.chain for s in ev.specs)
        if ev.points is not None:
            key: Tuple = (chains, ev.points)
        else:
            mix_key = tuple(ev.mix.items()) if ev.mix else None
            key = (chains, ev.workload, mix_key)
        with self._lock:
            state = self._sessions.get(ev.session) if ev.session else None
        if state is not None:
            packed = state.get(key)
            if packed is not None:
                with self._lock:
                    self._stats.session_frontier_hits += 1
                return packed
        if ev.points is not None:
            packed = pack_sweep(ev.specs, [p[0] for p in ev.points],
                                [dict(p[1]) for p in ev.points])
        else:
            packed = pack_frontier(ev.specs, ev.workload, ev.mix)
        if state is not None:
            state.put(key, packed)
        return packed

    def _expire(self, req: _Request, now: float) -> None:
        """Fail a request whose deadline passed before it finished."""
        req.dead = True
        late = now - req.deadline
        req.future.set_exception(DeadlineExceeded(
            f"deadline of {req.deadline_s:.3f}s exceeded before serving "
            f"({late * 1e3:.1f} ms late)",
            deadline_s=req.deadline_s or 0.0, late_by_s=late))
        with self._lock:
            self._stats.expired += 1

    def _finalize(self, req: _Request) -> bool:
        """Resolve one fully-scored request; True on success."""
        try:
            for ev in req.evals:
                if ev.error is not None:
                    raise ev.error
            answer = req.finalize(time.perf_counter() - req.t0)
            # tag the answer with the engine(s) that actually produced it
            # (fused / fused-flat / grouped), so clients and the chaos
            # bench can see when a degraded path served them
            engines = sorted({ev.engine for ev in req.evals if ev.engine})
            if engines and hasattr(answer, "engine"):
                answer.engine = engines[0] if len(engines) == 1 \
                    else ",".join(engines)
            req.future.set_result(answer)
            return True
        except Exception as exc:
            req.future.set_exception(exc)
            return False

    def _score_group(self, evals: List[_Evaluation], hw: HardwareProfile,
                     points, probe: Callable[[int], bool],
                     deadline: Optional[float]
                     ) -> Optional[Tuple[int, int]]:
        """Score one (profile, axis) group through the degraded-engine
        fallback chain: fused-sharded -> fused-flat -> grouped oracle.

        Fused results are validated with a cheap ``isfinite`` reduction
        (NaN-poisoned parameter banks produce *finite-looking shapes*
        with garbage values — the one failure a shape check misses).  A
        fused failure falls back to the flat fused call (same banks, no
        shard pool — isolating device trouble from bank corruption);
        when that also fails but the grouped oracle answers, the profile
        is demoted to the oracle until a timed fused probe — which first
        drops the possibly-poisoned device banks
        (:func:`repro.core.devicecost.invalidate_table`) — succeeds.
        When the oracle *also* rejects the request, that is a request
        problem, not an engine problem: the oracle's exception surfaces
        and the profile is not demoted.

        Returns ``(score_calls, shard_dispatches)`` — ``(0, 0)`` when
        the group failed with every evaluation's ``error`` set — or
        ``None`` when every owner expired before a scoring call ran.
        """
        if points is not None:
            product = concat_sweeps([ev.packed for ev in evals])
            pool_call = self._shards.score_sweep
        else:
            product = concat_frontiers([ev.packed for ev in evals])
            pool_call = self._shards.score_frontier

        def finish(result, engine: str, used: int = 1) -> Tuple[int, int]:
            offset = 0
            for ev in evals:
                if points is not None:
                    n = ev.packed.n_designs
                    ev.totals = result[:, offset:offset + n]
                else:
                    n = ev.packed.n_segments
                    ev.totals = result[offset:offset + n]
                ev.engine = engine
                offset += n
            return 1, used if used > 1 else 0

        if self._engine != "fused":     # grouped-engine service: no chain
            try:
                result, used = pool_call(product, hw, engine=self._engine,
                                         before_dispatch=probe,
                                         deadline=deadline)
            except Exception as exc:
                for ev in evals:
                    ev.error = exc
                return 0, 0
            if result is None:
                return None
            return finish(result, self._engine, used)

        attempt, probing = self._fused_allowed(hw.name, time.monotonic())
        fused_failures = 0
        first_error: Optional[Exception] = None
        if attempt:
            if probing:
                # recovery probe: drop the (possibly NaN-poisoned) banks
                # so the probe scores from freshly built device tables
                devicecost.invalidate_table(hw)
            try:
                result, used = pool_call(product, hw, engine="fused",
                                         before_dispatch=probe,
                                         deadline=deadline)
                if result is None:
                    return None
                if not np.isfinite(result).all():
                    raise NonFiniteScore(
                        f"merged fused totals for {hw.name!r} contain "
                        f"non-finite values")
                self._note_fused_ok(hw.name)
                return finish(result, "fused", used)
            except Exception as exc:    # noqa: BLE001 — chain continues
                fused_failures += 1
                first_error = exc
                if isinstance(exc, NonFiniteScore):
                    with self._lock:
                        self._stats.nonfinite_groups += 1
                _LOG.warning("fused sharded scoring failed for %r (%r); "
                             "retrying flat", hw.name, exc)
            if not probe(0):
                return None
            try:
                flat = product.score(hw, engine="fused", shard=False)
                if not np.isfinite(np.asarray(flat)).all():
                    raise NonFiniteScore(
                        f"flat fused totals for {hw.name!r} contain "
                        f"non-finite values")
                # flat success means the banks are fine: the sharded
                # failure was device/shard trouble (the pool's breaker
                # handles that) — engine health resets, no demotion
                self._note_fused_ok(hw.name)
                with self._lock:
                    self._stats.fallback_flat += 1
                return finish(flat, "fused-flat")
            except Exception as exc:    # noqa: BLE001 — chain continues
                fused_failures += 1
                if isinstance(exc, NonFiniteScore):
                    with self._lock:
                        self._stats.nonfinite_groups += 1
                _LOG.warning("flat fused scoring failed for %r (%r); "
                             "falling back to the grouped oracle",
                             hw.name, exc)
        # grouped oracle: the last resort, and the whole path while the
        # profile is degraded
        if not probe(0):
            return None
        try:
            result = product.score(hw, engine="grouped")
        except Exception as exc:
            # the oracle rejected the request too: a request problem, not
            # an engine problem — surface the (more diagnostic) original
            # fused error when there was one, and don't demote the profile
            for ev in evals:    # each group keeps its own failure
                ev.error = first_error if first_error is not None else exc
            return 0, 0
        for _ in range(fused_failures):     # oracle fine, fused broken
            self._note_fused_failure(hw.name)
        with self._lock:
            self._stats.fallback_grouped += 1
        return finish(result, "grouped")

    def _serve_batch(self, batch: List[_Request]) -> None:
        """Answer one coalescing window: splice every evaluation into one
        frontier per (hardware profile, sweep-point axis), score each
        group with one fused call, slice the per-design totals (or
        per-grid columns) back out, resolve the futures.

        With lanes enabled, groups containing interactive requests score
        first and every request's future resolves as soon as its last
        evaluation is scored — an interactive answer never waits on a
        bulk group's fused call.  Deadlines are checked here (the
        dequeue point) and again before every scoring call."""
        if not batch:
            with self._lock:
                self._stats.empty_windows += 1
            return
        # fault seam: a rule on "service.worker" crashes the loop here,
        # exercising the supervisor's restart + WorkerCrashed path
        faults.check("service.worker", len(batch))
        groups: Dict[Tuple, List[_Evaluation]] = {}
        live: List[_Request] = []
        now = time.monotonic()
        cancelled = failed = 0
        for req in batch:
            # Future-based cancel: a request cancelled before the worker
            # picked it up is dropped without packing or scoring
            if not req.future.set_running_or_notify_cancel():
                cancelled += 1
                continue
            if req.deadline is not None and now > req.deadline:
                self._expire(req, now)
                continue
            try:
                for ev in req.evals:
                    ev.owner = req
                    ev.packed = self._pack(ev)
            except Exception as exc:
                req.future.set_exception(exc)
                failed += 1
                continue
            req.remaining = len(req.evals)
            for ev in req.evals:
                groups.setdefault((ev.hw_name, ev.points), []).append(ev)
            live.append(req)

        def _rank(item) -> Tuple[int, int]:
            (_, points), evals = item
            interactive = any(ev.owner.lane == INTERACTIVE for ev in evals)
            return (0 if interactive else 1, 0 if points is None else 1)

        ordered = sorted(groups.items(), key=_rank) \
            if self._lanes_enabled else list(groups.items())
        score_calls = answered = shard_dispatches = 0
        for (hw_name, points), evals in ordered:
            # deadline re-check between coalesced scoring calls: expired
            # requests fail fast instead of occupying this fused call
            now = time.monotonic()
            for ev in evals:
                req = ev.owner
                if not req.dead and req.deadline is not None \
                        and now > req.deadline:
                    self._expire(req, now)
            evals = [ev for ev in evals if not ev.owner.dead]
            if not evals:
                continue
            hw = self._profiles[hw_name]

            def _probe(shard_idx: int, _evals=evals) -> bool:
                """Deadline check between shard dispatches (PR 6's
                between-scoring-calls contract, extended inside one
                sharded call); False once no owner is left alive."""
                now = time.monotonic()
                alive = False
                for ev in _evals:
                    req = ev.owner
                    if not req.dead and req.deadline is not None \
                            and now > req.deadline:
                        self._expire(req, now)
                    alive = alive or not req.dead
                return alive

            # the window's part-wait bound: the furthest-out owner
            # deadline — unless some owner is deadline-free, in which
            # case only the pool's own part_timeout_s bounds the wait
            deadline = None
            if all(ev.owner.deadline is not None for ev in evals):
                deadline = max(ev.owner.deadline for ev in evals)
            outcome = self._score_group(evals, hw, points, _probe,
                                        deadline)
            if outcome is None:   # every owner expired mid-dispatch
                continue
            calls, used = outcome
            score_calls += calls
            shard_dispatches += used
            for ev in evals:
                req = ev.owner
                if req.dead:   # expired by a mid-dispatch probe
                    continue
                req.remaining -= 1
                if req.remaining == 0 and self._lanes_enabled:
                    # eager resolution: the future resolves the moment
                    # its last group scored, ahead of later bulk groups
                    if self._finalize(req):
                        answered += 1
                    else:
                        failed += 1
        for req in live:   # FIFO mode, plus any defensive leftovers
            if req.dead or req.future.done():
                continue
            if self._finalize(req):
                answered += 1
            else:
                failed += 1
        with self._lock:
            st = self._stats
            st.batches += 1
            st.score_calls += score_calls
            st.shard_dispatches += shard_dispatches
            st.answered += answered
            st.failed += failed
            st.cancelled += cancelled
            st.max_batch = max(st.max_batch, len(batch))
            if len(batch) > 1:
                st.coalesced += len(batch)
