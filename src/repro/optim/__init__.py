from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               apply_updates, clip_by_global_norm,
                               cosine_schedule)
