"""AdamW + global-norm clipping + warmup-cosine schedule, in pure JAX.

Moments are kept in float32 regardless of param dtype (bf16 params at
scale); the update is computed in float32 and cast back — the standard
mixed-precision recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


def adamw_init(params: Params, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype=bfloat16`` halves optimizer-state HBM (low-precision
    moments; the update math itself stays float32)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def cosine_schedule(step: jax.Array, run: RunConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(run.warmup_steps, 1), 1.0)
    progress = jnp.clip((step - run.warmup_steps) /
                        max(run.total_steps - run.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return run.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 run: RunConfig) -> Tuple[Params, AdamWState]:
    step = state.step + 1
    lr = cosine_schedule(step, run)
    b1, b2 = run.b1, run.b2

    mu = jax.tree.map(
        lambda g, m: (b1 * m.astype(jnp.float32) +
                      (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
        grads, state.mu)
    nu = jax.tree.map(
        lambda g, v: (b2 * v.astype(jnp.float32) + (1 - b2) *
                      jnp.square(g.astype(jnp.float32))).astype(v.dtype),
        grads, state.nu)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def update(p, m, v):
        m = m.astype(jnp.float32)
        v = v.astype(jnp.float32)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + 1e-8)
        u = u + run.weight_decay * p.astype(jnp.float32)
        return (-lr * u).astype(p.dtype)

    updates = jax.tree.map(update, params, mu, nu)
    return updates, AdamWState(step, mu, nu)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
