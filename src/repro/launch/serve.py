"""Serving driver: batched request loop (prefill + decode) with KV/state
caches and simple continuous-batching bookkeeping.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 8 --prompt-len 16 --max-new 16

One jitted decode step serves the whole batch; finished requests are
masked (their slots keep stepping — the SPMD-friendly formulation; a slot
allocator would recycle them in a long-running server).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import host_mesh
from repro.models import build
from repro.parallel import ctx
from repro.train.serve import greedy_sample, make_serve_step


def serve_batch(cfg, prompts: np.ndarray, max_new: int,
                mesh=None, log=print) -> Dict[str, Any]:
    mesh = mesh or host_mesh()
    model = build(cfg)
    b, s = prompts.shape
    max_len = s + max_new
    with mesh, ctx.mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(0))
        kw = {"src_len": 8} if cfg.family == "audio" else {}
        cache = model.init_cache(b, max_len, **kw)
        decode = jax.jit(make_serve_step(model))

        pos = jnp.zeros((b,), jnp.int32)
        t0 = time.perf_counter()
        logits = None
        for t in range(s):                      # prefill by stepping
            logits, cache = decode(params, cache, jnp.asarray(prompts[:, t]),
                                   pos)
            pos = pos + 1
        prefill_s = time.perf_counter() - t0

        token = greedy_sample(logits)
        out = [token]
        t0 = time.perf_counter()
        for _ in range(max_new - 1):
            logits, cache = decode(params, cache, token, pos)
            pos = pos + 1
            token = greedy_sample(logits)
            out.append(token)
        jax.block_until_ready(token)
        decode_s = time.perf_counter() - t0

    tokens = np.stack([np.asarray(t) for t in out], axis=1)
    tput = b * (max_new - 1) / max(decode_s, 1e-9)
    log(f"prefill {s} toks x {b} reqs: {prefill_s:.2f}s | "
        f"decode {max_new} toks: {decode_s:.2f}s "
        f"({tput:.1f} tok/s aggregate)")
    return {"tokens": tokens, "prefill_s": prefill_s, "decode_s": decode_s,
            "throughput_tok_s": tput}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    out = serve_batch(cfg, prompts, args.max_new)
    print(f"generated shape: {out['tokens'].shape}")


if __name__ == "__main__":
    main()
