"""Production training driver: mesh-aware SPMD train loop with sharded
state, background data pipeline, async checkpointing, restart-from-latest,
heartbeats and straggler tracking.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 128

On this container the mesh is ``host`` (1 CPU device); on a pod the same
entry point takes --mesh single|multi (16x16 / 2x16x16) — the dry-run
proves those compile.  Restart semantics: if --checkpoint-dir holds a
manifest, training resumes from the latest step (the data pipeline is a
pure function of the step, so the token stream realigns exactly).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,
                                         restore_checkpoint)
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ArchConfig, RunConfig, SHAPES, ShapeConfig
from repro.data.pipeline import DataPipeline, make_batch
from repro.launch.mesh import host_mesh, make_production_mesh
from repro.models import build
from repro.parallel import ctx
from repro.parallel.sharding import batch_sharding, state_shardings
from repro.train import ft
from repro.train.loop import init_state, make_train_step


def train(cfg: ArchConfig, shape: ShapeConfig, run: RunConfig,
          mesh=None, worker: str = "w0",
          log=print) -> Dict[str, Any]:
    mesh = mesh or host_mesh()
    model = build(cfg)

    abstract = jax.eval_shape(
        lambda k: init_state(model, k), jax.random.PRNGKey(run.seed))
    state_sh = state_shardings(abstract, mesh)
    step_fn = jax.jit(make_train_step(model, run),
                      in_shardings=(state_sh, None),
                      out_shardings=(state_sh, NamedSharding(mesh, P())),
                      donate_argnums=(0,))

    manager = CheckpointManager(run.checkpoint_dir, keep=3)
    monitor = ft.FaultToleranceManager(
        heartbeat=ft.HeartbeatMonitor(
            os.path.join(run.checkpoint_dir, "hb")),
        stragglers=ft.StragglerDetector(),
        checkpoint_dir=run.checkpoint_dir, workers=(worker,))

    start = 0
    with mesh, ctx.mesh_context(mesh):
        if latest_step(run.checkpoint_dir) is not None:
            start, state = restore_checkpoint(
                run.checkpoint_dir, abstract, shardings=state_sh)
            log(f"restored checkpoint at step {start}")
        else:
            state = jax.jit(
                lambda k: init_state(model, k),
                out_shardings=state_sh)(jax.random.PRNGKey(run.seed))

        pipe = DataPipeline(cfg, shape, seed=run.seed, start_step=start)
        metrics: Dict[str, Any] = {}
        losses = []
        try:
            for step, batch in pipe:
                if step >= run.total_steps:
                    break
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                monitor.on_step(worker, dt)
                losses.append(loss)
                if step % run.log_every == 0:
                    log(f"step {step:5d} loss {loss:8.4f} "
                        f"grad_norm {float(metrics['grad_norm']):7.3f} "
                        f"({dt:5.2f}s/step)")
                if run.checkpoint_every and step and \
                        step % run.checkpoint_every == 0:
                    manager.save(step, state, extra={"loss": loss})
            manager.save(min(run.total_steps, step + 1), state,
                         extra={"loss": losses[-1] if losses else None})
            manager.wait()
        finally:
            pipe.close()
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "steps": len(losses),
            "health": monitor.health_check()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("host", "single", "multi"),
                    default="host")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(learning_rate=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 10, 1),
                    microbatch=args.microbatch,
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every,
                    log_every=max(args.steps // 50, 1))
    mesh = host_mesh() if args.mesh == "host" else \
        make_production_mesh(multi_pod=(args.mesh == "multi"))
    out = train(cfg, shape, run, mesh=mesh)
    print(f"done: {out}")


if __name__ == "__main__":
    main()
