"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before jax initializes devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod; multi-pod adds a leading 'pod' axis (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...],
              axes: Optional[Tuple[str, ...]] = None) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests / elastic re-meshing."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally (tests: 1 CPU device => (1,1))."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
