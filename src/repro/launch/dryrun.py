import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: no `from __future__ import annotations` here — the XLA_FLAGS export
# above must stay the first executable statement, before any jax import.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this produces
  * a FULL compile (scan-over-layers) on the requested mesh — proves the
    sharding config is coherent, yields memory_analysis();
  * two PROBE compiles (reduced layer count, scans fully unrolled) on the
    single-pod mesh — XLA HloCostAnalysis counts while bodies once, so true
    FLOPs/bytes/collective-bytes are recovered by linear extrapolation:
        f(L) = a + b*L  measured at L = p and L = 2p.
  * the three roofline terms (hardware constants: TPU v5e) plus the
    Distributed Data Calculator's *predicted* terms for comparison.

Results are cached as JSON under experiments/dryrun/ (one file per cell) so
the sweep is resumable.  Run:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all   (subprocess sweep)
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import (ArchConfig, RunConfig, SHAPES, ShapeConfig,
                                shape_applies)
from repro.core import distcalc
from repro.core.hardware import TPU_V5E
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.models.registry import Model
from repro.parallel import (batch_sharding, cache_shardings, data_axes,
                            param_shardings, state_shardings)
from repro.parallel import ctx
from repro.parallel.sharding import embeds_sharding
from repro.train.loop import TrainState, init_state, make_train_step
from repro.train.serve import make_prefill_step, make_serve_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

#: probe layer counts per family pattern period
PROBE_PERIOD = {"dense": 2, "moe": 2, "vlm": 2, "audio": 2,
                "hybrid": 6, "ssm": 4}


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b,), i32),
                "pos": jax.ShapeDtypeStruct((b,), i32)}
    if cfg.family == "audio":
        # half source frames, half target tokens (total = seq_len)
        return {"tokens": jax.ShapeDtypeStruct((b, s // 2), i32),
                "labels": jax.ShapeDtypeStruct((b, s // 2), i32),
                "embeds": jax.ShapeDtypeStruct((b, s // 2, cfg.d_model),
                                               jnp.float32)}
    if cfg.family == "vlm":
        txt = s - cfg.n_patches
        return {"tokens": jax.ShapeDtypeStruct((b, txt), i32),
                "labels": jax.ShapeDtypeStruct((b, txt), i32),
                "embeds": jax.ShapeDtypeStruct((b, cfg.n_patches,
                                                cfg.d_model), jnp.float32)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32)}


def _batch_shardings(specs: Dict, mesh: Mesh, batch: int) -> Dict:
    out = {}
    for key, sds in specs.items():
        if key == "embeds":
            out[key] = embeds_sharding(mesh, batch)
        else:
            out[key] = batch_sharding(mesh, batch, ndim=len(sds.shape))
    return out


def _logits_sharding(mesh: Mesh, cfg: ArchConfig, batch: int
                     ) -> NamedSharding:
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    first = (axes if len(axes) > 1 else axes[0]) \
        if axes and batch % total == 0 else None
    vocab_axis = "model" if "model" in mesh.axis_names and \
        cfg.vocab_size % mesh.shape["model"] == 0 else None
    return NamedSharding(mesh, P(first, vocab_axis))


# ---------------------------------------------------------------------------
# Lower + compile one cell
# ---------------------------------------------------------------------------
#: per-chip activation-stash budget driving the microbatch policy (bytes)
STASH_BUDGET = 2 << 30


def pick_microbatch(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    seq_parallel: bool) -> int:
    """Gradient-accumulation policy: smallest number of microbatches such
    that the per-chip remat stash (one [b_micro, S, D] residual per layer)
    fits the budget.  Microbatch size must stay divisible by the dp ways."""
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    sp = mesh.shape.get("model", 1) if seq_parallel and \
        shape.seq_len % mesh.shape.get("model", 1) == 0 else 1
    cb = 2 if cfg.compute_dtype == "bfloat16" else 4
    layers = cfg.n_layers + cfg.n_encoder_layers
    micro = shape.global_batch
    while micro > dp:
        stash = micro * shape.seq_len * cfg.d_model * cb * layers / (dp * sp)
        if stash <= STASH_BUDGET:
            break
        micro //= 2
    return max(micro, min(dp, shape.global_batch))


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               seq_parallel: Optional[bool] = None,
               microbatch: Optional[int] = None,
               fsdp: bool = True,
               ep: bool = True,
               moment_dtype: str = "float32",
               grad_compression: bool = False) -> Tuple[Any, Any]:
    """Returns (lowered, compiled) for the cell's step function."""
    model = build(cfg)
    specs = input_specs(cfg, shape)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)  # PRNGKey placeholder
    sp = shape.kind == "train" if seq_parallel is None else seq_parallel
    mdt = jnp.dtype(moment_dtype)

    if shape.kind == "train":
        micro = pick_microbatch(cfg, shape, mesh, sp) \
            if microbatch is None else microbatch
        run = RunConfig(microbatch=micro, grad_compression=grad_compression)
        abstract_state = jax.eval_shape(
            lambda k: init_state(model, k, mdt), jax.random.PRNGKey(0))
        state_sh = state_shardings(abstract_state, mesh, fsdp=fsdp,
                                   ep=ep)
        batch_sh = _batch_shardings(specs, mesh, shape.global_batch)
        step = make_train_step(model, run)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh,
                                        NamedSharding(mesh, P())),
                         donate_argnums=(0,))
        with mesh, ctx.mesh_context(mesh), \
                ctx.options(seq_parallel=sp, expert_parallel=ep):
            lowered = jitted.lower(abstract_state, specs)
            compiled = lowered.compile()
        return lowered, compiled

    if shape.kind == "prefill":
        abstract_params = jax.eval_shape(
            lambda k: model.init(k), jax.random.PRNGKey(0))
        p_sh = param_shardings(abstract_params, mesh, fsdp=fsdp, ep=ep)
        batch_sh = _batch_shardings(specs, mesh, shape.global_batch)
        step = make_prefill_step(model, max_len=shape.seq_len)
        kwargs = {}
        args: Tuple = (abstract_params, specs.get("tokens"))
        in_sh: Tuple = (p_sh, batch_sh.get("tokens"))
        if "embeds" in specs:
            args = args + (specs["embeds"],)
            in_sh = in_sh + (batch_sh["embeds"],)
        jitted = jax.jit(step, in_shardings=in_sh)
        with mesh, ctx.mesh_context(mesh), \
                ctx.options(seq_parallel=sp, expert_parallel=ep):
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        return lowered, compiled

    # decode
    model = build(cfg)
    abstract_params = jax.eval_shape(
        lambda k: model.init(k), jax.random.PRNGKey(0))
    p_sh = param_shardings(abstract_params, mesh, fsdp=fsdp, ep=ep)
    kw = {"src_len": 4096} if cfg.family == "audio" else {}
    abstract_cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, **kw))
    c_sh = cache_shardings(abstract_cache, mesh, shape.global_batch, cfg)
    tok_sh = batch_sharding(mesh, shape.global_batch, ndim=1)
    step = make_serve_step(model)
    jitted = jax.jit(
        step, in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
        out_shardings=(_logits_sharding(mesh, cfg, shape.global_batch),
                       c_sh),
        donate_argnums=(1,))
    with mesh, ctx.mesh_context(mesh), \
            ctx.options(seq_parallel=False, expert_parallel=ep):
        lowered = jitted.lower(abstract_params, abstract_cache,
                               input_specs(cfg, shape)["token"],
                               input_specs(cfg, shape)["pos"])
        compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# Cost extraction
# ---------------------------------------------------------------------------
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1.0
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind from optimized HLO.

    Per-chip data-movement factors (ring algorithms): all-reduce = 2x
    result; reduce-scatter = result x group (input is the full buffer);
    all-gather / all-to-all / permute = 1x result.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) and f"{kind}-done" in hlo_text:
            pass  # started op; result shape still correct
        result_bytes = _shape_bytes(m.group(1))
        out[kind] += result_bytes
        counts[kind] += 1
    moved = (2.0 * out["all-reduce"] + out["all-gather"] +
             out["reduce-scatter"] + out["all-to-all"] +
             out["collective-permute"])
    return {"per_kind_result_bytes": out, "counts": counts,
            "moved_bytes_per_chip": moved}


def extract_costs(lowered, compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_fields = {}
    if mem is not None:
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            mem_fields[field] = getattr(mem, field, None)
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "memory": mem_fields, "collectives": coll}


def probe_config(cfg: ArchConfig, n_layers: int) -> ArchConfig:
    changes: Dict[str, Any] = {"n_layers": n_layers, "scan_unroll": True}
    if cfg.is_encdec:
        changes["n_encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **changes)


def measure_cell(arch: str, shape_name: str, mesh_kind: str,
                 with_probes: bool = True,
                 variant: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """``variant`` overrides (seq_parallel / microbatch / fsdp /
    moment_dtype) — the §Perf hillclimb's A/B knobs; None = defaults."""
    variant = variant or {}
    cfg = get_config(arch)
    if "attn_impl" in variant:
        cfg = dataclasses.replace(cfg, attn_impl=variant["attn_impl"])
    shape = SHAPES[shape_name]
    applies, reason = shape_applies(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "time": time.time()}
    if not applies:
        record["skipped"] = reason
        return record

    if "mesh_shape" in variant:  # e.g. (32, 8): same 256 chips, TP=8
        d, m = variant["mesh_shape"]
        mesh = jax.make_mesh((d, m), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    sp = variant.get("seq_parallel", shape.kind == "train")
    if shape.kind == "train":
        record["microbatch"] = variant.get(
            "microbatch", pick_microbatch(cfg, shape, mesh, sp))
        record["n_microbatches"] = shape.global_batch // record["microbatch"]
    record["seq_parallel"] = sp
    kw = dict(seq_parallel=sp,
              microbatch=record.get("microbatch"),
              fsdp=variant.get("fsdp", True),
              ep=variant.get("ep", True),
              moment_dtype=variant.get("moment_dtype", "float32"),
              grad_compression=variant.get("grad_compression", False))
    t0 = time.perf_counter()
    lowered, compiled = lower_cell(cfg, shape, mesh, **kw)
    record["compile_seconds"] = time.perf_counter() - t0
    record["full"] = extract_costs(lowered, compiled)
    del lowered, compiled

    if with_probes and mesh_kind == "single":
        p = PROBE_PERIOD[cfg.family]
        probes = {}
        for mult in (1, 2):
            pc = probe_config(cfg, p * mult)
            # probes run without gradient accumulation: the microbatch scan
            # is a while loop HloCostAnalysis counts once; a single pass has
            # identical FLOPs (the accumulated variant re-gathers FSDP
            # params n_micro times — added analytically in §Roofline)
            pkw = dict(kw, microbatch=shape.global_batch)
            lo, co = lower_cell(pc, shape, mesh, **pkw)
            probes[mult] = extract_costs(lo, co)
            del lo, co
        record["probes"] = {"period": p, "p1": probes[1], "p2": probes[2]}
        record["extrapolated"] = extrapolate(cfg, probes[1], probes[2], p)

    record["distcalc"] = predicted_terms(cfg, shape, mesh_kind)
    record["roofline"] = roofline_terms(cfg, shape, mesh_kind, record)
    return record


def extrapolate(cfg: ArchConfig, p1: Dict, p2: Dict, period: int
                ) -> Dict[str, float]:
    """f(L) = a + b*L measured at L=period and 2*period."""
    L = cfg.n_layers
    out = {}
    for key, get in (("flops", lambda r: r["flops"]),
                     ("bytes_accessed", lambda r: r["bytes_accessed"]),
                     ("collective_bytes",
                      lambda r: r["collectives"]["moved_bytes_per_chip"])):
        f1, f2 = get(p1), get(p2)
        b = (f2 - f1) / period
        a = f1 - b * period
        out[key] = max(a + b * L, 0.0)
    return out


def predicted_terms(cfg: ArchConfig, shape: ShapeConfig | str,
                    mesh_kind: str) -> Dict[str, Any]:
    if isinstance(shape, str):
        shape = SHAPES[shape]
    mesh_spec = distcalc.MeshSpec(pods=2 if mesh_kind == "multi" else 1)
    strat, terms = distcalc.complete_strategy(cfg, shape, mesh_spec)
    return {"strategy": strat.describe(), **terms.to_json()}


def roofline_terms(cfg: ArchConfig, shape: ShapeConfig, mesh_kind: str,
                   record: Dict) -> Dict[str, Any]:
    """Three-term roofline from the measured (extrapolated) HLO costs.

    XLA reports whole-program flops for the SPMD program = per-chip flops.
    """
    chips = 512 if mesh_kind == "multi" else 256
    src = record.get("extrapolated") or {
        "flops": record["full"]["flops"],
        "bytes_accessed": record["full"]["bytes_accessed"],
        "collective_bytes":
            record["full"]["collectives"]["moved_bytes_per_chip"]}
    compute_s = src["flops"] / TPU_V5E.peak_flops_bf16
    memory_s = src["bytes_accessed"] / TPU_V5E.hbm_bw
    collective_s = src["collective_bytes"] / TPU_V5E.ici_bw
    mf = distcalc.model_flops(cfg, shape)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "dominant": max([("compute", compute_s), ("memory", memory_s),
                              ("collective", collective_s)],
                             key=lambda kv: kv[1])[0],
             "model_flops_total": mf,
             "model_flops_per_chip": mf / chips,
             "useful_flops_ratio":
                 (mf / chips) / src["flops"] if src["flops"] else 0.0,
             "roofline_fraction":
                 compute_s / max(compute_s, memory_s, collective_s)
                 if max(compute_s, memory_s, collective_s) > 0 else 0.0}
    return terms


# ---------------------------------------------------------------------------
# Sweep driver (subprocess per cell: isolates compiles, caches results)
# ---------------------------------------------------------------------------
def cell_path(arch: str, shape: str, mesh: str, tag: str = "") -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def run_one(arch: str, shape: str, mesh: str, probes: bool,
            variant: Optional[Dict[str, Any]] = None,
            tag: str = "") -> Dict:
    record = measure_cell(arch, shape, mesh, with_probes=probes,
                          variant=variant)
    with open(cell_path(arch, shape, mesh, tag), "w") as fh:
        json.dump(record, fh, indent=1)
    return record


def sweep(mesh_kinds=("single", "multi"), force: bool = False) -> None:
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in mesh_kinds:
                cells.append((arch, shape, mesh))
    for arch, shape, mesh in cells:
        path = cell_path(arch, shape, mesh)
        if os.path.exists(path) and not force:
            print(f"skip (cached) {arch} {shape} {mesh}")
            continue
        print(f"=== {arch} {shape} {mesh} ===", flush=True)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh],
            env=dict(os.environ),
            capture_output=True, text=True, timeout=7200)
        if proc.returncode != 0:
            print(f"FAILED {arch} {shape} {mesh}:\n{proc.stdout[-2000:]}"
                  f"\n{proc.stderr[-4000:]}", flush=True)
            with open(path, "w") as fh:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "error": proc.stderr[-4000:]}, fh)
        else:
            print(proc.stdout[-800:], flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    # §Perf hillclimb knobs (written to a --tag'd variant file)
    ap.add_argument("--tag", default="", help="variant file suffix")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence parallelism")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params across data (DP baseline)")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--moment-dtype", default=None,
                    choices=(None, "float32", "bfloat16"))
    ap.add_argument("--grad-compress", action="store_true",
                    help="bf16 gradient reduction")
    ap.add_argument("--no-ep", action="store_true",
                    help="replicate experts; TP inside the expert ffn")
    ap.add_argument("--attn-impl", default=None, choices=("xla", "skip"),
                    help="'skip' = attention-internal-bytes ablation probe")
    ap.add_argument("--mesh-shape", default=None,
                    help="single-pod mesh reshape, e.g. 32x8")
    args = ap.parse_args()
    if args.all:
        sweep(force=args.force)
        return
    variant: Dict[str, Any] = {}
    if args.no_sp:
        variant["seq_parallel"] = False
    if args.no_fsdp:
        variant["fsdp"] = False
    if args.microbatch is not None:
        variant["microbatch"] = args.microbatch
    if args.moment_dtype:
        variant["moment_dtype"] = args.moment_dtype
    if args.grad_compress:
        variant["grad_compression"] = True
    if args.no_ep:
        variant["ep"] = False
    if args.attn_impl:
        variant["attn_impl"] = args.attn_impl
    if args.mesh_shape:
        variant["mesh_shape"] = tuple(
            int(x) for x in args.mesh_shape.split("x"))
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for mesh in meshes:
        record = run_one(args.arch, args.shape, mesh,
                         probes=not args.no_probes,
                         variant=variant or None, tag=args.tag)
        summary = {k: record.get(k) for k in
                   ("arch", "shape", "mesh", "skipped", "compile_seconds",
                    "variant", "microbatch")}
        if "roofline" in record:
            summary["roofline"] = record["roofline"]
        if "full" in record:
            summary["memory"] = record["full"]["memory"]
            summary["collectives"] = record["full"]["collectives"][
                "per_kind_result_bytes"]
        print(json.dumps(summary, indent=1, default=str))


if __name__ == "__main__":
    main()
