"""Training step: loss, grads, clipping, AdamW — plus microbatch grad
accumulation (scan over microbatches, constant memory)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models.registry import Model
from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               apply_updates, clip_by_global_norm)

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: AdamWState


def init_state(model: Model, rng, moment_dtype=jnp.float32) -> TrainState:
    params = model.init(rng)
    return TrainState(params, adamw_init(params, moment_dtype))


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [B,S,V] (any float dtype), labels [B,S] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return (logz - gold).mean()


#: sequence positions per chunked-CE slice; at vocab 128k / bf16 one chunk's
#: logits are B/chips x 512 x V ~ 128 MB per chip — VMEM-pipeline friendly
CE_CHUNK = 512


def chunked_cross_entropy(x: jax.Array, embed: Params, labels: jax.Array,
                          cfg: ArchConfig) -> jax.Array:
    """CE over hidden states without materializing [B,S,V] logits.

    Scans the sequence in CE_CHUNK slices; each slice computes its logits,
    reduces them to (logsumexp - gold), and frees them.  The body is
    rematerialized so the backward pass also recomputes per-slice logits
    instead of stashing them — this is what makes llama3-405b/train_4k fit
    (naive CE: ~1.05 TB/chip of logit temps; chunked: ~134 MB/chip)."""
    b, s, d = x.shape
    chunk = min(CE_CHUNK, s)
    if s % chunk != 0:  # fall back (tests with odd tiny lengths)
        from repro.models import layers as L
        return cross_entropy_loss(L.unembed(embed, x, cfg), labels)
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(total, inputs):
        x_blk, l_blk = inputs
        from repro.models import layers as L
        logits = L.unembed(embed, x_blk, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, l_blk[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return total + (logz - gold).sum(), None

    # scan_unroll: dry-run cost probes count while bodies once; unroll so
    # HloCostAnalysis sees every chunk (launch/dryrun.py)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc),
                            unroll=nc if cfg.scan_unroll else 1)
    return total / (b * s)


def _loss_fn(params: Params, batch: Dict[str, jax.Array], model: Model
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    embeds = batch.get("embeds")
    labels = batch["labels"]
    x, aux = model.forward(params, batch["tokens"], embeds=embeds,
                           hidden=True)
    # VLM: hidden states cover [patches ++ text]; loss on text positions
    if x.shape[1] != labels.shape[1]:
        x = x[:, -labels.shape[1]:]
    loss = chunked_cross_entropy(x, params["embed"], labels, model.cfg)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


def train_step(state: TrainState, batch: Dict[str, jax.Array], model: Model,
               run: RunConfig) -> Tuple[TrainState, Dict[str, jax.Array]]:
    grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)
    micro = run.microbatch
    # gradient compression: reduce cross-replica grads in bf16 (halves the
    # all-reduce / reduce-scatter traffic; accumulation + Adam stay fp32)
    compress = (lambda g: g.astype(jnp.bfloat16)) if run.grad_compression \
        else (lambda g: g)
    if micro and micro < batch["tokens"].shape[0]:
        # gradient accumulation: scan over microbatches
        b = batch["tokens"].shape[0]
        n_micro = b // micro
        stacked = {k: v.reshape((n_micro, micro) + v.shape[1:])
                   for k, v in batch.items()}
        acc_dtype = jnp.bfloat16 if run.grad_compression else jnp.float32
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                             state.params)

        def body(acc, mb):
            (_, metrics), grads = grad_fn(state.params, mb, model)
            acc = jax.tree.map(
                lambda a, g: a + (compress(g) / n_micro).astype(a.dtype),
                acc, grads)
            return acc, metrics

        grads, metrics = jax.lax.scan(body, zeros, stacked)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
    else:
        (_, metrics), grads = grad_fn(state.params, batch, model)
        grads = jax.tree.map(compress, grads)
    grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
    updates, opt = adamw_update(grads, state.opt, state.params, run)
    params = apply_updates(state.params, updates)
    metrics = dict(metrics, grad_norm=gnorm)
    return TrainState(params, opt), metrics


def make_train_step(model: Model, run: RunConfig):
    """Closure suitable for jax.jit(in_shardings=..., out_shardings=...)."""

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        return train_step(state, batch, model, run)

    return step
