"""Fault tolerance for multi-pod runs: heartbeats, straggler detection,
checkpoint/restart, and elastic re-meshing plans.

Designed for thousands of workers: all coordination is through cheap local
state + the shared checkpoint directory (no extra RPC layer), matching how
TPU pods are actually babysat.  Every component is unit-testable on one
host by simulating worker reports.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time tracker; flags workers whose step time deviates.

    Mitigation policy at scale: flagged workers are candidates for (a)
    within-step work-stealing is impossible under SPMD, so (b) the runner
    either drops the worker's pod at the next elastic boundary or restarts
    it from checkpoint — both decisions this class feeds.
    """

    alpha: float = 0.1
    threshold: float = 2.0      # flag if step_time > threshold * fleet EWMA
    ewma: Dict[str, float] = dataclasses.field(default_factory=dict)

    def observe(self, worker: str, step_seconds: float) -> None:
        prev = self.ewma.get(worker)
        self.ewma[worker] = (step_seconds if prev is None
                             else (1 - self.alpha) * prev +
                             self.alpha * step_seconds)

    def fleet_median(self) -> float:
        values = sorted(self.ewma.values())
        if not values:
            return 0.0
        return values[len(values) // 2]

    def stragglers(self) -> List[str]:
        median = self.fleet_median()
        if median <= 0:
            return []
        return [w for w, t in self.ewma.items()
                if t > self.threshold * median]


@dataclasses.dataclass
class HeartbeatMonitor:
    """File-based heartbeats: worker i touches <dir>/hb_<i> each step."""

    directory: str
    timeout_seconds: float = 120.0

    def beat(self, worker: str) -> None:
        os.makedirs(self.directory, exist_ok=True)
        path = os.path.join(self.directory, f"hb_{worker}")
        with open(path, "w") as fh:
            fh.write(str(time.time()))

    def dead_workers(self, expected: Sequence[str]) -> List[str]:
        now = time.time()
        dead = []
        for worker in expected:
            path = os.path.join(self.directory, f"hb_{worker}")
            try:
                with open(path) as fh:
                    last = float(fh.read().strip())
            except (FileNotFoundError, ValueError):
                dead.append(worker)
                continue
            if now - last > self.timeout_seconds:
                dead.append(worker)
        return dead


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Re-mesh decision after pod loss/gain.

    The global batch is preserved by rescaling per-pod batch (keeps the
    optimizer trajectory comparable); restore resharding is handled by
    checkpoint.restore_checkpoint against the new mesh's shardings.
    """

    old_pods: int
    new_pods: int
    pod_shape: Tuple[int, int]
    global_batch: int

    @property
    def per_pod_batch(self) -> int:
        return self.global_batch // max(self.new_pods, 1)

    @property
    def mesh_shape(self) -> Tuple[int, ...]:
        if self.new_pods == 1:
            return self.pod_shape
        return (self.new_pods,) + self.pod_shape

    def valid(self) -> bool:
        return self.new_pods >= 1 and \
            self.global_batch % max(self.new_pods, 1) == 0


def plan_elastic_remesh(available_pods: int, pod_shape: Tuple[int, int],
                        global_batch: int, old_pods: int) -> ElasticPlan:
    """Largest power-of-two pod count <= available that divides the batch."""
    pods = 1
    while pods * 2 <= available_pods and \
            global_batch % (pods * 2) == 0:
        pods *= 2
    return ElasticPlan(old_pods, pods, pod_shape, global_batch)


@dataclasses.dataclass
class FaultToleranceManager:
    """Glue: drives heartbeat + straggler checks and restart decisions."""

    heartbeat: HeartbeatMonitor
    stragglers: StragglerDetector
    checkpoint_dir: str
    workers: Sequence[str] = ()

    def on_step(self, worker: str, step_seconds: float) -> None:
        self.heartbeat.beat(worker)
        self.stragglers.observe(worker, step_seconds)

    def health_check(self) -> Dict[str, List[str]]:
        return {"dead": self.heartbeat.dead_workers(self.workers),
                "stragglers": self.stragglers.stragglers()}

    def should_restart(self) -> bool:
        return bool(self.health_check()["dead"])
