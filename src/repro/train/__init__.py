from repro.train.loop import (TrainState, cross_entropy_loss, init_state,
                              make_train_step, train_step)
