"""Serving: batched prefill + decode steps with KV/SSM caches.

``make_serve_step`` returns the one-token decode closure lowered by the
dry-run for ``decode_*`` / ``long_*`` shapes; ``make_prefill_step`` covers
``prefill_*`` shapes.  ``generate`` is the runnable batched-request loop
used by examples/serve_lm.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model

Params = Any


def make_serve_step(model: Model):
    def step(params: Params, cache: Params, token: jax.Array,
             pos: jax.Array) -> Tuple[jax.Array, Params]:
        logits, cache = model.decode_step(params, cache, token, pos)
        return logits, cache

    return step


def make_prefill_step(model: Model, max_len: int):
    cfg = model.cfg

    def step(params: Params, tokens: Optional[jax.Array],
             embeds: Optional[jax.Array] = None):
        if model._prefill is not None:
            return model.prefill(params, tokens, max_len, embeds=embeds)
        # families without a fused prefill: full forward, last-token logits
        logits, _ = model.forward(params, tokens, embeds=embeds)
        return logits[:, -1], None

    return step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def generate(model: Model, params: Params, prompt: jax.Array,
             max_new_tokens: int, max_len: Optional[int] = None,
             embeds=None) -> jax.Array:
    """Batched greedy generation: prompt [B, S] -> [B, S + new]."""
    cfg = model.cfg
    b, s = prompt.shape
    max_len = max_len or (s + max_new_tokens)
    cache = model.init_cache(b, max_len)
    decode = jax.jit(make_serve_step(model))

    # prefill by stepping the prompt (works for every family; transformer
    # families could use the fused prefill instead)
    pos = jnp.zeros((b,), jnp.int32)
    logits = None
    for t in range(s):
        logits, cache = decode(params, cache, prompt[:, t], pos)
        pos = pos + 1
    tokens = [prompt]
    token = greedy_sample(logits)
    for _ in range(max_new_tokens - 1):
        tokens.append(token[:, None])
        logits, cache = decode(params, cache, token, pos)
        pos = pos + 1
        token = greedy_sample(logits)
    tokens.append(token[:, None])
    return jnp.concatenate(tokens, axis=1)
