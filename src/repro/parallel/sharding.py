"""Sharding rules: logical tensor axes -> mesh axes, with divisibility
fallback chains so every assigned (arch x shape) cell shards on the
production meshes (16,16) and (2,16,16).

Strategy (see DESIGN.md §5 and the distcalc auto-completion that derived
it):

* TP ("model" axis): attention q-heads (fallback head_dim), MLP hidden,
  MoE expert axis (EP), vocab (fallback embed dim), mamba/xlstm inner dim.
* FSDP ("data" axis): parameters additionally sharded along their largest
  remaining dim within a pod (hierarchical ZeRO-3 — cross-pod parameter
  gathers avoided; only grad all-reduce crosses pods).
* batch: ("pod", "data"); long-context caches: sequence over "data" when
  the batch axis cannot be split (context parallelism).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

Params = Any

#: params smaller than this stay replicated (FSDP gather overhead dominates)
FSDP_MIN_ELEMS = 1 << 16


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % _axis_size(mesh, axis) == 0


# ---------------------------------------------------------------------------
# Per-leaf rules.  Each rule gives, per tensor dim counted FROM THE END,
# an ordered preference of mesh-axis candidates; the first divisible one
# wins, otherwise the dim is unsharded.  ``None`` marks "never shard".
# dims not listed are unsharded (covers the stacked leading layer dim).
# ---------------------------------------------------------------------------
# name-pattern -> {negative_dim_index: (axis_candidates...)}
_RULES: Tuple[Tuple[str, Dict[int, Tuple[str, ...]]], ...] = (
    # attention projections [.., D, H|K, hd]
    (r"(^|/)(attn|xattn)/w[qkv]$", {-2: ("model",), -1: ("model",),
                                    -3: ("data",)}),
    (r"(^|/)(attn|xattn)/b[qkv]$", {-2: ("model",), -1: ("model",)}),
    (r"(^|/)(attn|xattn)/wo$", {-3: ("model",), -2: ("model",),
                                -1: ("data",)}),
    # MoE expert weights [.., E, D, F] / [.., E, F, D]: EP on E, FSDP inside
    (r"(^|/)moe/w_(gate|up)$", {-3: ("model",), -2: ("data",)}),
    (r"(^|/)moe/w_down$", {-3: ("model",), -2: ("data",)}),
    # no-EP variant (ep=False rewrites moe/ paths to dmoe/): experts
    # replicated across model; TP shards the ffn dim, FSDP the d dim
    (r"(^|/)dmoe/w_(gate|up)$", {-1: ("model",), -2: ("data",)}),
    (r"(^|/)dmoe/w_down$", {-2: ("model",), -1: ("data",)}),
    (r"(^|/)moe/router$", {-2: ("data",)}),
    # dense MLP [.., D, F] / [.., F, D]
    (r"(^|/)mlp/w_(gate|up)$", {-1: ("model",), -2: ("data",)}),
    (r"(^|/)mlp/w_down$", {-2: ("model",), -1: ("data",)}),
    # embeddings
    (r"(^|/)embed/tok$", {-2: ("model",), -1: ("data",)}),
    (r"(^|/)embed/head$", {-1: ("model",), -2: ("data",)}),
    # mamba2
    (r"(^|/)mamba/in_proj$", {-1: ("model",), -2: ("data",)}),
    (r"(^|/)mamba/out_proj$", {-2: ("model",), -1: ("data",)}),
    (r"(^|/)mamba/conv_w$", {-1: ("model",)}),
    # xlstm blocks
    (r"(^|/)mlstm/up_proj$", {-1: ("model",), -2: ("data",)}),
    (r"(^|/)mlstm/down_proj$", {-2: ("model",), -1: ("data",)}),
    (r"(^|/)mlstm/w[qkv]$", {-2: ("model",), -1: ("model",),
                             -3: ("data",)}),
    (r"(^|/)mlstm/w_[if]gate$", {-2: ("data",)}),
    (r"(^|/)slstm/w_in$", {-1: ("model",), -4: ("data",)}),
    (r"(^|/)slstm/r$", {-1: ("model",)}),
    (r"(^|/)slstm/out_proj$", {-2: ("model",), -1: ("data",)}),
)


def _path_to_str(path) -> str:
    parts = []
    for entry in path:
        if hasattr(entry, "key"):
            parts.append(str(entry.key))
        elif hasattr(entry, "idx"):
            parts.append(str(entry.idx))
        elif hasattr(entry, "name"):
            parts.append(str(entry.name))
    return "/".join(parts)


def spec_for_param(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
                   fsdp: bool = True, ep: bool = True) -> P:
    """Resolve one parameter's PartitionSpec under the fallback chain.

    ``fsdp=False`` drops the "data"-axis (ZeRO-3) candidates: params are
    TP-sharded only and replicated across data — the DP baseline the §Perf
    hillclimb compares against (no per-step param gathers, more HBM).
    ``ep=False`` switches MoE expert weights from expert-parallel (model
    axis on E => all-to-all dispatch) to TP-inside-experts (model axis on
    d_ff; experts replicated over data modulo FSDP).
    """
    if not ep:
        path_str = path_str.replace("moe/", "dmoe/")
    if len(shape) == 0 or int(np.prod(shape)) < FSDP_MIN_ELEMS and \
            len(shape) <= 1:
        return P()
    spec: list = [None] * len(shape)
    used_axes = set()
    matched = False
    for pattern, dims in _RULES:
        if re.search(pattern, path_str):
            matched = True
            # sort: model assignments first so FSDP takes what's left
            order = sorted(dims.items(),
                           key=lambda kv: 0 if "model" in kv[1] else 1)
            for neg_idx, candidates in order:
                if -neg_idx > len(shape):
                    continue
                idx = len(shape) + neg_idx
                if spec[idx] is not None:
                    continue
                for axis in candidates:
                    if axis in used_axes or axis not in mesh.axis_names:
                        continue
                    if axis == "data" and (not fsdp or
                            int(np.prod(shape)) < FSDP_MIN_ELEMS):
                        continue
                    if _divisible(shape[idx], mesh, axis):
                        spec[idx] = axis
                        used_axes.add(axis)
                        break
            break
    if not matched:
        # generic fallback: big tensors get model on the last divisible dim
        if int(np.prod(shape)) >= FSDP_MIN_ELEMS and len(shape) >= 2:
            for idx in range(len(shape) - 1, -1, -1):
                if "model" in mesh.axis_names and \
                        _divisible(shape[idx], mesh, "model"):
                    spec[idx] = "model"
                    break
    return P(*spec)


def param_shardings(abstract_params: Params, mesh: Mesh,
                    fsdp: bool = True, ep: bool = True) -> Params:
    """Pytree of NamedSharding matching ``abstract_params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in flat:
        spec = spec_for_param(_path_to_str(path), tuple(leaf.shape), mesh,
                              fsdp=fsdp, ep=ep)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(abstract_state: Any, mesh: Mesh,
                    fsdp: bool = True, ep: bool = True) -> Any:
    """TrainState = (params, AdamWState(step, mu, nu)); Adam moments follow
    the params sharding exactly (same pytree structure)."""
    from repro.optim.adamw import AdamWState
    from repro.train.loop import TrainState
    p_sh = param_shardings(abstract_state.params, mesh, fsdp=fsdp, ep=ep)
    mu_sh = param_shardings(abstract_state.opt.mu, mesh, fsdp=fsdp, ep=ep)
    nu_sh = param_shardings(abstract_state.opt.nu, mesh, fsdp=fsdp, ep=ep)
    return TrainState(p_sh, AdamWState(NamedSharding(mesh, P()),
                                       mu_sh, nu_sh))


# ---------------------------------------------------------------------------
# Activations / inputs / caches
# ---------------------------------------------------------------------------
def batch_sharding(mesh: Mesh, batch_size: int, ndim: int = 2
                   ) -> NamedSharding:
    """tokens/labels [B, S] (or [B] for decode): batch over (pod, data)."""
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    first = None
    if axes and batch_size % total == 0:
        first = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*([first] + [None] * (ndim - 1))))


def embeds_sharding(mesh: Mesh, batch_size: int) -> NamedSharding:
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    first = axes if batch_size % max(total, 1) == 0 and axes else None
    return NamedSharding(mesh, P(first, None, None))


def cache_shardings(abstract_cache: Params, mesh: Mesh, batch: int,
                    cfg: ArchConfig) -> Params:
    """KV / SSM-state caches.

    Preference: batch over (pod,data) when divisible; otherwise shard the
    *sequence* dim over "data" (context parallelism for long_500k b=1).
    Heads/state dims go on "model" when divisible.
    """
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    batch_ok = axes and batch % total == 0
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    out = []
    for path, leaf in flat:
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        # locate the batch dim: first dim equal to batch (after any leading
        # stacking dims); KV caches are [L|apps, B, S, K, hd], ssm states
        # [L, B, ...]
        try:
            b_idx = shape.index(batch)
        except ValueError:
            b_idx = -1
        if b_idx >= 0 and batch_ok:
            spec[b_idx] = axes if len(axes) > 1 else axes[0]
        path_str = _path_to_str(path)
        is_kv = re.search(r"(^|/)(k|v|xk|xv)$", path_str) is not None
        if is_kv and len(shape) >= 4:
            # [.., B, S, K, hd]
            if not (b_idx >= 0 and batch_ok) and "data" in mesh.axis_names \
                    and _divisible(shape[-3], mesh, "data"):
                spec[-3] = "data"  # context parallelism over sequence
            if _divisible(shape[-2], mesh, "model"):
                spec[-2] = "model"
            elif _divisible(shape[-1], mesh, "model"):
                spec[-1] = "model"
        elif len(shape) >= 2:
            # ssm states [.., B, h, n, p] etc: shard a head/state dim
            for idx in range(len(shape) - 1, max(b_idx, 0), -1):
                if spec[idx] is None and \
                        _divisible(shape[idx], mesh, "model") and \
                        shape[idx] >= _axis_size(mesh, "model"):
                    spec[idx] = "model"
                    break
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
