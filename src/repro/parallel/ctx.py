"""Activation-sharding context.

XLA's SPMD partitioner is free to resolve a conflict between FSDP weights
(sharded on "data") and batch-parallel activations (also on "data") by
replicating the batch — catastrophic for DP.  Real frameworks pin
intermediate activations with sharding constraints so the partitioner must
all-gather weights instead.  ``set_mesh`` installs the active mesh; the
model code calls ``constrain_bsd`` etc., which are no-ops outside a mesh
context (single-host tests stay unchanged).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _STATE.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def _clean_axis(axis, mesh: Mesh):
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op if none).

    Axes missing from the mesh are dropped; dims whose size is not
    divisible by the target axis are left unsharded.
    """
    mesh = get_mesh()
    if mesh is None or x.ndim != len(spec):
        return x
    cleaned = []
    for dim, axis in zip(x.shape, spec):
        axis = _clean_axis(axis, mesh)
        if axis is None:
            cleaned.append(None)
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for a in names:
            size *= mesh.shape[a]
        cleaned.append(axis if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


def batch_axes() -> Tuple[str, ...]:
    mesh = get_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def set_sequence_parallel(enabled: bool) -> None:
    """Megatron-style sequence parallelism for the residual stream: outside
    attention/MLP blocks, activations are sharded [batch->(pod,data),
    seq->model].  XLA inserts the block-entry all-gathers; the remat stash
    (the per-layer residual) shrinks by the model-axis size — the change
    that makes llama3-405b/train_4k activations fit (EXPERIMENTS.md §Perf).
    """
    _STATE.seq_parallel = enabled


def sequence_parallel() -> bool:
    return getattr(_STATE, "seq_parallel", False)


def set_expert_parallel(enabled: bool) -> None:
    _STATE.expert_parallel = enabled


def expert_parallel() -> bool:
    return getattr(_STATE, "expert_parallel", True)


@contextlib.contextmanager
def options(seq_parallel: bool = False, expert_parallel: bool = True):
    prev = sequence_parallel()
    prev_ep = globals()["expert_parallel"]()
    set_sequence_parallel(seq_parallel)
    set_expert_parallel(expert_parallel)
    try:
        yield
    finally:
        set_sequence_parallel(prev)
        set_expert_parallel(prev_ep)


def constrain_bsd(x: jax.Array) -> jax.Array:
    """Activations [B, S, D]: batch over (pod, data)."""
    return constrain(x, batch_axes() or None, None, None)


def constrain_residual(x: jax.Array) -> jax.Array:
    """Residual stream [B, S, D] at layer boundaries: batch over (pod,data)
    plus sequence over model when sequence parallelism is on."""
    if sequence_parallel():
        return constrain(x, batch_axes() or None, "model", None)
    return constrain_bsd(x)


def constrain_heads(x: jax.Array) -> jax.Array:
    """Per-head activations [B, S, H, hd]: heads over model (TP)."""
    return constrain(x, batch_axes() or None, None, "model", None)


def constrain_ffn(x: jax.Array) -> jax.Array:
    """MLP hidden [B, S, F]: F over model (TP)."""
    return constrain(x, batch_axes() or None, None, "model")


def constrain_experts(x: jax.Array) -> jax.Array:
    """MoE dispatch [E, C, D]: experts over model (EP); under ep=False the
    expert dim stays replicated and TP lives inside the expert ffn."""
    if not expert_parallel():
        return x
    return constrain(x, "model", None, None)


def constrain_logits(x: jax.Array) -> jax.Array:
    """[B, S, V]: batch over (pod, data), vocab over model."""
    return constrain(x, batch_axes() or None, None, "model")
