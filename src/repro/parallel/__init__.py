from repro.parallel.sharding import (batch_sharding, cache_shardings,
                                     data_axes, param_shardings,
                                     state_shardings)
