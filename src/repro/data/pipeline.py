"""Synthetic sharded token pipeline with background prefetch.

Deterministic per-(step, shard) PRNG so every data-parallel host generates
exactly its shard without coordination — the property a real multi-pod
loader needs (restart-safe: the stream is a pure function of the step).
A Zipf-ish unigram distribution over the vocab avoids degenerate uniform
statistics in the loss.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def synthetic_batch(step: int, batch: int, seq_len: int, vocab: int,
                    seed: int = 0, shard: int = 0, n_shards: int = 1
                    ) -> Dict[str, np.ndarray]:
    """Generate this shard's slice of the global batch for ``step``."""
    per_shard = batch // max(n_shards, 1)
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))
    # zipfian unigram over the vocab (clipped) + shifted-copy labels
    z = rng.zipf(1.3, size=(per_shard, seq_len + 1))
    tokens = np.minimum(z, vocab - 1).astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, step: int = 0,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """A full global batch (single-host test path)."""
    out = synthetic_batch(step, shape.global_batch, shape.seq_len,
                          cfg.vocab_size, seed)
    if cfg.family in ("audio", "vlm"):
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
        if cfg.family == "audio":
            src = shape.seq_len // 2
            out = synthetic_batch(step, shape.global_batch, shape.seq_len // 2,
                                  cfg.vocab_size, seed)
            out["embeds"] = rng.standard_normal(
                (shape.global_batch, src, cfg.d_model)).astype(np.float32)
        else:
            txt = max(shape.seq_len - cfg.n_patches, 1)
            out = synthetic_batch(step, shape.global_batch, txt,
                                  cfg.vocab_size, seed)
            out["embeds"] = rng.standard_normal(
                (shape.global_batch, cfg.n_patches,
                 cfg.d_model)).astype(np.float32)
            # labels must cover patches + text - 1 positions; trainer slices
    return out


class DataPipeline:
    """Background-prefetching iterator over synthetic batches.

    ``sharding`` (optional NamedSharding) device-puts each host batch so
    the training step never blocks on H2D transfers.
    """

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, *,
                 seed: int = 0, start_step: int = 0,
                 sharding: Optional[jax.sharding.NamedSharding] = None,
                 prefetch: int = 2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.sharding = sharding
        self._queue: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.shape, step, self.seed)
            if self.sharding is not None:
                batch = {k: jax.device_put(v, self.sharding)
                         for k, v in batch.items()}
            try:
                self._queue.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Tuple[int, Dict]]:
        return self

    def __next__(self) -> Tuple[int, Dict]:
        return self._queue.get()

    def close(self) -> None:
        self._stop.set()
