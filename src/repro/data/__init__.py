from repro.data.pipeline import DataPipeline, make_batch, synthetic_batch
