"""Fig. 7: (a) bulk-loading cost synthesis vs measured; (b) time to train
all Level-2 access primitives ("merely a few minutes")."""
from __future__ import annotations

import inspect
import time

import numpy as np

from benchmarks.common import container_profile, emit
from repro.core import access, elements as el, structures as S, synthesis
from repro.core.synthesis import Workload
from repro.core.training import benchmark_primitive, train_profile

N = 100_000

PAIRS = [
    ("array", S.Array),
    ("sorted_array", S.SortedArray),
    ("linked_list", S.LinkedList),
    ("skip_list", S.SkipList),
    ("hash_table", S.HashTable),
    ("btree", S.BPlusTree),
]


def run(quick: bool = False) -> None:
    n = 20_000 if quick else N
    hw = container_profile()
    rng = np.random.default_rng(11)
    keys = rng.permutation(n * 2)[:n].astype(np.int64)
    values = keys.copy()
    rows = []
    for name, cls in PAIRS:
        structure = cls()
        measured = S.measure_workload(structure, keys, values,
                                      queries=keys[:5])["bulk_load_s"]
        make = el.ALL_PAPER_SPECS[name]
        sig = inspect.signature(make)
        spec = make(n) if "n_puts" in sig.parameters else make()
        predicted = synthesis.cost("bulk_load", spec, Workload(n_entries=n),
                                   hw)
        rows.append({"structure": name, "measured_ms": measured * 1e3,
                     "predicted_ms": predicted * 1e3,
                     "ratio": predicted / max(measured, 1e-12)})
    emit("fig7a_bulkload", rows)

    # (b) training time per Level-2 primitive
    rows = []
    total = 0.0
    for pname, prim in access.LEVEL2.items():
        t0 = time.perf_counter()
        sizes = prim.sizes[:4] if quick else prim.sizes[:6]
        benchmark_primitive(prim, sizes=sizes, reps=16 if quick else 32)
        dt = time.perf_counter() - t0
        total += dt
        rows.append({"primitive": pname, "train_seconds": dt})
    rows.append({"primitive": "TOTAL", "train_seconds": total})
    emit("fig7b_training_time", rows)


if __name__ == "__main__":
    run()
