"""BENCH_load: sustained mixed-traffic load through the hardened server.

Drives hundreds of concurrent questions — closed-loop interactive
what-if clients plus bulk workload-sweep clients — through two serving
regimes at equal offered load:

1. **fifo** — the pre-hardening baseline (``lanes=False``): one
   unbounded-order queue, no priority, every future resolves when its
   whole coalescing window has scored.  Interactive latency rides on
   whatever bulk work shares (and precedes) the window.
2. **lanes** — the hardened regime: bounded priority lanes with
   weighted dequeue, at most ``bulk_per_window`` sweeps per coalescing
   window, interactive groups scored first and resolved eagerly.

Recorded per regime: per-lane p50/p95/p99 latency, questions/sec; the
acceptance bar is interactive p99 improving ``TARGET_P99_RATIO`` x under
lanes.  Three hardening behaviors are exercised and recorded alongside:

* **overload shedding** — a burst into a deliberately tiny bulk lane
  must shed with :class:`~repro.serving.admission.RejectedError`
  (never block, never deadlock); the shed rate lands in the row;
* **zero recompiles under load** — ``devicecost.trace_count`` must not
  move across the measured lanes drive (hardware swap stays a pure
  parameter-table swap even with concurrent mixed traffic);
* **warm restart** — the synthesis/packing memos are snapshotted
  (:meth:`~repro.serving.DesignCalculatorService.save_snapshot`), the
  packing layers are dropped, and the first question of a freshly
  started service is timed cold vs snapshot-restored; the bar is
  ``TARGET_WARM_SPEEDUP`` x.  Compiled executables are deliberately
  kept in both arms — a real restart pays XLA compilation identically
  either way, so the in-process A/B isolates exactly what the snapshot
  persists.

Interactive answers are spot-checked against the scalar ``cost_workload``
oracle (1e-6) after the drives.  Each full run appends one labelled
entry to experiments/bench/BENCH_load.json; ``run(smoke=True)`` pushes a
small mixed burst through the lanes regime in seconds — zero recompiles,
zero shed interactive requests, parity — without touching the
trajectory.  Standalone runs re-exec under the tcmalloc +
``xla_force_host_platform_device_count`` process tuning
(:func:`benchmarks.common.apply_process_tuning`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import emit_trajectory

#: acceptance bar: interactive p99 (fifo) / interactive p99 (lanes)
TARGET_P99_RATIO = 3.0
#: acceptance bar: cold first-question / warm-restarted first-question
TARGET_WARM_SPEEDUP = 3.0


def _interactive_questions(workload, skewed, h1, h2) -> List[Tuple]:
    """A small cycle of cheap what-if questions (the interactive lane)."""
    from repro.core import elements as el, whatif
    b, hsh, skip = el.spec_btree(), el.spec_hash_table(), el.spec_skip_list()
    bloom = whatif.add_bloom_filters(el.spec_hash_table())
    return [
        ("design", b, el.spec_btree(fanout=40), workload, h1),
        ("hardware", hsh, workload, h1, h2),
        ("workload", skip, workload, skewed, h1),
        ("design", hsh, bloom, workload, h2),
        ("hardware", b, workload, h1, h2),
        ("workload", b, workload, skewed, h2),
    ]


def _bulk_sweep(n_specs: int, n_points: int, base_workload):
    """One deliberately heavy (designs x workloads) sweep (the bulk lane)."""
    from repro.core import elements as el
    specs = [el.spec_btree(fanout=8 + 2 * i, page=128 << (i % 3))
             for i in range(n_specs)]
    alphas = np.linspace(0.0, 1.5, n_points)
    workloads = [dataclasses.replace(base_workload, zipf_alpha=float(a))
                 for a in alphas]
    return specs, workloads


def _submit_interactive(service, q: Tuple):
    kind = q[0]
    if kind == "design":
        return service.submit_design(q[1], q[2], q[3], q[4])
    if kind == "hardware":
        return service.submit_hardware(q[1], q[2], q[3], q[4])
    return service.submit_workload(q[1], q[2], q[3], q[4])


def _drive(service, duration_s: float, n_interactive: int, n_bulk: int,
           questions: List[Tuple], sweep, bulk_hw) -> Dict:
    """Closed-loop mixed load for ``duration_s``; per-lane latencies."""
    from repro.serving import RejectedError, ServiceError
    out = {"interactive": [], "bulk": [], "shed_interactive": 0,
           "shed_bulk": 0, "errors": []}
    lock = threading.Lock()
    stop = threading.Event()
    specs, workloads = sweep

    def interactive_client(idx: int) -> None:
        i = idx
        while not stop.is_set():
            q = questions[i % len(questions)]
            i += 1
            t0 = time.perf_counter()
            try:
                _submit_interactive(service, q).result(timeout=120.0)
            except RejectedError:
                with lock:
                    out["shed_interactive"] += 1
                time.sleep(0.001)
                continue
            except ServiceError as exc:
                with lock:
                    out["errors"].append(repr(exc))
                continue
            with lock:
                out["interactive"].append(time.perf_counter() - t0)

    def bulk_client(idx: int) -> None:
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                service.submit_sweep(specs, workloads,
                                     bulk_hw).result(timeout=300.0)
            except RejectedError:
                with lock:
                    out["shed_bulk"] += 1
                time.sleep(0.001)
                continue
            except ServiceError as exc:
                with lock:
                    out["errors"].append(repr(exc))
                continue
            with lock:
                out["bulk"].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=interactive_client, args=(i,),
                                daemon=True) for i in range(n_interactive)]
    threads += [threading.Thread(target=bulk_client, args=(i,),
                                 daemon=True) for i in range(n_bulk)]
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=60)
    out["wall_s"] = time.perf_counter() - t_start
    return out


def _percentiles(samples: List[float]) -> Dict[str, float]:
    if not samples:
        return {"p50": float("nan"), "p95": float("nan"),
                "p99": float("nan")}
    arr = np.asarray(samples) * 1e3   # -> milliseconds
    return {"p50": float(np.percentile(arr, 50)),
            "p95": float(np.percentile(arr, 95)),
            "p99": float(np.percentile(arr, 99))}


def _check_parity(service, questions: List[Tuple]) -> None:
    """Sampled answers under load-warmed caches vs the scalar oracle."""
    from repro.core import whatif
    oracle_fns = {"design": whatif.what_if_design,
                  "hardware": whatif.what_if_hardware,
                  "workload": whatif.what_if_workload}
    for q in questions[:3]:
        got = _submit_interactive(service, q).result(timeout=120.0)
        ref = oracle_fns[q[0]](*q[1:], engine="scalar")
        for attr in ("baseline_seconds", "variant_seconds"):
            g, r = getattr(got, attr), getattr(ref, attr)
            assert abs(g - r) <= 1e-6 * abs(r), (q[0], attr, g, r)


def _overload_probe(h1, workload) -> Tuple[int, int]:
    """Burst into a tiny bulk lane: sheds must reject, never deadlock."""
    from repro.serving import (DesignCalculatorService, RejectedError,
                               ServiceError)
    specs, workloads = _bulk_sweep(4, 3, workload)
    svc = DesignCalculatorService([h1], window_s=0.05, bulk_capacity=2,
                                  bulk_per_window=1)
    n_offered, shed, futures = 24, 0, []
    try:
        for _ in range(n_offered):
            try:
                futures.append(svc.submit_sweep(specs, workloads, h1))
            except RejectedError:
                shed += 1
        for fut in futures:
            try:
                fut.result(timeout=60)
            except ServiceError:
                pass
    finally:
        svc.stop()
    return shed, n_offered


def _forget_packing() -> None:
    """Drop exactly the layers a warm-restart snapshot persists (plus
    their synthesis feeders), keeping compiled executables: the cold/warm
    A/B then isolates the snapshot's contribution."""
    from repro.core import memo, templatecost
    from repro.core.synthesis import clear_synthesis_caches
    with memo.MEMO_LOCK:
        for name in ("packed_spec", "frontier", "sweep"):
            cache = memo.REGISTRY.get(name)
            if cache is not None:
                cache.clear()
        templatecost.clear_template_caches()
        clear_synthesis_caches()


def _first_question_s(h1, workload, n_specs: int, n_points: int,
                      snapshot_path: Optional[str]) -> Tuple[float, int]:
    """Start a fresh service (optionally warm-restored) on dropped packing
    caches and time its first sweep question, built from *fresh* spec and
    workload objects (no instance-level statics riding along)."""
    from repro.serving import DesignCalculatorService
    _forget_packing()
    specs, workloads = _bulk_sweep(n_specs, n_points, workload)
    svc = DesignCalculatorService([h1], window_s=0.001,
                                  snapshot_path=snapshot_path)
    try:
        t0 = time.perf_counter()
        svc.workload_sweep(specs, workloads, h1)
        elapsed = time.perf_counter() - t0
        restored = svc.stats()["snapshot_entries"]
    finally:
        svc.stop()
    return elapsed, restored


def _smoke(h1, h2, workload, skewed) -> None:
    """S5 smoke: a small mixed burst through the lanes regime — zero
    recompiles, zero dropped interactive requests, scalar parity."""
    from benchmarks.common import _print_table
    from repro.core import devicecost
    from repro.serving import DesignCalculatorService
    questions = _interactive_questions(workload, skewed, h1, h2)
    sweep = _bulk_sweep(6, 4, workload)
    svc = DesignCalculatorService([h1, h2], window_s=0.05,
                                  bulk_per_window=1)
    try:
        # warm pass compiles every shape the burst can produce
        for q in questions:
            _submit_interactive(svc, q).result(timeout=120.0)
        svc.submit_sweep(*sweep, h1).result(timeout=300.0)
        res = _drive(svc, 0.5, n_interactive=4, n_bulk=1,
                     questions=questions, sweep=sweep, bulk_hw=h1)
        traces_before = devicecost.trace_count()
        futures = [_submit_interactive(svc, q) for q in questions * 2]
        futures.append(svc.submit_sweep(*sweep, h1))
        for fut in futures:
            fut.result(timeout=60)
        recompiles = devicecost.trace_count() - traces_before
        _check_parity(svc, questions)
        stats = svc.stats()
    finally:
        svc.stop()
    assert recompiles == 0, \
        f"mixed burst recompiled the fused scorer {recompiles}x"
    assert res["shed_interactive"] == 0 and stats["shed_interactive"] == 0, \
        "interactive requests were shed under a small mixed burst"
    assert not res["errors"], res["errors"][:3]
    lat = _percentiles(res["interactive"])
    _print_table("BENCH_load [smoke — not persisted]", [{
        "interactive_served": len(res["interactive"]),
        "bulk_served": len(res["bulk"]),
        "interactive_p50_ms": lat["p50"],
        "interactive_p99_ms": lat["p99"],
        "recompiles": recompiles,
        "shed_interactive": stats["shed_interactive"],
    }])
    print("load smoke: zero recompiles, zero interactive sheds, parity ok")


def run(quick: bool = False, smoke: bool = False) -> None:
    import os
    import tempfile

    from repro.core import devicecost
    from repro.core.hardware import hw1, hw2
    from repro.core.synthesis import Workload
    from repro.serving import DesignCalculatorService

    workload = Workload(n_entries=100_000, n_queries=100)
    skewed = dataclasses.replace(workload, zipf_alpha=1.5)
    h1, h2 = hw1(), hw2()
    if smoke:
        _smoke(h1, h2, workload, skewed)
        return

    duration = 2.0 if quick else 4.0
    n_interactive, n_bulk = 8, 3
    # the bulk sweep must be *heavy*: its fused call is the thing
    # interactive requests hide behind in the FIFO baseline (~32k cells
    # is ~10-15 ms of scoring per call on the container CPU)
    n_specs, n_points = (384, 48) if quick else (512, 64)
    questions = _interactive_questions(workload, skewed, h1, h2)
    sweep = _bulk_sweep(n_specs, n_points, workload)

    # -- regime A: pre-hardening FIFO baseline ------------------------------
    fifo_svc = DesignCalculatorService([h1, h2], window_s=0.002,
                                       lanes=False)
    try:
        _drive(fifo_svc, min(duration / 2, 1.5), n_interactive, n_bulk,
               questions, sweep, h1)                  # warm + compile
        fifo = _drive(fifo_svc, duration, n_interactive, n_bulk,
                      questions, sweep, h1)
    finally:
        fifo_svc.stop()
    assert not fifo["errors"], fifo["errors"][:3]

    # -- regime B: hardened lanes, equal offered load -----------------------
    lanes_svc = DesignCalculatorService([h1, h2], window_s=0.002,
                                        bulk_per_window=1)
    try:
        _drive(lanes_svc, min(duration / 2, 1.5), n_interactive, n_bulk,
               questions, sweep, h1)                  # warm + compile
        traces_before = devicecost.trace_count()
        lanes = _drive(lanes_svc, duration, n_interactive, n_bulk,
                       questions, sweep, h1)
        recompiles = devicecost.trace_count() - traces_before
        _check_parity(lanes_svc, questions)
        lane_stats = lanes_svc.stats()
    finally:
        lanes_svc.stop()
    assert not lanes["errors"], lanes["errors"][:3]
    assert lanes["shed_interactive"] == 0, \
        "interactive lane shed under nominal load"
    assert recompiles == 0, \
        f"sustained mixed load recompiled the fused scorer {recompiles}x"

    shed, offered = _overload_probe(h1, workload)
    assert shed > 0, "overloading a 2-deep bulk lane shed nothing"

    # -- warm restart -------------------------------------------------------
    with tempfile.TemporaryDirectory() as tmp:
        snap = os.path.join(tmp, "memo.snapshot")
        keeper = DesignCalculatorService([h1], snapshot_path=snap,
                                         start=False)
        written = keeper.save_snapshot()      # caches are load-warm
        cold_s, _ = _first_question_s(h1, workload, n_specs, n_points,
                                      snapshot_path=None)
        warm_s, restored = _first_question_s(h1, workload, n_specs,
                                             n_points, snapshot_path=snap)
    assert restored > 0, "warm restart restored nothing from the snapshot"
    warm_speedup = cold_s / max(warm_s, 1e-12)

    fifo_i = _percentiles(fifo["interactive"])
    lanes_i = _percentiles(lanes["interactive"])
    lanes_b = _percentiles(lanes["bulk"])
    p99_ratio = fifo_i["p99"] / max(lanes_i["p99"], 1e-12)
    rows = [{
        "bench": "sustained_load",
        "duration_s": duration,
        "clients_interactive": n_interactive,
        "clients_bulk": n_bulk,
        "fifo_interactive_p50_ms": fifo_i["p50"],
        "fifo_interactive_p99_ms": fifo_i["p99"],
        "fifo_qps": (len(fifo["interactive"]) + len(fifo["bulk"]))
        / fifo["wall_s"],
        "lanes_interactive_p50_ms": lanes_i["p50"],
        "lanes_interactive_p95_ms": lanes_i["p95"],
        "lanes_interactive_p99_ms": lanes_i["p99"],
        "lanes_bulk_p50_ms": lanes_b["p50"],
        "lanes_bulk_p99_ms": lanes_b["p99"],
        "lanes_qps": (len(lanes["interactive"]) + len(lanes["bulk"]))
        / lanes["wall_s"],
        "interactive_p99_ratio": p99_ratio,
        "shed_rate_overloaded": shed / offered,
        "recompiles_under_load": recompiles,
        "score_calls": lane_stats["score_calls"],
        "snapshot_entries": written,
        "cold_first_question_s": cold_s,
        "warm_first_question_s": warm_s,
        "warm_restart_speedup": warm_speedup,
    }]
    # device scaling: questions/sec through the scoring-shard pool at 1
    # vs 4 forced host devices (subprocess children — the device count
    # is fixed at backend init).  The >= 2x bar is asserted inside
    # serving_scaling_row on hosts with >= 4 physical cores and recorded
    # as an explicit waiver otherwise.
    from benchmarks import device_scaling
    scaling = device_scaling.serving_scaling_row(quick)
    print(f"shard-routed serving at {device_scaling.BAR_DEVICES} devices"
          f" vs 1: {scaling['speedup_serving_4dev_vs_1dev']:.2f}x "
          f"({scaling['scaling_bar']})")
    rows[0].update(scaling)
    keys = list(rows[0].keys())
    print(f"interactive p99: fifo {fifo_i['p99']:.1f} ms -> lanes "
          f"{lanes_i['p99']:.1f} ms ({p99_ratio:.1f}x, target >= "
          f"{TARGET_P99_RATIO:.0f}x); warm restart {warm_speedup:.1f}x "
          f"(target >= {TARGET_WARM_SPEEDUP:.0f}x)")
    assert p99_ratio >= TARGET_P99_RATIO, \
        "priority lanes regressed below the interactive-p99 bar"
    assert warm_speedup >= TARGET_WARM_SPEEDUP, \
        "warm restart regressed below the first-question bar"
    emit_trajectory("BENCH_load", "PR7 device-routed serving tier",
                    rows, keys=keys)


if __name__ == "__main__":
    from benchmarks.common import apply_process_tuning
    apply_process_tuning()
    run()
