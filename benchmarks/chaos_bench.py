"""BENCH_chaos: mixed traffic through the serving tier under injected
faults — the PR 8 self-healing acceptance run.

Drives the PR 6 closed-loop mixed traffic (interactive what-if clients +
bulk workload-sweep clients, same shapes as ``benchmarks/load_bench.py``
so the throughput numbers are comparable to BENCH_load's lanes regime)
through two arms on one hardened service:

1. **fault-free** — no :class:`~repro.testing.faults.FaultPlan` active:
   the seams must cost nothing.  Asserted: **zero recompiles** across
   the measured drive and every request answered; the arm's
   questions/sec lands in the row next to BENCH_load's.
2. **chaos** — ~5% of scoring work is sabotaged by a seeded plan:
   shard dispatches raise (3%) and hang (1%, well past the part
   timeout) and fused outputs NaN-poison (1%).

Two catastrophic one-shot events are probed *between* the arms, outside
the timed drives (they are not part of the 5% steady-state fault rate
the p99 bar is about): the worker loop is crashed once — its in-flight
window must fail *typed*, with :class:`~repro.serving.WorkerCrashed`,
and the supervisor must resurrect the loop — and one profile's
parameter banks are NaN-poisoned once — the fused -> flat -> grouped
chain must serve the *exact* oracle answer, hold it while degraded, and
recover through the timed fused probe.

Acceptance bars — all asserted **before** anything is appended to the
trajectory:

* **nothing lost**: >= ``TARGET_RESOLVED`` of submitted requests
  resolve with an answer or a typed ``ServiceError``; zero futures hang
  (every wait bounded), zero untyped errors;
* **every served answer is right**: interactive answers match the
  scalar ``cost_workload`` oracle to 1e-6 and sweep grids match the
  grouped oracle to 1e-6 (itself spot-checked against scalar cells) —
  regardless of which engine (fused / fused-flat / grouped) served
  them;
* **bounded latency damage**: chaos-arm interactive p99 within
  ``TARGET_CHAOS_P99_RATIO`` x of the fault-free arm's.

Each full run appends one labelled entry to
experiments/bench/BENCH_chaos.json.  ``run(smoke=True)`` — wired into
``benchmarks/run.py --smoke`` — injects exactly one shard failure and
one NaN-bank corruption, oracle-checks both answers, and writes no
artifacts.  Standalone runs re-exec under
:func:`benchmarks.common.apply_process_tuning`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import emit_trajectory
from benchmarks.load_bench import (_bulk_sweep, _interactive_questions,
                                   _percentiles, _submit_interactive)

#: >= this fraction of submitted requests must resolve with an answer
#: or a typed ServiceError (the rest may only be admission sheds)
TARGET_RESOLVED = 0.99
#: chaos-arm interactive p99 / fault-free interactive p99
TARGET_CHAOS_P99_RATIO = 3.0

#: the ~5% sabotage plan (rates are per seam crossing)
CHAOS_SEED = 1808
FAULT_RATES = {"dispatch_error": 0.035, "dispatch_hang": 0.005,
               "fused_corrupt": 0.01}


def _chaos_plan(hang_s: float):
    from repro.testing.faults import FaultPlan, FaultRule
    return FaultPlan(CHAOS_SEED, [
        FaultRule("shards.dispatch", kind="error",
                  rate=FAULT_RATES["dispatch_error"]),
        FaultRule("shards.dispatch", kind="hang",
                  rate=FAULT_RATES["dispatch_hang"], hang_s=hang_s),
        FaultRule("devicecost.fused", kind="corrupt",
                  rate=FAULT_RATES["fused_corrupt"]),
    ])


def _drive(service, duration_s: float, n_interactive: int, n_bulk: int,
           questions: List[Tuple], sweep, bulk_hw,
           think_s: Tuple[float, float] = (0.008, 0.03)) -> Dict:
    """Paced closed-loop mixed load that keeps every outcome: latencies
    per lane, (question, answer) pairs for parity, and a full resolution
    census — answered / typed / shed / untyped / hung.

    ``think_s`` is the (interactive, bulk) per-client pause between
    requests.  Unlike BENCH_load's zero-think-time drives (whose point
    is saturation behavior), the chaos bench offers *nominal* load: the
    p99-damage bar is about what healing costs when the system has the
    slack to heal, not about queueing theory at 100% utilization, where
    any capacity loss inflates the tail without bound."""
    from repro.serving import RejectedError, ServiceError
    out: Dict = {"interactive": [], "bulk": [], "answers": [],
                 "sweeps": [], "submitted": 0, "answered": 0,
                 "typed_errors": 0, "shed_interactive": 0, "shed_bulk": 0,
                 "untyped": [], "hung": 0}
    lock = threading.Lock()
    stop = threading.Event()
    specs, workloads = sweep

    def resolve(fut, t0: float, record) -> None:
        try:
            answer = fut.result(timeout=30)
        except FutureTimeout:
            with lock:          # a lost/hung future — the cardinal sin
                out["hung"] += 1
            return
        except ServiceError:
            with lock:
                out["typed_errors"] += 1
            return
        except Exception as exc:    # noqa: BLE001 — census, not control
            with lock:
                out["untyped"].append(repr(exc))
            return
        dt = time.perf_counter() - t0
        with lock:
            out["answered"] += 1
            record(answer, dt)

    def interactive_client(idx: int) -> None:
        # staggered starts: a simultaneous thundering herd at arm start
        # floods the first windows, and with a plan active (executor-
        # routed parts) the queue wait trips spurious part timeouts
        # whose hedges queue behind the same backlog — a ramp-in
        # artifact, not steady-state healing
        time.sleep(idx * 0.004)
        i = idx
        while not stop.is_set():
            qi = i % len(questions)
            i += 1
            t0 = time.perf_counter()
            try:
                fut = _submit_interactive(service, questions[qi])
            except RejectedError:
                with lock:
                    out["shed_interactive"] += 1
                time.sleep(0.001)
                continue
            with lock:
                out["submitted"] += 1

            def record(answer, dt, qi=qi):
                out["interactive"].append(dt)
                out["answers"].append((qi, answer))
            resolve(fut, t0, record)
            time.sleep(think_s[0])

    def bulk_client(idx: int) -> None:
        time.sleep(0.005 + idx * 0.02)      # see interactive_client
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                fut = service.submit_sweep(specs, workloads, bulk_hw)
            except RejectedError:
                with lock:
                    out["shed_bulk"] += 1
                time.sleep(0.001)
                continue
            with lock:
                out["submitted"] += 1

            def record(answer, dt):
                out["bulk"].append(dt)
                out["sweeps"].append(answer)
            resolve(fut, t0, record)
            time.sleep(think_s[1])

    threads = [threading.Thread(target=interactive_client, args=(i,),
                                daemon=True) for i in range(n_interactive)]
    threads += [threading.Thread(target=bulk_client, args=(i,),
                                 daemon=True) for i in range(n_bulk)]
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=90)
    out["wall_s"] = time.perf_counter() - t_start
    return out


def _compile_ladder(hws, max_records: int) -> None:
    """Deterministically pre-trace every fused-kernel signature the
    drives can produce.

    A fused trace is keyed by the pow2 record bucket and segment pad
    (``devicecost._pad_records``), and a coalescing window holds
    anywhere from one evaluation (a lightly-loaded paced client) to a
    full batch — so the *drive*-based warmup only compiles the window
    compositions it happens to see.  Walking the pow2 bucket ladder up
    front makes arm A's zero-recompile assert independent of warmup
    scheduling luck.  Both the plain and the device-routed dispatch are
    warmed; profiles share bank shapes, so the ladder costs one trace
    set total."""
    import jax

    from repro.core import devicecost, elements as el
    from repro.core.batchcost import pack_frontier
    from repro.core.synthesis import Workload
    # a real fitted model id — _check_frontier rejects unfitted ids
    mid = pack_frontier([el.spec_btree()],
                        Workload(n_entries=1000, n_queries=10), None).ids[0]
    dev = jax.local_devices()[0]
    for hw in hws:
        for n_seg in (1, 17):        # n_pad 16 and 32
            bucket = 16
            while bucket <= max_records:
                ids = np.full(bucket, mid, np.int32)
                sizes = np.ones(bucket, np.float32)
                weights = np.zeros(bucket, np.float32)
                tiles = np.zeros(bucket // devicecost.TILE, np.int64)
                devicecost.score_frontier(ids, sizes, weights, tiles,
                                          n_seg, hw, shard=False)
                devicecost.score_frontier(ids, sizes, weights, tiles,
                                          n_seg, hw, device=dev)
                bucket *= 2


def _interactive_oracles(questions: List[Tuple]) -> List:
    from repro.core import whatif
    fns = {"design": whatif.what_if_design,
           "hardware": whatif.what_if_hardware,
           "workload": whatif.what_if_workload}
    return [fns[q[0]](*q[1:], engine="scalar") for q in questions]


def _assert_parity(res: Dict, oracles: List, sweep_oracle: np.ndarray,
                   arm: str) -> None:
    """Every *served* answer matches its oracle — whichever engine
    produced it."""
    for qi, answer in res["answers"]:
        ref = oracles[qi]
        for attr in ("baseline_seconds", "variant_seconds"):
            got, want = getattr(answer, attr), getattr(ref, attr)
            assert abs(got - want) <= 1e-6 * abs(want), (
                f"{arm}: interactive answer diverged from the scalar "
                f"oracle (q{qi} {attr}: {got!r} vs {want!r}, "
                f"engine={answer.engine})")
    for answer in res["sweeps"]:
        assert np.allclose(answer.totals, sweep_oracle, rtol=1e-6), (
            f"{arm}: sweep grid diverged from the grouped oracle "
            f"(engine={answer.engine})")


def _assert_resolution(res: Dict, arm: str) -> float:
    assert res["hung"] == 0, \
        f"{arm}: {res['hung']} futures hung past their bounded wait"
    assert not res["untyped"], \
        f"{arm}: untyped client-visible errors: {res['untyped'][:3]}"
    resolved = res["answered"] + res["typed_errors"]
    ratio = resolved / max(res["submitted"], 1)
    assert ratio >= TARGET_RESOLVED, (
        f"{arm}: only {ratio:.4f} of submitted requests resolved "
        f"(answered {res['answered']}, typed {res['typed_errors']}, "
        f"of {res['submitted']})")
    return ratio


def _crash_probe(service, questions: List[Tuple]) -> int:
    """Crash the worker once; the in-flight window must fail typed and
    the supervisor must resurrect the loop.  Returns restart count."""
    from repro.serving import WorkerCrashed
    from repro.testing.faults import FaultPlan, FaultRule
    plan = FaultPlan(CHAOS_SEED, [FaultRule("service.worker",
                                            kind="error", at=(0,))])
    with plan.activate():
        fut = _submit_interactive(service, questions[0])
        try:
            fut.result(timeout=30)
            raise AssertionError("injected worker crash did not surface")
        except WorkerCrashed:
            pass
    _submit_interactive(service, questions[0]).result(timeout=30)
    restarts = service.stats()["worker_restarts"]
    assert restarts >= 1 and service.health()["worker_alive"]
    return restarts


def _degradation_probe(service, questions: List[Tuple], oracles: List,
                       victim, probe_s: float) -> None:
    """NaN-poison one profile's parameter banks (once); the degraded
    chain must serve the *exact* grouped-oracle answer, stay on it while
    degraded, and recover through the timed fused probe."""
    from repro.core import devicecost
    from repro.testing.faults import FaultPlan, FaultRule
    qi = next(i for i, q in enumerate(questions) if q[-1] is victim)
    q, ref = questions[qi], oracles[qi]
    # the corruption only bites a *rebuilt* table: drop the live one and
    # rebuild it under the plan *here*, synchronously — a tight part
    # timeout must not let an abandoned first build race a clean rebuild
    # for the cache slot (the one-shot rule would be spent on the loser)
    devicecost.invalidate_table(victim)
    plan = FaultPlan(CHAOS_SEED + 1, [
        FaultRule("devicecost.banks", kind="corrupt", rate=1.0,
                  key=victim.name, max_fires=1)])
    with plan.activate():
        devicecost.device_table(victim)
        assert plan.fires("devicecost.banks") == 1
        got = _submit_interactive(service, q).result(timeout=30)
    assert got.engine == "grouped", \
        f"NaN banks were served by {got.engine!r}, not the grouped oracle"
    assert abs(got.baseline_seconds - ref.baseline_seconds) \
        <= 1e-9 * abs(ref.baseline_seconds)
    assert service.health()["engines"][victim.name]["degraded"]
    time.sleep(probe_s + 0.1)
    got = _submit_interactive(service, q).result(timeout=30)
    assert got.engine == "fused", \
        "the engine probe did not recover the fused path"
    assert not service.health()["engines"][victim.name]["degraded"]


def _smoke(h1, workload, skewed) -> None:
    """S6 smoke: one injected shard failure + one NaN-bank corruption,
    both oracle-checked; no artifacts."""
    from repro.core import devicecost, whatif
    from repro.serving import DesignCalculatorService
    from repro.testing.faults import FaultPlan, FaultRule
    questions = _interactive_questions(workload, skewed, h1, h1)
    q = questions[0]
    oracle = whatif.what_if_design(*q[1:], engine="scalar")
    svc = DesignCalculatorService([h1], window_s=0.002,
                                  engine_probe_s=30.0)
    try:
        _submit_interactive(svc, q).result(timeout=60)      # warm
        # one shard-dispatch failure: healed by the pool, served fused
        with FaultPlan(7, [FaultRule("shards.dispatch", kind="error",
                                     at=(0,))]).activate():
            got = _submit_interactive(svc, q).result(timeout=60)
        assert abs(got.baseline_seconds - oracle.baseline_seconds) \
            <= 1e-6 * oracle.baseline_seconds
        assert got.engine == "fused" and \
            svc.stats()["shard_retries"] >= 1
        # one NaN-bank corruption: served exactly by the grouped oracle
        devicecost.invalidate_table(h1)
        with FaultPlan(7, [FaultRule("devicecost.banks", kind="corrupt",
                                     rate=1.0, max_fires=1)]).activate():
            got = _submit_interactive(svc, q).result(timeout=60)
        assert abs(got.baseline_seconds - oracle.baseline_seconds) \
            <= 1e-9 * oracle.baseline_seconds
        assert got.engine == "grouped" and \
            svc.stats()["fallback_grouped"] >= 1
    finally:
        svc.stop()
    print("chaos smoke: shard failure healed fused, NaN banks served by "
          "the grouped oracle, both to oracle parity")


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.core import devicecost, whatif
    from repro.core.hardware import hw1, hw2
    from repro.core.synthesis import Workload, cost_workload
    from repro.serving import DesignCalculatorService
    from repro.testing.faults import FaultPlan

    workload = Workload(n_entries=100_000, n_queries=100)
    skewed = dataclasses.replace(workload, zipf_alpha=1.5)
    h1, h2 = hw1(), hw2()
    if smoke:
        _smoke(h1, workload, skewed)
        return

    duration = 2.0 if quick else 3.0
    # BENCH_load's lanes client mix, but with a moderate sweep: the
    # chaos-arm p99 sits on one part timeout + one retry, so the part
    # timeout wants to be tight, and the timeout floor is a *legit*
    # bulk dispatch under load — a spurious timeout abandons a part
    # that is still computing, and on a small host that duplicated work
    # cascades into worse tails than the hang it was guarding against.
    # Cheap parts keep every heal (real or spurious) cheap.
    n_interactive, n_bulk = 8, 3
    n_specs, n_points = 256, 24
    part_timeout_s = 0.008
    engine_probe_s = 0.25
    questions = _interactive_questions(workload, skewed, h1, h2)
    sweep = _bulk_sweep(n_specs, n_points, workload)
    oracles = _interactive_oracles(questions)
    sweep_oracle = whatif.workload_sweep(*sweep, h1,
                                         engine="grouped").totals
    # the grouped oracle itself is spot-checked against scalar cells
    specs, workloads = sweep
    for w_i, d_i in ((0, 0), (len(workloads) // 2, len(specs) // 2),
                     (len(workloads) - 1, len(specs) - 1)):
        cell = cost_workload(specs[d_i], workloads[w_i], h1)
        assert abs(sweep_oracle[w_i, d_i] - cell) <= 1e-9 * abs(cell)

    svc = DesignCalculatorService(
        [h1, h2], window_s=0.002, bulk_per_window=1,
        shard_part_timeout_s=part_timeout_s,
        engine_probe_s=engine_probe_s, worker_backoff_s=0.005)
    try:
        # warm: pre-trace the whole fused bucket ladder (window
        # compositions vary run to run), then a short drive to compile
        # the sweep shape and heat the service's own caches
        _compile_ladder([h1, h2], 512)
        _drive(svc, min(duration / 2, 1.5), n_interactive, n_bulk,
               questions, sweep, h1)
        # a rule-free plan forces the executor-routed timed path the
        # fault-free fast path skips: spawn + warm the pool's worker
        # threads NOW, or the chaos arm's first dispatch pays the cold
        # start, trips a spurious part timeout, and the abandoned work
        # wedges the executor into a timeout cascade for ~0.3s
        with FaultPlan(0, []).activate():
            for q in questions:
                _submit_interactive(svc, q).result(timeout=60)
            svc.submit_sweep(*sweep, h1).result(timeout=60)

        # -- arm A: fault-free — the seams must cost nothing ----------------
        traces_before = devicecost.trace_count()
        clean = _drive(svc, duration, n_interactive, n_bulk, questions,
                       sweep, h1)
        recompiles = devicecost.trace_count() - traces_before
        assert recompiles == 0, \
            f"fault-free chaos arm recompiled the fused scorer {recompiles}x"
        clean_resolved = _assert_resolution(clean, "fault-free")
        assert clean["typed_errors"] == 0 and \
            clean["shed_interactive"] == 0, \
            "fault-free arm saw errors or interactive sheds"
        _assert_parity(clean, oracles, sweep_oracle, "fault-free")

        # -- catastrophic one-shot probes (untimed) -------------------------
        restarts = _crash_probe(svc, questions)
        _degradation_probe(svc, questions, oracles, h1, engine_probe_s)

        # -- arm B: ~5% chaos -----------------------------------------------
        plan = _chaos_plan(hang_s=6 * part_timeout_s)
        with plan.activate():
            chaos = _drive(svc, duration, n_interactive, n_bulk,
                           questions, sweep, h1)
        assert plan.fires() > 0, "the chaos plan injected nothing"
        chaos_resolved = _assert_resolution(chaos, "chaos")
        _assert_parity(chaos, oracles, sweep_oracle, "chaos")
        stats = svc.stats()
    finally:
        svc.stop()

    clean_i = _percentiles(clean["interactive"])
    chaos_i = _percentiles(chaos["interactive"])
    p99_ratio = chaos_i["p99"] / max(clean_i["p99"], 1e-12)
    print(f"interactive p99: fault-free {clean_i['p99']:.1f} ms -> "
          f"chaos {chaos_i['p99']:.1f} ms ({p99_ratio:.2f}x, target <= "
          f"{TARGET_CHAOS_P99_RATIO:.0f}x); {plan.fires()} faults "
          f"injected, {chaos['answered']} answered, "
          f"{chaos['typed_errors']} typed errors")
    worst = sorted(chaos["interactive"])[-5:]
    print(f"healing: {stats['shard_timeouts']} timeouts, "
          f"{stats['shard_retries']} retries, "
          f"{stats['shard_rescored']} flat rescores, "
          f"{stats['abandoned_parts']} abandoned, "
          f"{stats['shard_nonfinite']} non-finite; worst interactive "
          + " ".join(f"{s * 1e3:.0f}ms" for s in worst))
    assert p99_ratio <= TARGET_CHAOS_P99_RATIO, (
        f"chaos p99 {chaos_i['p99']:.1f} ms blew past "
        f"{TARGET_CHAOS_P99_RATIO:.0f}x the fault-free "
        f"{clean_i['p99']:.1f} ms")

    rows = [{
        "bench": "chaos_mixed_load",
        "duration_s": duration,
        "clients_interactive": n_interactive,
        "clients_bulk": n_bulk,
        "sweep_cells": n_specs * n_points,
        "fault_rates": dict(FAULT_RATES),
        "faults_injected": plan.fires(),
        "fault_counts": plan.counts(),
        "faultfree_qps": (len(clean["interactive"]) + len(clean["bulk"]))
        / clean["wall_s"],
        "faultfree_interactive_p99_ms": clean_i["p99"],
        "faultfree_recompiles": recompiles,
        "faultfree_resolved": clean_resolved,
        "chaos_qps": (len(chaos["interactive"]) + len(chaos["bulk"]))
        / chaos["wall_s"],
        "chaos_interactive_p50_ms": chaos_i["p50"],
        "chaos_interactive_p99_ms": chaos_i["p99"],
        "chaos_p99_ratio": p99_ratio,
        "chaos_resolved": chaos_resolved,
        "chaos_answered": chaos["answered"],
        "chaos_typed_errors": chaos["typed_errors"],
        "shard_retries": stats["shard_retries"],
        "shard_timeouts": stats["shard_timeouts"],
        "abandoned_parts": stats["abandoned_parts"],
        "shard_rescored": stats["shard_rescored"],
        "device_quarantines": stats["device_quarantines"],
        "nonfinite_groups": stats["nonfinite_groups"],
        "fallback_flat": stats["fallback_flat"],
        "fallback_grouped": stats["fallback_grouped"],
        "engine_degraded": stats["engine_degraded"],
        "engine_recovered": stats["engine_recovered"],
        "worker_restarts": restarts,
    }]
    emit_trajectory("BENCH_chaos", "PR8 fault injection + self-healing",
                    rows, keys=list(rows[0].keys()))


if __name__ == "__main__":
    from benchmarks.common import apply_process_tuning
    apply_process_tuning()
    run()
