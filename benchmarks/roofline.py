"""§Roofline: the three-term roofline per (arch x shape x mesh) from the
dry-run artifacts, plus the Distributed Data Calculator's predicted terms
(the Fig. 6 predicted-vs-measured methodology transferred to TPU).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun); run the
sweep first for full coverage — cells not yet swept are listed as missing.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ROOT, emit

DRYRUN = os.path.join(ROOT, "experiments", "dryrun")


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as fh:
            record = json.load(fh)
        if record.get("variant"):
            continue  # §Perf hillclimb variants live in hillclimb.json
        cells.append(record)
    return cells


def run(quick: bool = False) -> None:
    cells = load_cells()
    rows, missing, pred_rows = [], 0, []
    for cell in cells:
        if "error" in cell:
            missing += 1
            continue
        if "skipped" in cell and cell["skipped"]:
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh"], "dominant": "SKIP"})
            continue
        rf = cell.get("roofline")
        if not rf:
            missing += 1
            continue
        rows.append({
            "arch": cell["arch"], "shape": cell["shape"],
            "mesh": cell["mesh"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "roofline_frac": rf["roofline_fraction"],
            "useful_ratio": rf["useful_flops_ratio"],
        })
        dc = cell.get("distcalc")
        if dc and cell["mesh"] == "single":
            step = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            pred_rows.append({
                "arch": cell["arch"], "shape": cell["shape"],
                "xla_step_bound_s": step,
                "distcalc_step_s": dc["step_seconds"],
                "ratio": dc["step_seconds"] / max(step, 1e-12),
                "both_pick": ("same" if dc["dominant"] == rf["dominant"]
                              else f'{dc["dominant"]}!={rf["dominant"]}')})
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    emit("roofline_table", rows)
    emit("distcalc_vs_xla", pred_rows)
    if missing:
        print(f"[roofline] {missing} cells missing/failed — "
              f"run PYTHONPATH=src python -m repro.launch.dryrun --all")


if __name__ == "__main__":
    run()
