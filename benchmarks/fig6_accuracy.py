"""Fig. 6: the Calculator's synthesized Get latency vs a real
implementation, per structure, as data grows.

The paper sweeps 1e5..1e7 entries with 1e2 uniform Gets on three machines;
this container is one machine and the python ground truths are slower than
C++, so we sweep 1e4..2e5 and report per-structure predicted vs measured
latency plus the cross-structure rank agreement — the paper's headline
claim ("accurately computes the latency of arbitrary designs, ranked
correctly") in reproducible form.
"""
from __future__ import annotations

import inspect

import numpy as np

from benchmarks.common import container_profile, emit
from repro.core import elements as el, structures as S, synthesis
from repro.core.synthesis import Workload

SIZES = (10_000, 50_000, 200_000)
N_QUERIES = 100

PAIRS = [
    ("array", S.Array),
    ("sorted_array", S.SortedArray),
    ("linked_list", S.LinkedList),
    ("range_partitioned_linked_list", S.RangePartitionedLinkedList),
    ("skip_list", S.SkipList),
    ("trie", S.Trie),
    ("hash_table", S.HashTable),
    ("btree", S.BPlusTree),
]


def run(quick: bool = False) -> None:
    sizes = SIZES[:2] if quick else SIZES
    hw = container_profile()
    rng = np.random.default_rng(7)
    rows = []
    for n in sizes:
        keys = rng.choice(np.arange(n * 4), size=n,
                          replace=False).astype(np.int64)
        values = rng.integers(0, 1 << 30, size=n).astype(np.int64)
        queries = keys[rng.integers(0, n, size=N_QUERIES)]
        for name, cls in PAIRS:
            structure = cls()
            measured = S.measure_workload(structure, keys, values,
                                          queries)["per_query_s"]
            make = el.ALL_PAPER_SPECS[name]
            sig = inspect.signature(make)
            spec = make(n) if "n_puts" in sig.parameters else make()
            predicted = synthesis.cost(
                "get", spec, Workload(n_entries=n, n_queries=N_QUERIES), hw)
            rows.append({
                "structure": name, "n": n,
                "measured_us": measured * 1e6,
                "predicted_us": predicted * 1e6,
                "ratio": predicted / max(measured, 1e-12)})
    # rank agreement per size
    for n in sizes:
        sub = [r for r in rows if r["n"] == n]
        meas = np.argsort(np.argsort([r["measured_us"] for r in sub]))
        pred = np.argsort(np.argsort([r["predicted_us"] for r in sub]))
        rho = float(np.corrcoef(meas, pred)[0, 1])
        rows.append({"structure": f"(rank-corr n={n})", "n": n,
                     "measured_us": 0.0, "predicted_us": 0.0, "ratio": rho})
    emit("fig6_accuracy", rows,
         ["structure", "n", "measured_us", "predicted_us", "ratio"])


if __name__ == "__main__":
    run()
