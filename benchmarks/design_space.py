"""§2 Equations 1-4: design-space cardinality accounting."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import design_space


def run(quick: bool = False) -> None:
    summary = design_space.summary()
    rows = [{"quantity": k, "log10_count": v} for k, v in summary.items()]
    emit("design_space", rows)


if __name__ == "__main__":
    run()
