"""Shared benchmark plumbing: profile cache, tables, JSON artifacts."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_DIR = os.path.join(ROOT, "experiments", "bench")
PROFILE_PATH = os.path.join(ROOT, "experiments", "profiles",
                            "container.json")


def container_profile(refresh: bool = False):
    """Train (or load the cached) Level-2 model profile for this machine."""
    from repro.core.hardware import HardwareProfile
    from repro.core.training import train_profile
    if os.path.exists(PROFILE_PATH) and not refresh:
        return HardwareProfile.load(PROFILE_PATH)
    profile = train_profile("HW-container", reps=48, max_size=1 << 20)
    profile.save(PROFILE_PATH)
    return profile


def _atomic_dump(obj, path: str) -> None:
    """Serialize to a sibling temp file, then ``os.replace`` over ``path``.

    A crash mid-``json.dump`` must never truncate an existing artifact —
    the trajectory files accumulate cross-PR history that a plain
    ``open(path, "w")`` would destroy on the next interrupted run."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as fh:
            json.dump(obj, fh, indent=1, default=str)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def emit(name: str, rows: Sequence[Dict], keys: Optional[List[str]] = None
         ) -> None:
    """Print an aligned table and persist rows under experiments/bench/."""
    os.makedirs(BENCH_DIR, exist_ok=True)
    _atomic_dump(list(rows), os.path.join(BENCH_DIR, f"{name}.json"))
    _print_table(name, rows, keys)


def emit_trajectory(name: str, label: str, rows: Sequence[Dict],
                    keys: Optional[List[str]] = None) -> None:
    """*Append* one labelled entry to experiments/bench/<name>.json.

    Unlike :func:`emit` (which overwrites), the trajectory file is a list
    of ``{"entry", "label", "date", "rows"}`` records that accumulates
    across PRs, so perf history survives re-runs.  A legacy bare-rows file
    (the pre-trajectory format) is migrated into entry 0.

    The rewrite is atomic (temp file + ``os.replace``); a corrupted
    history file — e.g. truncated by a crash on a pre-atomic version — is
    backed up beside itself and a fresh history is started instead of
    raising on every future append.
    """
    os.makedirs(BENCH_DIR, exist_ok=True)
    path = os.path.join(BENCH_DIR, f"{name}.json")
    history: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as fh:
                existing = json.load(fh)
            if not isinstance(existing, list):
                raise ValueError(f"expected a list, found "
                                 f"{type(existing).__name__}")
        except ValueError:          # json.JSONDecodeError subclasses this
            backup = f"{path}.corrupt-{time.strftime('%Y%m%d-%H%M%S')}"
            os.replace(path, backup)
            print(f"warning: {path} was corrupted; backed it up to "
                  f"{backup} and starting a fresh history")
            existing = []
        if existing and isinstance(existing[0], dict) and \
                "rows" not in existing[0]:
            history = [{"entry": 0, "label": "pre-trajectory",
                        "rows": existing}]
        else:
            history = existing
    history.append({"entry": len(history), "label": label,
                    "date": time.strftime("%Y-%m-%d %H:%M:%S"),
                    "rows": list(rows)})
    _atomic_dump(history, path)
    _print_table(f"{name} [entry {len(history) - 1}: {label}]", rows, keys)


def _print_table(name: str, rows: Sequence[Dict],
                 keys: Optional[List[str]] = None) -> None:
    if not rows:
        print(f"[{name}] (no rows)")
        return
    keys = keys or list(rows[0].keys())
    widths = {k: max(len(k), *(len(_fmt(r.get(k))) for r in rows))
              for k in keys}
    print(f"== {name} ==")
    print("  ".join(k.ljust(widths[k]) for k in keys))
    for row in rows:
        print("  ".join(_fmt(row.get(k)).ljust(widths[k]) for k in keys))
    print()


def _fmt(value) -> str:
    if isinstance(value, float):
        if value == 0:
            return "0"
        if abs(value) < 1e-3 or abs(value) >= 1e5:
            return f"{value:.3e}"
        return f"{value:.4g}"
    return str(value)


def timer():
    t0 = time.perf_counter()
    return lambda: time.perf_counter() - t0


#: guard so the tuning re-exec happens exactly once
_TUNED_ENV = "_REPRO_BENCH_TUNED"
_TCMALLOC = "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4"


def apply_process_tuning(n_devices: int = None) -> None:
    """Re-exec the current command under the standard serving-process
    tuning: tcmalloc preloaded (thread-friendly allocator for the
    multi-client load benchmarks) and ``XLA_FLAGS`` forcing one host
    device per core (``n_devices`` overrides; an explicit flag already
    in the environment always wins).  Both only take effect at process
    start — tcmalloc must be preloaded and XLA reads its flags when the
    backend initializes — hence the exec.  The device-count plumbing is
    shared with the pytest ``devices(n)`` marker via
    :mod:`repro.testing.devices`.  No-ops inside the tuned child, when
    already configured, or on platforms without tcmalloc."""
    from repro.testing.devices import forced_device_count, forced_device_env
    if os.environ.get(_TUNED_ENV) == "1":
        return
    env = dict(os.environ)
    env[_TUNED_ENV] = "1"
    changed = False
    if os.path.exists(_TCMALLOC) and "tcmalloc" not in env.get(
            "LD_PRELOAD", ""):
        env["LD_PRELOAD"] = (env.get("LD_PRELOAD", "") + " " +
                             _TCMALLOC).strip()
        changed = True
    if forced_device_count(env) is None:
        n = n_devices if n_devices is not None \
            else min(os.cpu_count() or 1, 48)
        env = forced_device_env(n, env)
        changed = True
    if not changed:
        return
    os.execve(sys.executable, [sys.executable, "-m",
                               main_module_name()] + sys.argv[1:], env)


def main_module_name() -> str:
    """The ``-m``-style name of the currently running benchmark module."""
    main = sys.modules.get("__main__")
    spec = getattr(main, "__spec__", None)
    if spec is not None and spec.name:
        return spec.name
    return "benchmarks.run"
