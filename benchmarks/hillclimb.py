"""§Perf hillclimb driver: run tagged dry-run variants for the three
selected cells and print the before/after roofline deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb [--only CELL]

Each variant is one lower+compile of the cell with one knob changed; the
baseline is the sweep's untagged cell file.  Results append to
experiments/dryrun/<cell>__<tag>.json and the comparison table prints at
the end (and lands in experiments/bench/hillclimb.json).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

from benchmarks.common import ROOT, emit

DRYRUN = os.path.join(ROOT, "experiments", "dryrun")

# (cell-id, arch, shape, [(tag, [flags...]), ...])
PLANS = [
    ("A-prefill-mem", "qwen1.5-32b", "prefill_32k", [
        ("noattn", ["--attn-impl", "skip"]),
        ("nofsdp", ["--no-fsdp"]),
        ("mesh32x8", ["--mesh-shape", "32x8"]),
        ("mesh32x8-noattn", ["--mesh-shape", "32x8",
                             "--attn-impl", "skip"]),
    ]),
    ("B-moe-coll", "granite-moe-1b-a400m", "train_4k", [
        ("noep", ["--no-ep"]),                      # it.1 (refuted)
        ("gc", ["--grad-compress"]),                # it.2: grad bytes /2
        ("gc-nofsdp", ["--grad-compress", "--no-fsdp"]),  # it.3: no gathers
        ("mesh32x8", ["--mesh-shape", "32x8"]),     # it.4: kv-head divis.
    ]),
    ("C-405b-train", "llama3-405b", "train_4k", [
        ("bf16mom", ["--moment-dtype", "bfloat16"]),
        ("bf16mom-gc", ["--moment-dtype", "bfloat16", "--grad-compress"]),
        ("bf16mom-gc-mb64", ["--moment-dtype", "bfloat16",
                             "--grad-compress", "--microbatch", "64"]),
        ("nosp", ["--no-sp"]),
        ("mesh32x8", ["--mesh-shape", "32x8",
                      "--moment-dtype", "bfloat16"]),  # kv=8 divides TP=8
    ]),
]


def run_variant(arch: str, shape: str, tag: str, flags) -> None:
    path = os.path.join(DRYRUN, f"{arch}__{shape}__single__{tag}.json")
    if os.path.exists(path):
        print(f"cached {arch} {shape} [{tag}]")
        return
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", "single", "--tag", tag] + list(flags)
    print("run:", " ".join(cmd), flush=True)
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=7200,
                          env=dict(os.environ))
    if proc.returncode != 0:
        print(proc.stderr[-3000:])
        raise RuntimeError(f"variant failed: {tag}")


def summarize() -> None:
    rows = []
    for cell_id, arch, shape, variants in PLANS:
        base_path = os.path.join(DRYRUN, f"{arch}__{shape}__single.json")
        entries = [("baseline", base_path)]
        entries += [(tag, os.path.join(
            DRYRUN, f"{arch}__{shape}__single__{tag}.json"))
            for tag, _ in variants]
        for tag, path in entries:
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            rf = r.get("roofline") or {}
            mem = r.get("full", {}).get("memory", {})
            rows.append({
                "cell": cell_id, "variant": tag,
                "Tc_s": rf.get("compute_s"), "Tm_s": rf.get("memory_s"),
                "Tcoll_s": rf.get("collective_s"),
                "dominant": rf.get("dominant"),
                "frac": rf.get("roofline_fraction"),
                "args_GB": (mem.get("argument_size_in_bytes") or 0) / 1e9,
                "temps_GB": (mem.get("temp_size_in_bytes") or 0) / 1e9,
            })
    emit("hillclimb", rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--summarize-only", action="store_true")
    args = ap.parse_args()
    if not args.summarize_only:
        for cell_id, arch, shape, variants in PLANS:
            if args.only and args.only != cell_id:
                continue
            for tag, flags in variants:
                run_variant(arch, shape, tag, flags)
    summarize()


if __name__ == "__main__":
    main()
