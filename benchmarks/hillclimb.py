"""Hillclimb drivers: design-space search (batched) + dry-run variants.

Two climbers meet here:

1. ``repro.core.autocomplete.design_hillclimb`` — local search over data
   structure designs (paper §4 territory): mutate fanouts / capacities /
   element choices and cost the whole neighbor frontier in ONE
   ``batchcost.cost_many`` call per step.  ``bench_climb``/``run()``
   benchmark it batched vs scalar (identical climb path,
   designs-costed-per-second reported; feeds BENCH_search.json).

2. The §Perf dry-run variant climber for the three selected cells:

    PYTHONPATH=src python -m benchmarks.hillclimb [--only CELL]

Each variant is one lower+compile of the cell with one knob changed; the
baseline is the sweep's untagged cell file.  Results append to
experiments/dryrun/<cell>__<tag>.json and the comparison table prints at
the end (and lands in experiments/bench/hillclimb.json).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, Optional

from benchmarks.common import ROOT, emit

DRYRUN = os.path.join(ROOT, "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Benchmarking the design-space hill climb (the climber itself lives in
# repro.core.autocomplete.design_hillclimb)
# ---------------------------------------------------------------------------
def bench_climb(workload, hw, mix: Optional[Dict[str, float]] = None,
                steps: int = 30) -> Dict:
    """Measure one climb through all three costing paths, cold caches each.

    Warms every path first (one-time jax compilations — the fused frontier
    buckets, the grouped shape buckets and the scalar shape-(1,) predicts —
    are process costs, not search costs), then times each path from cold
    synthesis caches.  Asserts the identical climb result.  The single
    measurement authority for the hillclimb rows of BENCH_search.json and
    hillclimb_design.
    """
    from repro.core import batchcost
    from repro.core.autocomplete import design_hillclimb

    design_hillclimb(workload, hw, mix, max_steps=steps)
    design_hillclimb(workload, hw, mix, max_steps=steps, engine="grouped")
    design_hillclimb(workload, hw, mix, max_steps=1, batched=False)
    batchcost.clear_caches()
    f = design_hillclimb(workload, hw, mix, max_steps=steps)
    batchcost.clear_caches()
    g = design_hillclimb(workload, hw, mix, max_steps=steps,
                         engine="grouped")
    batchcost.clear_caches()
    s = design_hillclimb(workload, hw, mix, max_steps=steps, batched=False)
    # cost parity is the hard invariant (grouped/scalar 1e-9, fused 1e-6 —
    # the engines' documented tolerances); structural identity is expected
    # but an argmin flip between exactly cost-tied neighbors is benign, so
    # note it rather than failing the whole benchmark run
    assert abs(g["cost_s"] - s["cost_s"]) <= \
        1e-9 * max(s["cost_s"], 1e-30), (g, s)
    assert abs(f["cost_s"] - s["cost_s"]) <= \
        1e-6 * max(s["cost_s"], 1e-30), (f, s)
    if (f["design"], f["fanouts"]) != (s["design"], s["fanouts"]):
        print(f"note: cost-tied climb results differ structurally: "
              f"{f['design']} vs {s['design']}")
    return {"design": f["design"], "cost_s": f["cost_s"],
            "designs_costed": f["designs_costed"],
            "fused_s": f["elapsed_s"], "grouped_s": g["elapsed_s"],
            "scalar_s": s["elapsed_s"],
            "fused_designs_per_s": f["designs_per_s"],
            "grouped_designs_per_s": g["designs_per_s"],
            "scalar_designs_per_s": s["designs_per_s"],
            "speedup_fused_vs_scalar":
                s["elapsed_s"] / max(f["elapsed_s"], 1e-12),
            "speedup_fused_vs_grouped":
                g["elapsed_s"] / max(f["elapsed_s"], 1e-12)}


def run(quick: bool = False) -> None:
    """Benchmark entry: climb three workloads batched vs scalar."""
    from repro.core.hardware import hw3
    from repro.core.synthesis import Workload

    hw = hw3()
    n = 100_000 if quick else 1_000_000
    # (the read/write mixed climb is already measured by BENCH_search's
    # hillclimb row — only the scenarios it does not cover run here)
    scenarios = [
        ("point-reads", Workload(n_entries=n), {"get": 100.0}),
        ("skewed-ranges", Workload(n_entries=n, zipf_alpha=1.2),
         {"get": 50.0, "range_get": 50.0}),
    ]
    steps = 5 if quick else 30
    rows = []
    for name, workload, mix in scenarios:
        row = bench_climb(workload, hw, mix, steps=steps)
        rows.append({"scenario": name, **{k: row[k] for k in (
            "design", "cost_s", "designs_costed", "fused_s", "grouped_s",
            "scalar_s", "speedup_fused_vs_scalar")}})
    emit("hillclimb_design", rows)

# (cell-id, arch, shape, [(tag, [flags...]), ...])
PLANS = [
    ("A-prefill-mem", "qwen1.5-32b", "prefill_32k", [
        ("noattn", ["--attn-impl", "skip"]),
        ("nofsdp", ["--no-fsdp"]),
        ("mesh32x8", ["--mesh-shape", "32x8"]),
        ("mesh32x8-noattn", ["--mesh-shape", "32x8",
                             "--attn-impl", "skip"]),
    ]),
    ("B-moe-coll", "granite-moe-1b-a400m", "train_4k", [
        ("noep", ["--no-ep"]),                      # it.1 (refuted)
        ("gc", ["--grad-compress"]),                # it.2: grad bytes /2
        ("gc-nofsdp", ["--grad-compress", "--no-fsdp"]),  # it.3: no gathers
        ("mesh32x8", ["--mesh-shape", "32x8"]),     # it.4: kv-head divis.
    ]),
    ("C-405b-train", "llama3-405b", "train_4k", [
        ("bf16mom", ["--moment-dtype", "bfloat16"]),
        ("bf16mom-gc", ["--moment-dtype", "bfloat16", "--grad-compress"]),
        ("bf16mom-gc-mb64", ["--moment-dtype", "bfloat16",
                             "--grad-compress", "--microbatch", "64"]),
        ("nosp", ["--no-sp"]),
        ("mesh32x8", ["--mesh-shape", "32x8",
                      "--moment-dtype", "bfloat16"]),  # kv=8 divides TP=8
    ]),
]


def run_variant(arch: str, shape: str, tag: str, flags) -> None:
    path = os.path.join(DRYRUN, f"{arch}__{shape}__single__{tag}.json")
    if os.path.exists(path):
        print(f"cached {arch} {shape} [{tag}]")
        return
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", "single", "--tag", tag] + list(flags)
    print("run:", " ".join(cmd), flush=True)
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=7200,
                          env=dict(os.environ))
    if proc.returncode != 0:
        print(proc.stderr[-3000:])
        raise RuntimeError(f"variant failed: {tag}")


def summarize() -> None:
    rows = []
    for cell_id, arch, shape, variants in PLANS:
        base_path = os.path.join(DRYRUN, f"{arch}__{shape}__single.json")
        entries = [("baseline", base_path)]
        entries += [(tag, os.path.join(
            DRYRUN, f"{arch}__{shape}__single__{tag}.json"))
            for tag, _ in variants]
        for tag, path in entries:
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            rf = r.get("roofline") or {}
            mem = r.get("full", {}).get("memory", {})
            rows.append({
                "cell": cell_id, "variant": tag,
                "Tc_s": rf.get("compute_s"), "Tm_s": rf.get("memory_s"),
                "Tcoll_s": rf.get("collective_s"),
                "dominant": rf.get("dominant"),
                "frac": rf.get("roofline_fraction"),
                "args_GB": (mem.get("argument_size_in_bytes") or 0) / 1e9,
                "temps_GB": (mem.get("temp_size_in_bytes") or 0) / 1e9,
            })
    emit("hillclimb", rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--summarize-only", action="store_true")
    args = ap.parse_args()
    if not args.summarize_only:
        for cell_id, arch, shape, variants in PLANS:
            if args.only and args.only != cell_id:
                continue
            for tag, flags in variants:
                run_variant(arch, shape, tag, flags)
    summarize()


if __name__ == "__main__":
    main()
