"""BENCH_serving: questions/sec through the concurrent what-if server.

Measures a >=64-question *mixed* batch (design / hardware / workload
what-ifs plus a few auto-completions) through two serving regimes:

1. **serial** — the PR-3 interactive baseline: a one-call-per-question
   loop over the :mod:`repro.core.whatif` functions (each question is its
   own fused scoring dispatch, 1-2 per question);
2. **coalesced** — the same questions submitted concurrently to a
   :class:`repro.serving.DesignCalculatorService`, whose micro-batching
   loop splices the whole window into ONE fused scoring call per distinct
   hardware profile.

Both regimes answer from warm packing caches (the steady-state design-
session regime), so the measured gap is pure dispatch amortization — the
thing the serving engine exists to remove.  Three invariants are asserted
before any number is persisted:

* every coalesced answer matches the serial answer AND the scalar
  ``cost_workload`` oracle to the fused engine's documented 1e-6;
* a hardware-swap burst against a freshly built profile triggers **zero**
  recompilations of the fused scorer (``devicecost.trace_count``);
* coalesced serving clears ``TARGET_SPEEDUP`` x the serial loop.

Each run appends one labelled entry to
experiments/bench/BENCH_serving.json (same cross-PR trajectory format as
BENCH_search).  ``run(smoke=True)`` executes the parity + recompile
checks at a tiny size without touching the trajectory or asserting perf
bars.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from benchmarks.common import emit_trajectory

#: acceptance bar: coalesced questions/sec vs the serial one-call loop
TARGET_SPEEDUP = 3.0


def _mixed_questions(workload, skewed, grown, h1, h2, h3, n_questions: int,
                     max_depth: int) -> List[Tuple]:
    """A deterministic mixed question list: (kind, args...) tuples."""
    from repro.core import elements as el, whatif
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list(),
             el.spec_btree(fanout=40), el.spec_trie()]
    variants = [whatif.add_bloom_filters(el.spec_hash_table()),
                el.spec_csb_tree(), el.spec_btree(page=512)]
    qs: List[Tuple] = []
    i = 0
    while len(qs) < n_questions:
        spec = specs[i % len(specs)]
        # the session mix of the motivation: what-if heavy, with an
        # auto-completion every 8th question
        kind = (i % 8) % 3 if i % 8 != 7 else 3
        if kind == 0:
            qs.append(("design", spec, variants[i % len(variants)],
                       workload, h1))
        elif kind == 1:
            qs.append(("hardware", spec, workload, h1, (h2, h3)[i % 2]))
        elif kind == 2:
            qs.append(("workload", spec, workload,
                       (skewed, grown)[i % 2], (h1, h2)[i % 2]))
        else:
            qs.append(("complete", (spec.chain[0],), workload,
                       (h1, h3)[i % 2], max_depth))
        i += 1
    return qs


def _ask_serial(q: Tuple):
    """One question through the serial whatif/autocomplete API."""
    from repro.core import autocomplete, whatif
    kind = q[0]
    if kind == "design":
        return whatif.what_if_design(q[1], q[2], q[3], q[4])
    if kind == "hardware":
        return whatif.what_if_hardware(q[1], q[2], q[3], q[4])
    if kind == "workload":
        return whatif.what_if_workload(q[1], q[2], q[3], q[4])
    return autocomplete.complete_design(q[1], q[2], q[3],
                                        max_depth=q[4])


def _submit(service, q: Tuple):
    kind = q[0]
    if kind == "design":
        return service.submit_design(q[1], q[2], q[3], q[4])
    if kind == "hardware":
        return service.submit_hardware(q[1], q[2], q[3], q[4])
    if kind == "workload":
        return service.submit_workload(q[1], q[2], q[3], q[4])
    return service.submit_complete(q[1], q[2], q[3], max_depth=q[4])


def _ask_coalesced(service, questions: List[Tuple]) -> List:
    futures = [_submit(service, q) for q in questions]
    return [f.result(timeout=120.0) for f in futures]


def _scalar_oracle(q: Tuple):
    """The per-record scalar answer for one what-if question (None for
    auto-completions — their parity bar is the serial fused answer)."""
    from repro.core import whatif
    kind = q[0]
    if kind == "design":
        return whatif.what_if_design(q[1], q[2], q[3], q[4],
                                     engine="scalar")
    if kind == "hardware":
        return whatif.what_if_hardware(q[1], q[2], q[3], q[4],
                                       engine="scalar")
    if kind == "workload":
        return whatif.what_if_workload(q[1], q[2], q[3], q[4],
                                       engine="scalar")
    return None


def _check_parity(questions, coalesced, serial, oracles) -> None:
    from repro.core.autocomplete import SearchResult
    for q, got, ref, oracle in zip(questions, coalesced, serial, oracles):
        if isinstance(got, SearchResult):
            # same fused engine either way; only the concat grouping of
            # the scoring call differs, so allow its float32 tolerance
            assert abs(got.cost_seconds - ref.cost_seconds) <= \
                1e-6 * abs(ref.cost_seconds), q[0]
            assert got.explored == ref.explored
            continue
        for attr in ("baseline_seconds", "variant_seconds"):
            c, s, o = (getattr(x, attr) for x in (got, ref, oracle))
            assert abs(c - o) <= 1e-6 * abs(o), (q[0], attr, c, o)
            assert abs(s - o) <= 1e-6 * abs(o), (q[0], attr, s, o)
        assert got.beneficial == oracle.beneficial == ref.beneficial


def _best_of(fn: Callable[[], object], reps: int) -> float:
    best = None
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        elapsed = time.perf_counter() - t0
        best = elapsed if best is None else min(best, elapsed)
    return best


def run(quick: bool = False, smoke: bool = False) -> None:
    from benchmarks.common import _print_table
    from repro.core import batchcost, devicecost
    from repro.core.hardware import analytical_profile, hw1, hw2, hw3
    from repro.core.synthesis import Workload
    from repro.serving import DesignCalculatorService

    quick = quick or smoke
    n_questions = 16 if smoke else (64 if quick else 96)
    max_depth = 2
    workload = Workload(n_entries=100_000 if quick else 1_000_000,
                        n_queries=100)
    skewed = dataclasses.replace(workload, zipf_alpha=1.5)
    grown = dataclasses.replace(workload,
                                n_entries=workload.n_entries * 4)
    h1, h2, h3 = hw1(), hw2(), hw3()
    questions = _mixed_questions(workload, skewed, grown, h1, h2, h3,
                                 n_questions, max_depth)

    batchcost.clear_caches()
    # warm the serial path: compiles the per-question fused shapes and
    # fills the segment/frontier caches (the steady-state session regime
    # both loops are measured in)
    serial = [_ask_serial(q) for q in questions]
    oracles = [_scalar_oracle(q) for q in questions]

    # max_batch == n_questions with a generous window: a burst submitted
    # together always lands in exactly one deterministic batch
    service = DesignCalculatorService(
        [h1, h2, h3], window_s=0.25, max_batch=n_questions)
    try:
        coalesced = _ask_coalesced(service, questions)   # warm + parity
        _check_parity(questions, coalesced, serial, oracles)

        # zero recompiles across hardware-swap requests: a pure hardware
        # burst is warmed once (compiling its h1/h3 group shapes), then
        # re-asked against a freshly built profile — identical frontier
        # shapes, new parameter banks, so every scoring call must reuse an
        # already-compiled executable
        specs = sorted({q[1] for q in questions if q[0] == "hardware"},
                       key=lambda s: s.describe())
        hw_burst = [("hardware", specs[i % len(specs)], workload, h1, h3)
                    for i in range(n_questions)]
        _ask_coalesced(service, hw_burst)                # compile burst shape
        hw_new = analytical_profile("HW-new", mem_ns=60.0,
                                    bw_bytes_per_s=80e9,
                                    l3_bytes=64 << 20)
        service.register_hardware(hw_new)                # banks built here
        swapped = [(kind, spec, wl, base, hw_new)
                   for kind, spec, wl, base, _ in hw_burst]
        traces_before = devicecost.trace_count()
        _ask_coalesced(service, swapped)
        recompiles = devicecost.trace_count() - traces_before
        assert recompiles == 0, \
            f"hardware swap recompiled the fused scorer {recompiles}x"

        reps = 2 if smoke else 5
        serial_s = _best_of(lambda: [_ask_serial(q) for q in questions],
                            reps)
        coalesced_s = _best_of(
            lambda: _ask_coalesced(service, questions), reps)
        stats = service.stats()
    finally:
        service.stop()

    speedup = serial_s / max(coalesced_s, 1e-12)
    rows = [{
        "bench": "whatif_serving",
        "questions": n_questions,
        "serial_s": serial_s,
        "coalesced_s": coalesced_s,
        "serial_qps": n_questions / max(serial_s, 1e-12),
        "coalesced_qps": n_questions / max(coalesced_s, 1e-12),
        "speedup_coalesced_vs_serial": speedup,
        "hw_swap_recompiles": recompiles,
        "score_calls": stats["score_calls"],
        "batches": stats["batches"],
        "questions_served": stats["answered"],
    }]
    keys = list(rows[0].keys())
    if smoke:
        _print_table("BENCH_serving [smoke — not persisted]", rows, keys)
        print("serving parity + recompile checks passed")
        return
    print(f"coalesced serving vs serial loop: {speedup:.1f}x "
          f"(target >= {TARGET_SPEEDUP:.0f}x) on {n_questions} questions")
    assert speedup >= TARGET_SPEEDUP, \
        "coalesced what-if serving regressed below the acceptance bar"
    emit_trajectory("BENCH_serving",
                    "PR4 concurrent what-if serving engine", rows,
                    keys=keys)


if __name__ == "__main__":
    run()
