"""Fig. 8: cache-conscious designs (CSB+ vs B+) across data sizes, and
workload skew (Zipf alpha sweep) — predicted vs measured."""
from __future__ import annotations

import numpy as np

from benchmarks.common import container_profile, emit
from repro.core import elements as el, structures as S, synthesis
from repro.core.synthesis import Workload

ALPHAS = (0.0, 0.5, 1.0, 1.5, 2.0)


def _zipf_queries(keys: np.ndarray, n_queries: int, alpha: float,
                  rng) -> np.ndarray:
    if alpha <= 0:
        return keys[rng.integers(0, len(keys), n_queries)]
    ranks = np.arange(1, len(keys) + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(keys, size=n_queries, p=p)


def run(quick: bool = False) -> None:
    hw = container_profile()
    rng = np.random.default_rng(3)

    # (a) CSB+ vs B+ across sizes
    rows = []
    sizes = (10_000, 50_000) if quick else (10_000, 100_000, 400_000)
    for n in sizes:
        keys = rng.permutation(n * 2)[:n].astype(np.int64)
        values = keys.copy()
        queries = keys[rng.integers(0, n, 100)]
        for name, cls, spec in (
                ("btree", S.BPlusTree, el.spec_btree()),
                ("csb_tree", S.CSBTree, el.spec_csb_tree())):
            measured = S.measure_workload(cls(), keys, values,
                                          queries)["per_query_s"]
            predicted = synthesis.cost("get", spec, Workload(n_entries=n),
                                       hw)
            rows.append({"structure": name, "n": n,
                         "measured_us": measured * 1e6,
                         "predicted_us": predicted * 1e6})
    emit("fig8a_cache_conscious", rows)

    # (b) skew sweep: predicted latency must fall with alpha, faster for B+
    rows = []
    n = 50_000 if quick else 200_000
    keys = rng.permutation(n * 2)[:n].astype(np.int64)
    values = keys.copy()
    for name, cls, spec in (
            ("btree", S.BPlusTree, el.spec_btree()),
            ("csb_tree", S.CSBTree, el.spec_csb_tree())):
        structure = cls()
        structure.bulk_load(keys, values)
        for alpha in ALPHAS:
            queries = _zipf_queries(np.sort(keys), 200, alpha, rng)
            import time
            t0 = time.perf_counter()
            for q in queries:
                structure.get(int(q))
            measured = (time.perf_counter() - t0) / len(queries)
            predicted = synthesis.cost(
                "get", spec, Workload(n_entries=n, n_queries=200,
                                      zipf_alpha=alpha), hw)
            rows.append({"structure": name, "alpha": alpha,
                         "measured_us": measured * 1e6,
                         "predicted_us": predicted * 1e6})
    emit("fig8b_skew", rows)


if __name__ == "__main__":
    run()
