"""Fig. 8: cache-conscious designs (CSB+ vs B+) across data sizes, and
workload skew (Zipf alpha sweep) — predicted vs measured.

The skew predictions run through the PR-5 workload-sweep engine
(:func:`repro.core.batchcost.cost_sweep`): the whole (designs x alphas)
grid is one fused scoring call, checked against the scalar
``synthesis.cost`` oracle cell by cell."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import container_profile, emit
from repro.core import batchcost, elements as el, structures as S, synthesis
from repro.core.synthesis import Workload

ALPHAS = (0.0, 0.5, 1.0, 1.5, 2.0)


def _zipf_queries(keys: np.ndarray, n_queries: int, alpha: float,
                  rng) -> np.ndarray:
    if alpha <= 0:
        return keys[rng.integers(0, len(keys), n_queries)]
    ranks = np.arange(1, len(keys) + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(keys, size=n_queries, p=p)


def run(quick: bool = False) -> None:
    hw = container_profile()
    rng = np.random.default_rng(3)

    # (a) CSB+ vs B+ across sizes
    rows = []
    sizes = (10_000, 50_000) if quick else (10_000, 100_000, 400_000)
    for n in sizes:
        keys = rng.permutation(n * 2)[:n].astype(np.int64)
        values = keys.copy()
        queries = keys[rng.integers(0, n, 100)]
        for name, cls, spec in (
                ("btree", S.BPlusTree, el.spec_btree()),
                ("csb_tree", S.CSBTree, el.spec_csb_tree())):
            measured = S.measure_workload(cls(), keys, values,
                                          queries)["per_query_s"]
            predicted = synthesis.cost("get", spec, Workload(n_entries=n),
                                       hw)
            rows.append({"structure": name, "n": n,
                         "measured_us": measured * 1e6,
                         "predicted_us": predicted * 1e6})
    emit("fig8a_cache_conscious", rows)

    # (b) skew sweep: predicted latency must fall with alpha, faster for
    # B+.  The whole (designs x alphas) prediction grid is ONE fused
    # workload-sweep call; the scalar expert system stays the per-cell
    # oracle.
    rows = []
    n = 50_000 if quick else 200_000
    keys = rng.permutation(n * 2)[:n].astype(np.int64)
    values = keys.copy()
    designs = (("btree", S.BPlusTree, el.spec_btree()),
               ("csb_tree", S.CSBTree, el.spec_csb_tree()))
    base = Workload(n_entries=n, n_queries=200)
    workloads = [dataclasses.replace(base, zipf_alpha=alpha)
                 for alpha in ALPHAS]
    grid = batchcost.cost_sweep([spec for _, _, spec in designs],
                                workloads, hw, {"get": 1.0})
    oracle = np.asarray(
        [[synthesis.cost("get", spec, w, hw)
          for _, _, spec in designs] for w in workloads])
    np.testing.assert_allclose(grid, oracle, rtol=1e-6)
    for d, (name, cls, spec) in enumerate(designs):
        structure = cls()
        structure.bulk_load(keys, values)
        for a, alpha in enumerate(ALPHAS):
            queries = _zipf_queries(np.sort(keys), 200, alpha, rng)
            import time
            t0 = time.perf_counter()
            for q in queries:
                structure.get(int(q))
            measured = (time.perf_counter() - t0) / len(queries)
            rows.append({"structure": name, "alpha": alpha,
                         "measured_us": measured * 1e6,
                         "predicted_us": float(grid[a, d]) * 1e6})
    emit("fig8b_skew", rows)


if __name__ == "__main__":
    run()
