"""BENCH_search: designs-costed-per-second across costing engines (perf CI).

Measures three searches through every costing path — the scalar per-design
``cost_workload`` loop, the PR-1 grouped ``cost_many`` engine, and the PR-2
fused device-resident engine (:mod:`repro.core.devicecost`):

1. fig9-style auto-completion search (cold synthesis caches per run);
2. the design hill climb (cold caches per run);
3. steady-state scoring of a >=4096-design frontier — warm caches, the
   what-if-serving regime — against a verbatim reconstruction of the PR-1
   ``cost_many`` as the fixed baseline, so the recorded speedup stays
   comparable even as the in-tree grouped engine keeps improving.

Each run *appends* one labelled entry to
experiments/bench/BENCH_search.json (a trajectory accumulating across PRs
— the PR-1 rows are migrated to entry 0), so future PRs can track search
throughput against both PR 1 and this PR.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit_trajectory, timer
from benchmarks.hillclimb import bench_climb

#: the tentpole acceptance bar: fused frontier scoring vs PR-1 cost_many
TARGET_SPEEDUP = 3.0


def _pr1_cost_many(specs, workload, hw, mix) -> np.ndarray:
    """The PR-1 ``cost_many`` (commit fcf873f), reconstructed verbatim:
    per-call python assembly + one grouped predict per Level-2 model.
    Kept here as the frozen baseline for the trajectory speedup."""
    from repro.core.batchcost import (_MODEL_NAMES, _predict_padded,
                                      compiled_operation)

    mix = mix or {"get": float(workload.n_queries)}
    n = len(specs)
    ids_parts, sizes_parts, weight_parts, seg_parts = [], [], [], []
    for i, spec in enumerate(specs):
        for op, op_weight in mix.items():
            comp = compiled_operation(op, spec, workload)
            ids_parts.append(comp.model_ids)
            sizes_parts.append(comp.sizes)
            weight_parts.append(comp.counts * float(op_weight))
            seg_parts.append(np.full(comp.n_records, i, dtype=np.int64))
    ids = np.concatenate(ids_parts)
    sizes = np.concatenate(sizes_parts)
    weights = np.concatenate(weight_parts)
    segments = np.concatenate(seg_parts)
    totals = np.zeros(n, dtype=np.float64)
    for mid in np.unique(ids):
        mask = ids == mid
        y = _predict_padded(hw.model(_MODEL_NAMES[mid]), sizes[mask])
        totals += np.bincount(segments[mask], weights=weights[mask] * y,
                              minlength=n)
    return totals


def _steady_state(fn, reps: int = 7) -> float:
    """Best-of-reps wall time with the first (cold) call excluded."""
    fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _bench_frontier_scoring(workload, hw, mix, min_designs: int) -> Dict:
    """Steady-state frontier scoring: fused one-jitted-call engine vs the
    PR-1 cost_many baseline on an identical >=``min_designs`` frontier."""
    from repro.core import batchcost
    from repro.core.autocomplete import (default_candidates,
                                         default_terminals,
                                         enumerate_completions)

    frontier = enumerate_completions((), default_candidates(),
                                     default_terminals(), 4, "bench")
    while len(frontier) < min_designs:     # tile up to the design floor
        frontier = frontier + frontier
    n = len(frontier)

    fused = batchcost.cost_many(frontier, workload, hw, mix)
    pr1 = _pr1_cost_many(frontier, workload, hw, mix)
    np.testing.assert_allclose(fused, pr1, rtol=1e-6)
    assert int(np.argmin(fused)) == int(np.argmin(pr1))

    packed = batchcost.pack_frontier(frontier, workload, mix)
    pr1_s = _steady_state(
        lambda: _pr1_cost_many(frontier, workload, hw, mix))
    grouped_s = _steady_state(
        lambda: batchcost.cost_many(frontier, workload, hw, mix,
                                    engine="grouped"))
    fused_s = _steady_state(
        lambda: batchcost.cost_many(frontier, workload, hw, mix))
    fused_score_s = _steady_state(lambda: packed.score(hw))
    return {
        "search": "frontier_scoring",
        "design": frontier[int(np.argmin(fused))].describe(),
        "designs": n,
        "records": len(packed.ids),
        "scalar_s": None,
        "pr1_cost_many_s": pr1_s,
        "grouped_s": grouped_s,
        "fused_s": fused_s,
        "fused_score_s": fused_score_s,
        "pr1_designs_per_s": n / max(pr1_s, 1e-12),
        "fused_designs_per_s": n / max(fused_s, 1e-12),
        "fused_score_designs_per_s": n / max(fused_score_s, 1e-12),
        "speedup_fused_vs_pr1": pr1_s / max(fused_s, 1e-12),
        "speedup_fused_scoring_vs_pr1": pr1_s / max(fused_score_s, 1e-12),
    }


def _bench_complete_design(workload, hw, mix, max_depth: int) -> Dict:
    from repro.core import batchcost
    from repro.core.autocomplete import complete_design

    # Warm every path at full depth: XLA compilation of the per-bucket /
    # fused frontier shapes and of the scalar shape-(1,) predict path are
    # one-time process costs, not search costs.  Each timed run then
    # starts from cold synthesis/compile memos (the jax executable cache
    # is process-level and survives; our lru caches don't).
    complete_design((), workload, hw, mix=mix, max_depth=max_depth)
    complete_design((), workload, hw, mix=mix, max_depth=max_depth,
                    engine="grouped")
    complete_design((), workload, hw, mix=mix, max_depth=1, batched=False)
    results, times = {}, {}
    for label, kwargs in (("fused", {}), ("grouped", {"engine": "grouped"}),
                          ("scalar", {"batched": False})):
        # best of 3 cold-cache runs: single cold runs carry tens of ms of
        # allocator/OS noise, swamping the engine difference
        reps = 1 if label == "scalar" else 3
        best = None
        for _ in range(reps):
            batchcost.clear_caches()
            t = timer()
            results[label] = complete_design((), workload, hw, mix=mix,
                                             max_depth=max_depth, **kwargs)
            elapsed = t()
            best = elapsed if best is None else min(best, elapsed)
        times[label] = best
    # cost parity is the hard invariant; an argmin flip between exactly
    # cost-tied candidates would be benign (note it, don't fail the run)
    assert abs(results["grouped"].cost_seconds -
               results["scalar"].cost_seconds) <= \
        1e-9 * results["scalar"].cost_seconds
    assert abs(results["fused"].cost_seconds -
               results["scalar"].cost_seconds) <= \
        1e-6 * results["scalar"].cost_seconds
    if results["fused"].spec.describe() != results["scalar"].spec.describe():
        print(f"note: cost-tied search results differ structurally: "
              f"{results['fused'].spec.describe()} vs "
              f"{results['scalar'].spec.describe()}")
    explored = results["fused"].explored
    return {
        "search": "complete_design",
        "design": results["fused"].spec.describe(),
        "designs": explored,
        "scalar_s": times["scalar"],
        "grouped_s": times["grouped"],
        "fused_s": times["fused"],
        "scalar_designs_per_s": explored / max(times["scalar"], 1e-12),
        "fused_designs_per_s": explored / max(times["fused"], 1e-12),
        "speedup_fused_vs_pr1": times["grouped"] / max(times["fused"],
                                                       1e-12),
        "speedup_fused_vs_scalar": times["scalar"] / max(times["fused"],
                                                         1e-12),
    }


def _bench_hillclimb(workload, hw, mix, steps: int) -> Dict:
    row = bench_climb(workload, hw, mix, steps=steps)
    return {
        "search": "hillclimb",
        "design": row["design"],
        "designs": row["designs_costed"],
        "scalar_s": row["scalar_s"],
        "grouped_s": row["grouped_s"],
        "fused_s": row["fused_s"],
        "scalar_designs_per_s": row["scalar_designs_per_s"],
        "fused_designs_per_s": row["fused_designs_per_s"],
        "speedup_fused_vs_pr1": row["speedup_fused_vs_grouped"],
        "speedup_fused_vs_scalar": row["speedup_fused_vs_scalar"],
    }


def run(quick: bool = False) -> None:
    from repro.core import batchcost
    from repro.core.hardware import hw3
    from repro.core.synthesis import Workload

    hw = hw3()
    n = 100_000 if quick else 1_000_000
    workload = Workload(n_entries=n, n_queries=100)
    mix = {"get": 80.0, "update": 20.0}

    batchcost.clear_caches()   # measure from cold synthesis caches
    rows: List[Dict] = [
        _bench_complete_design(workload, hw, mix,
                               max_depth=2 if quick else 3),
        _bench_hillclimb(workload, hw, mix, steps=5 if quick else 30),
        _bench_frontier_scoring(workload, hw, mix,
                                min_designs=1024 if quick else 4096),
    ]
    emit_trajectory(
        "BENCH_search", "PR2 fused device-resident frontier scoring", rows,
        keys=["search", "designs", "scalar_s", "grouped_s", "fused_s",
              "fused_score_s", "fused_designs_per_s",
              "speedup_fused_vs_pr1", "design"])
    scoring = rows[-1]
    print(f"fused scoring vs PR-1 cost_many: "
          f"{scoring['speedup_fused_scoring_vs_pr1']:.1f}x "
          f"(target >= {TARGET_SPEEDUP:.0f}x) on "
          f"{scoring['designs']} designs")
    assert scoring["speedup_fused_scoring_vs_pr1"] >= TARGET_SPEEDUP, \
        "fused frontier scoring regressed below the PR-2 acceptance bar"


if __name__ == "__main__":
    run()
