"""BENCH_search: designs-costed-per-second across costing engines (perf CI).

Measures five searches through every costing path — the scalar per-design
``cost_workload`` loop, the PR-1 grouped ``cost_many`` engine, the PR-2
fused device-resident engine (:mod:`repro.core.devicecost`), the PR-3
template-vectorized packer (:mod:`repro.core.templatecost`), and the PR-5
workload-sweep engine (:func:`repro.core.batchcost.cost_sweep`):

1. fig9-style auto-completion search, cold caches per run *and*
   steady-state (warm enumeration/segment/frontier memos — the what-if
   serving regime), against a verbatim reconstruction of the PR-2
   per-design packing loop as the frozen end-to-end baseline;
2. the design hill climb (cold caches per run);
3. frontier *packing* throughput (designs/sec through ``pack_frontier``,
   construction only — no scoring), so the construction/scoring split of
   the Amdahl gap stays visible across future PRs;
4. steady-state scoring of a >=4096-design frontier against a verbatim
   reconstruction of the PR-1 ``cost_many`` as the fixed baseline;
5. an 8-workload x >=512-design **sweep** (read/write ratio + skew axis)
   through one fused ``cost_sweep`` call vs the pre-PR-5 capability —
   looping ``cost_many`` once per workload — with every cell checked
   against both engines' grids and the scalar oracle, and a
   zero-recompile probe across repeat sweeps and a hardware swap.

Each run *appends* one labelled entry to
experiments/bench/BENCH_search.json (a trajectory accumulating across PRs
— the PR-1 rows are migrated to entry 0), so future PRs can track search
throughput against PR 1, PR 2 and this PR.  ``run(smoke=True)`` executes
the same parity checks at tiny sizes without appending to the trajectory
or asserting perf bars (the ``benchmarks/run.py --smoke`` fast path).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit_trajectory, timer
from benchmarks.hillclimb import bench_climb

#: the PR-2 acceptance bar: fused frontier scoring vs PR-1 cost_many
TARGET_SPEEDUP = 3.0
#: the PR-3 acceptance bar: end-to-end auto-completion (cold and steady
#: state) and frontier packing vs the reconstructed PR-2 pipeline
E2E_TARGET_SPEEDUP = 3.0
#: the PR-5 acceptance bar: steady-state 8-workload sweep vs looping
#: cost_many per workload (measured 3.5-4.1x when the host has cores
#: for XLA to fan the one big fused call out to).  On a single-core
#: host the fused call loses exactly that intra-op parallelism edge
#: over 8 small dispatches and the *unchanged* seed tree measures
#: ~2.0x, so the floor adapts rather than failing every 1-core run.
SWEEP_TARGET_SPEEDUP = 3.0 if (os.cpu_count() or 1) >= 2 else 1.8


def _pr1_cost_many(specs, workload, hw, mix) -> np.ndarray:
    """The PR-1 ``cost_many`` (commit fcf873f), reconstructed verbatim:
    per-call python assembly + one grouped predict per Level-2 model.
    Kept here as the frozen baseline for the trajectory speedup."""
    from repro.core.batchcost import (_MODEL_NAMES, _predict_padded,
                                      compiled_operation)

    mix = mix or {"get": float(workload.n_queries)}
    n = len(specs)
    ids_parts, sizes_parts, weight_parts, seg_parts = [], [], [], []
    for i, spec in enumerate(specs):
        for op, op_weight in mix.items():
            comp = compiled_operation(op, spec, workload)
            ids_parts.append(comp.model_ids)
            sizes_parts.append(comp.sizes)
            weight_parts.append(comp.counts * float(op_weight))
            seg_parts.append(np.full(comp.n_records, i, dtype=np.int64))
    ids = np.concatenate(ids_parts)
    sizes = np.concatenate(sizes_parts)
    weights = np.concatenate(weight_parts)
    segments = np.concatenate(seg_parts)
    totals = np.zeros(n, dtype=np.float64)
    for mid in np.unique(ids):
        mask = ids == mid
        y = _predict_padded(hw.model(_MODEL_NAMES[mid]), sizes[mask])
        totals += np.bincount(segments[mask], weights=weights[mask] * y,
                              minlength=n)
    return totals


def _steady_state(fn, reps: int = 7) -> float:
    """Best-of-reps wall time with the first (cold) call excluded."""
    fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


# ---------------------------------------------------------------------------
# PR-2 frontier construction (commit be0802c), reconstructed verbatim: the
# per-design scalar-synthesis packing loop behind the old pack_frontier.
# Frozen here as the end-to-end baseline for the PR-3 trajectory speedups.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=65536)
def _pr2_packed_spec(chain, workload, mix_items):
    from repro.core import devicecost
    from repro.core.batchcost import _compiled_operation
    parts = [_compiled_operation(op, chain, workload) for op, _ in mix_items]
    n = sum(c.n_records for c in parts)
    padded = -n % devicecost.TILE
    real_ids = np.concatenate([c.model_ids for c in parts]) if parts else \
        np.zeros(0, np.int32)
    pad_id = real_ids[0] if n else 0
    ids = np.concatenate([real_ids, np.full(padded, pad_id, np.int32)])
    sizes = np.concatenate([c.sizes for c in parts] +
                           [np.ones(padded, np.float64)])
    weights = np.concatenate([c.counts * float(w)
                              for c, (_, w) in zip(parts, mix_items)] +
                             [np.zeros(padded, np.float64)])
    return ids, sizes, weights


def _pr2_pack_frontier(specs, workload, mix):
    from repro.core import devicecost
    from repro.core.batchcost import PackedFrontier
    mix = mix or {"get": float(workload.n_queries)}
    mix_items = tuple(mix.items())
    per_spec = [_pr2_packed_spec(spec.chain, workload, mix_items)
                for spec in specs]
    tile_segments = np.repeat(
        np.arange(len(per_spec), dtype=np.int64),
        [len(ids) // devicecost.TILE for ids, _, _ in per_spec])
    return PackedFrontier(
        np.concatenate([p[0] for p in per_spec]),
        np.concatenate([p[1] for p in per_spec]),
        np.concatenate([p[2] for p in per_spec]),
        tile_segments, len(per_spec))


def _pr2_clear_caches() -> None:
    from repro.core import batchcost
    batchcost.clear_caches()
    _pr2_packed_spec.cache_clear()


def _pr2_complete_design(workload, hw, mix, max_depth):
    """End-to-end PR-2 auto-completion: fresh enumeration (PR 2 had no
    enumeration memo) + per-design packing + fused scoring."""
    from repro.core.autocomplete import (default_candidates,
                                        default_terminals,
                                        enumerate_completions)
    frontier = enumerate_completions((), default_candidates(),
                                     default_terminals(), max_depth, "auto")
    totals = _pr2_pack_frontier(frontier, workload, mix).score(hw)
    best = int(np.argmin(totals))
    return frontier[best], float(totals[best]), len(frontier)


def _bench_frontier_scoring(workload, hw, mix, min_designs: int) -> Dict:
    """Steady-state frontier scoring: fused one-jitted-call engine vs the
    PR-1 cost_many baseline on an identical >=``min_designs`` frontier."""
    from repro.core import batchcost
    from repro.core.autocomplete import (default_candidates,
                                         default_terminals,
                                         enumerate_completions)

    frontier = enumerate_completions((), default_candidates(),
                                     default_terminals(), 4, "bench")
    while len(frontier) < min_designs:     # tile up to the design floor
        frontier = frontier + frontier
    n = len(frontier)

    fused = batchcost.cost_many(frontier, workload, hw, mix)
    pr1 = _pr1_cost_many(frontier, workload, hw, mix)
    np.testing.assert_allclose(fused, pr1, rtol=1e-6)
    assert int(np.argmin(fused)) == int(np.argmin(pr1))

    packed = batchcost.pack_frontier(frontier, workload, mix)
    pr1_s = _steady_state(
        lambda: _pr1_cost_many(frontier, workload, hw, mix))
    grouped_s = _steady_state(
        lambda: batchcost.cost_many(frontier, workload, hw, mix,
                                    engine="grouped"))
    fused_s = _steady_state(
        lambda: batchcost.cost_many(frontier, workload, hw, mix))
    fused_score_s = _steady_state(lambda: packed.score(hw))
    return {
        "search": "frontier_scoring",
        "design": frontier[int(np.argmin(fused))].describe(),
        "designs": n,
        "records": len(packed.ids),
        "scalar_s": None,
        "pr1_cost_many_s": pr1_s,
        "grouped_s": grouped_s,
        "fused_s": fused_s,
        "fused_score_s": fused_score_s,
        "pr1_designs_per_s": n / max(pr1_s, 1e-12),
        "fused_designs_per_s": n / max(fused_s, 1e-12),
        "fused_score_designs_per_s": n / max(fused_score_s, 1e-12),
        "speedup_fused_vs_pr1": pr1_s / max(fused_s, 1e-12),
        "speedup_fused_scoring_vs_pr1": pr1_s / max(fused_score_s, 1e-12),
    }


def _bench_complete_design(workload, hw, mix, max_depth: int) -> Dict:
    from repro.core import batchcost
    from repro.core.autocomplete import complete_design

    # Warm every path at full depth: XLA compilation of the per-bucket /
    # fused frontier shapes and of the scalar shape-(1,) predict path are
    # one-time process costs, not search costs.  Each timed run then
    # starts from cold synthesis/compile memos (the jax executable cache
    # is process-level and survives; our lru caches don't).
    complete_design((), workload, hw, mix=mix, max_depth=max_depth)
    complete_design((), workload, hw, mix=mix, max_depth=max_depth,
                    engine="grouped")
    complete_design((), workload, hw, mix=mix, max_depth=1, batched=False)
    _pr2_complete_design(workload, hw, mix, max_depth)
    results, times = {}, {}
    for label, kwargs in (("fused", {}), ("grouped", {"engine": "grouped"}),
                          ("scalar", {"batched": False})):
        # best of 3 cold-cache runs: single cold runs carry tens of ms of
        # allocator/OS noise, swamping the engine difference
        reps = 1 if label == "scalar" else 3
        best = None
        for _ in range(reps):
            batchcost.clear_caches()
            t = timer()
            results[label] = complete_design((), workload, hw, mix=mix,
                                             max_depth=max_depth, **kwargs)
            elapsed = t()
            best = elapsed if best is None else min(best, elapsed)
        times[label] = best
    pr2_cold = None
    for _ in range(3):
        _pr2_clear_caches()
        t = timer()
        pr2_spec, pr2_cost, pr2_explored = _pr2_complete_design(
            workload, hw, mix, max_depth)
        elapsed = t()
        pr2_cold = elapsed if pr2_cold is None else min(pr2_cold, elapsed)
    # steady state: warm enumeration/segment/frontier memos (the what-if
    # serving regime) vs the warm PR-2 loop (its only memo is per-spec)
    fused_steady = _steady_state(
        lambda: complete_design((), workload, hw, mix=mix,
                                max_depth=max_depth))
    pr2_steady = _steady_state(
        lambda: _pr2_complete_design(workload, hw, mix, max_depth))
    # cost parity is the hard invariant; an argmin flip between exactly
    # cost-tied candidates would be benign (note it, don't fail the run)
    assert abs(results["grouped"].cost_seconds -
               results["scalar"].cost_seconds) <= \
        1e-9 * results["scalar"].cost_seconds
    assert abs(results["fused"].cost_seconds -
               results["scalar"].cost_seconds) <= \
        1e-6 * results["scalar"].cost_seconds
    assert abs(pr2_cost - results["fused"].cost_seconds) <= \
        1e-6 * results["fused"].cost_seconds
    assert pr2_explored == results["fused"].explored
    if results["fused"].spec.describe() != results["scalar"].spec.describe():
        print(f"note: cost-tied search results differ structurally: "
              f"{results['fused'].spec.describe()} vs "
              f"{results['scalar'].spec.describe()}")
    explored = results["fused"].explored
    return {
        "search": "complete_design",
        "design": results["fused"].spec.describe(),
        "designs": explored,
        "scalar_s": times["scalar"],
        "grouped_s": times["grouped"],
        "fused_s": times["fused"],
        "fused_steady_s": fused_steady,
        "pr2_e2e_s": pr2_cold,
        "pr2_steady_s": pr2_steady,
        "scalar_designs_per_s": explored / max(times["scalar"], 1e-12),
        "fused_designs_per_s": explored / max(times["fused"], 1e-12),
        "steady_designs_per_s": explored / max(fused_steady, 1e-12),
        "speedup_fused_vs_pr1": times["grouped"] / max(times["fused"],
                                                       1e-12),
        "speedup_fused_vs_scalar": times["scalar"] / max(times["fused"],
                                                         1e-12),
        "speedup_e2e_cold_vs_pr2": pr2_cold / max(times["fused"], 1e-12),
        "speedup_e2e_steady_vs_pr2": pr2_steady / max(fused_steady, 1e-12),
    }


def _bench_frontier_packing(workload, hw, mix, min_designs: int) -> Dict:
    """Construction-only throughput: designs/sec through ``pack_frontier``
    (no scoring), template-vectorized vs the reconstructed PR-2 per-design
    loop — keeps the packing/scoring split of the Amdahl gap visible."""
    from repro.core import batchcost
    from repro.core.autocomplete import (default_candidates,
                                        default_terminals,
                                        enumerate_completions)

    frontier = enumerate_completions((), default_candidates(),
                                     default_terminals(), 4, "bench")
    while len(frontier) < min_designs:
        frontier = frontier + frontier
    n = len(frontier)

    packed = batchcost.pack_frontier(frontier, workload, mix)
    pr2 = _pr2_pack_frontier(frontier, workload, mix)
    assert packed.n_segments == pr2.n_segments
    new_totals = packed.score(hw, engine="grouped")
    pr2_totals = pr2.score(hw, engine="grouped")
    np.testing.assert_allclose(new_totals, pr2_totals, rtol=1e-9)
    assert int(np.argmin(new_totals)) == int(np.argmin(pr2_totals))

    pack_cold = None
    for _ in range(3):
        batchcost.clear_caches()
        t = timer()
        batchcost.pack_frontier(frontier, workload, mix)
        elapsed = t()
        pack_cold = elapsed if pack_cold is None else min(pack_cold, elapsed)
    pack_warm = _steady_state(
        lambda: batchcost.pack_frontier(frontier, workload, mix))
    pr2_cold = None
    for _ in range(3):
        _pr2_clear_caches()
        t = timer()
        _pr2_pack_frontier(frontier, workload, mix)
        elapsed = t()
        pr2_cold = elapsed if pr2_cold is None else min(pr2_cold, elapsed)
    return {
        "search": "frontier_packing",
        "designs": n,
        "records": len(packed.ids),
        "fused_s": pack_cold,
        "pr2_e2e_s": pr2_cold,
        "pack_cold_s": pack_cold,
        "pack_warm_s": pack_warm,
        "pack_designs_per_s": n / max(pack_cold, 1e-12),
        "pr2_pack_designs_per_s": n / max(pr2_cold, 1e-12),
        "speedup_pack_vs_pr2": pr2_cold / max(pack_cold, 1e-12),
    }


def _bench_workload_sweep(workload, hw, min_designs: int,
                          n_points: int = 8, smoke: bool = False) -> Dict:
    """The PR-5 scenario: an (8-workload x >=512-design) continuum —
    read fraction and skew varying together — scored as ONE fused sweep
    call vs the pre-PR-5 capability (looping ``cost_many`` per
    workload).  Steady state on both sides: warm segment/frontier/sweep
    memos, identical frontiers."""
    from repro.core import batchcost, devicecost
    from repro.core.autocomplete import (default_candidates,
                                         default_terminals,
                                         enumerate_completions)
    from repro.core.hardware import hw1
    from repro.core.synthesis import cost_workload

    depth = 2 if smoke else 3
    frontier = list(enumerate_completions((), default_candidates(),
                                          default_terminals(), depth,
                                          "sweep-bench"))
    while len(frontier) < min_designs:     # tile up to the design floor
        frontier = frontier + frontier
    n = len(frontier)
    fracs = np.linspace(1.0, 0.0, n_points)
    alphas = np.linspace(0.0, 2.1, n_points)
    workloads = [dataclasses.replace(workload, zipf_alpha=float(a))
                 for a in alphas]
    mixes = [{"get": float(f) * 100.0, "update": (1.0 - float(f)) * 100.0}
             for f in fracs]

    # -- parity: the hard invariant, asserted in smoke and full runs ------
    grid = batchcost.cost_sweep(frontier, workloads, hw, mixes)
    loop = np.stack([batchcost.cost_many(frontier, w, hw, m)
                     for w, m in zip(workloads, mixes)])
    np.testing.assert_allclose(grid, loop, rtol=1e-6)
    grid_grouped = batchcost.cost_sweep(frontier, workloads, hw, mixes,
                                        engine="grouped")
    loop_grouped = np.stack([batchcost.cost_many(frontier, w, hw, m,
                                                 engine="grouped")
                             for w, m in zip(workloads, mixes)])
    np.testing.assert_array_equal(grid_grouped, loop_grouped)
    np.testing.assert_allclose(grid, grid_grouped, rtol=1e-6)
    cells = np.linspace(0, n - 1, 5).astype(int)
    scalar = np.asarray([[cost_workload(frontier[d], w, hw, m)
                          for d in cells]
                         for w, m in zip(workloads, mixes)])
    np.testing.assert_allclose(grid[:, cells], scalar, rtol=1e-6)
    assert np.array_equal(np.argmin(grid, axis=1),
                          np.argmin(grid_grouped, axis=1))

    # -- zero recompiles across repeat sweeps and a hardware swap ---------
    other = hw1()
    batchcost.cost_sweep(frontier, workloads, other, mixes)  # warm shapes
    traces = devicecost.trace_count()
    batchcost.cost_sweep(frontier, workloads, hw, mixes)
    batchcost.cost_sweep(frontier, workloads, other, mixes)
    assert devicecost.trace_count() == traces, \
        "repeat sweeps / hardware swaps must not retrace the fused kernel"

    import gc
    gc.collect()   # timings below compare ~ms-scale dispatches
    sweep_s = _steady_state(
        lambda: batchcost.cost_sweep(frontier, workloads, hw, mixes),
        reps=11)
    loop_s = _steady_state(
        lambda: [batchcost.cost_many(frontier, w, hw, m)
                 for w, m in zip(workloads, mixes)], reps=11)
    packed = batchcost.pack_sweep(frontier, workloads, mixes)
    cells_total = n * n_points
    return {
        "search": "workload_sweep",
        "designs": n,
        "workloads": n_points,
        "records": len(packed.frontiers[0].ids) * n_points,
        "fused_s": sweep_s,
        "sweep_steady_s": sweep_s,
        "per_workload_steady_s": loop_s,
        "sweep_cells_per_s": cells_total / max(sweep_s, 1e-12),
        "per_workload_cells_per_s": cells_total / max(loop_s, 1e-12),
        "speedup_sweep_vs_per_workload": loop_s / max(sweep_s, 1e-12),
    }


def _bench_hillclimb(workload, hw, mix, steps: int) -> Dict:
    row = bench_climb(workload, hw, mix, steps=steps)
    return {
        "search": "hillclimb",
        "design": row["design"],
        "designs": row["designs_costed"],
        "scalar_s": row["scalar_s"],
        "grouped_s": row["grouped_s"],
        "fused_s": row["fused_s"],
        "scalar_designs_per_s": row["scalar_designs_per_s"],
        "fused_designs_per_s": row["fused_designs_per_s"],
        "speedup_fused_vs_pr1": row["speedup_fused_vs_grouped"],
        "speedup_fused_vs_scalar": row["speedup_fused_vs_scalar"],
    }


def run(quick: bool = False, smoke: bool = False) -> None:
    from benchmarks.common import _print_table
    from repro.core import batchcost
    from repro.core.hardware import hw3
    from repro.core.synthesis import Workload

    hw = hw3()
    quick = quick or smoke
    n = 100_000 if quick else 1_000_000
    workload = Workload(n_entries=n, n_queries=100)
    mix = {"get": 80.0, "update": 20.0}

    batchcost.clear_caches()   # measure from cold synthesis caches
    rows: List[Dict] = [
        # the sweep's ~ms-scale steady-state timings run first, before
        # the 6932-design benches fragment the heap
        _bench_workload_sweep(workload, hw,
                              min_designs=64 if smoke else 512,
                              n_points=4 if smoke else 8, smoke=smoke),
        _bench_complete_design(workload, hw, mix,
                               max_depth=2 if quick else 3),
        _bench_hillclimb(workload, hw, mix, steps=5 if quick else 30),
        _bench_frontier_packing(workload, hw, mix,
                                min_designs=256 if quick else 4096),
        _bench_frontier_scoring(workload, hw, mix,
                                min_designs=1024 if quick else 4096),
    ]
    keys = ["search", "designs", "workloads", "scalar_s", "grouped_s",
            "fused_s", "fused_steady_s", "fused_score_s", "pack_cold_s",
            "pr2_e2e_s", "sweep_steady_s", "per_workload_steady_s",
            "fused_designs_per_s", "pack_designs_per_s",
            "sweep_cells_per_s", "sharded_cells_per_s_4dev",
            "speedup_fused_vs_pr1",
            "speedup_e2e_cold_vs_pr2", "speedup_e2e_steady_vs_pr2",
            "speedup_sweep_vs_per_workload",
            "speedup_sharded_4dev_vs_1dev", "scaling_bar", "design"]
    if smoke:
        # parity-only pass: no trajectory append, no perf bars (tiny
        # sizes make wall-clock ratios meaningless)
        _print_table("BENCH_search [smoke — not persisted]", rows, keys)
        print("smoke parity checks passed")
        return
    # perf bars come BEFORE the trajectory append: a regressed run must
    # fail without permanently writing its entry into the cross-PR file
    by_name = {row["search"]: row for row in rows}
    scoring = by_name["frontier_scoring"]
    print(f"fused scoring vs PR-1 cost_many: "
          f"{scoring['speedup_fused_scoring_vs_pr1']:.1f}x "
          f"(target >= {TARGET_SPEEDUP:.0f}x) on "
          f"{scoring['designs']} designs")
    assert scoring["speedup_fused_scoring_vs_pr1"] >= TARGET_SPEEDUP, \
        "fused frontier scoring regressed below the PR-2 acceptance bar"
    e2e = by_name["complete_design"]
    print(f"auto-completion vs PR-2 pipeline: "
          f"{e2e['speedup_e2e_cold_vs_pr2']:.1f}x cold / "
          f"{e2e['speedup_e2e_steady_vs_pr2']:.1f}x steady "
          f"(target >= {E2E_TARGET_SPEEDUP:.0f}x) on "
          f"{e2e['designs']} designs")
    assert e2e["speedup_e2e_cold_vs_pr2"] >= E2E_TARGET_SPEEDUP, \
        "cold end-to-end search regressed below the PR-3 acceptance bar"
    assert e2e["speedup_e2e_steady_vs_pr2"] >= E2E_TARGET_SPEEDUP, \
        "steady-state search regressed below the PR-3 acceptance bar"
    packing = by_name["frontier_packing"]
    print(f"frontier packing vs PR-2 loop: "
          f"{packing['speedup_pack_vs_pr2']:.1f}x cold on "
          f"{packing['designs']} designs")
    # the acceptance bar is end-to-end (above); the packing-only ratio
    # (3.1-3.8x measured) gets a looser floor so run-to-run allocator
    # noise on the 200k-record frontier can't flake the perf CI
    assert packing["speedup_pack_vs_pr2"] >= 2.5, \
        "template-vectorized packing regressed below the PR-3 bar"
    sweep = by_name["workload_sweep"]
    print(f"workload sweep ({sweep['workloads']} workloads x "
          f"{sweep['designs']} designs) vs per-workload cost_many: "
          f"{sweep['speedup_sweep_vs_per_workload']:.1f}x steady-state "
          f"(target >= {SWEEP_TARGET_SPEEDUP:.0f}x)")
    assert sweep["speedup_sweep_vs_per_workload"] >= \
        SWEEP_TARGET_SPEEDUP, \
        "the workload-sweep engine regressed below the PR-5 bar"
    # device scaling: sweep cells/sec at 1 vs 4 forced host devices,
    # measured in subprocesses (the device count is fixed at backend
    # init).  The >= 2x bar is asserted inside sweep_scaling_row when
    # this host has >= 4 physical cores, and recorded as an explicit
    # waiver otherwise — either way the measured row joins the
    # trajectory.
    from benchmarks import device_scaling
    scaling = device_scaling.sweep_scaling_row(quick)
    print(f"sharded sweep at {device_scaling.BAR_DEVICES} devices vs "
          f"1-device flat: "
          f"{scaling['speedup_sharded_4dev_vs_1dev']:.2f}x "
          f"({scaling['scaling_bar']})")
    rows.append(scaling)
    emit_trajectory(
        "BENCH_search",
        "PR7 multi-device sharded sweep scoring",
        rows, keys=keys)


if __name__ == "__main__":
    run()
