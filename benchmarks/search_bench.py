"""BENCH_search: designs-costed-per-second, scalar vs batched (perf CI).

Measures the fig9-style auto-completion search and the design hill climb
through both costing paths — the scalar per-design ``cost_workload`` loop
("before") and the batched ``cost_many`` frontier engine ("after") — on
identical frontiers, asserting the argmin design and total agree, and
persists the trajectory to experiments/bench/BENCH_search.json so every
future PR can track search throughput against this one.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit, timer
from benchmarks.hillclimb import bench_climb


def _bench_complete_design(workload, hw, mix, max_depth: int) -> Dict:
    from repro.core import batchcost
    from repro.core.autocomplete import complete_design

    # Warm both paths at full depth: XLA compilation of the per-bucket
    # predict shapes (batched) and of the scalar shape-(1,) predict path
    # are one-time process costs, not search costs.  Each timed run then
    # starts from cold synthesis/compile memos (the jax executable cache
    # is process-level and survives; our lru caches don't).
    complete_design((), workload, hw, mix=mix, max_depth=max_depth)
    complete_design((), workload, hw, mix=mix, max_depth=1, batched=False)
    batchcost.clear_caches()

    t = timer()
    batched = complete_design((), workload, hw, mix=mix, max_depth=max_depth)
    batched_s = t()
    batchcost.clear_caches()
    t = timer()
    scalar = complete_design((), workload, hw, mix=mix, max_depth=max_depth,
                             batched=False)
    scalar_s = t()
    # cost parity is the hard invariant; an argmin flip between exactly
    # cost-tied candidates would be benign (note it, don't fail the run)
    assert abs(batched.cost_seconds - scalar.cost_seconds) <= \
        1e-9 * scalar.cost_seconds
    if batched.spec.describe() != scalar.spec.describe():
        print(f"note: cost-tied search results differ structurally: "
              f"{batched.spec.describe()} vs {scalar.spec.describe()}")
    return {
        "search": "complete_design",
        "design": batched.spec.describe(),
        "designs": batched.explored,
        "scalar_s": scalar_s,
        "batched_s": batched_s,
        "scalar_designs_per_s": scalar.explored / max(scalar_s, 1e-12),
        "batched_designs_per_s": batched.explored / max(batched_s, 1e-12),
        "speedup": scalar_s / max(batched_s, 1e-12),
    }


def _bench_hillclimb(workload, hw, mix, steps: int) -> Dict:
    row = bench_climb(workload, hw, mix, steps=steps)
    return {
        "search": "hillclimb",
        "design": row["design"],
        "designs": row["designs_costed"],
        "scalar_s": row["scalar_s"],
        "batched_s": row["batched_s"],
        "scalar_designs_per_s": row["scalar_designs_per_s"],
        "batched_designs_per_s": row["batched_designs_per_s"],
        "speedup": row["speedup"],
    }


def run(quick: bool = False) -> None:
    from repro.core import batchcost
    from repro.core.hardware import hw3
    from repro.core.synthesis import Workload

    hw = hw3()
    n = 100_000 if quick else 1_000_000
    workload = Workload(n_entries=n, n_queries=100)
    mix = {"get": 80.0, "update": 20.0}

    batchcost.clear_caches()   # measure from cold synthesis caches
    rows: List[Dict] = [
        _bench_complete_design(workload, hw, mix,
                               max_depth=2 if quick else 3),
        _bench_hillclimb(workload, hw, mix, steps=5 if quick else 30),
    ]
    emit("BENCH_search", rows,
         keys=["search", "designs", "scalar_s", "batched_s",
               "scalar_designs_per_s", "batched_designs_per_s", "speedup",
               "design"])
    worst = min(r["speedup"] for r in rows)
    print(f"worst-case batched speedup: {worst:.1f}x")


if __name__ == "__main__":
    run()
