"""Fig. 9 + §5 'Rich Design Questions': auto-completion scenarios.

Scenario 1: mixed reads/writes; point reads touch 20% of the domain.
Scenario 2: 50% point reads on 10% of the domain, 50% range reads on a
disjoint 10%, plus uniform inserts.

The Calculator designs per-region sub-structures under a shared
partitioning root (the paper reports hash->{log, B+tree-like} hybrids) —
we report the synthesized designs, costs, and wall time, plus the §5
what-if question sequence (hardware change, bloom filters, skew).
"""
from __future__ import annotations

from benchmarks.common import container_profile, emit, timer
from repro.core import elements as el, whatif
from repro.core.autocomplete import (DomainRegion, complete_design,
                                     design_hybrid)
from repro.core.hardware import hw1, hw3
from repro.core.synthesis import Workload

W = Workload(n_entries=1_000_000, n_queries=100)


def _hybrid_row(label: str, hybrid, elapsed: float) -> dict:
    designs = sum(result.explored for _, result in hybrid.regions)
    return {"scenario": label, "design": hybrid.describe(),
            "cost_s": hybrid.cost_seconds, "search_seconds": elapsed,
            "designs_costed": designs,
            "designs_per_s": designs / max(elapsed, 1e-12)}


def run(quick: bool = False) -> None:
    hw = hw3()
    rows = []

    t = timer()
    scenario1 = design_hybrid(W, [
        DomainRegion("point-reads", 0.2, {"get": 100.0}),
        DomainRegion("writes", 0.8, {"update": 100.0, "bulk_load": 1.0}),
    ], hw)
    rows.append(_hybrid_row("1 (reads 20% / writes 80%)", scenario1, t()))

    t = timer()
    scenario2 = design_hybrid(W, [
        DomainRegion("point-reads", 0.1, {"get": 50.0}),
        DomainRegion("range-reads", 0.1, {"range_get": 50.0}),
        DomainRegion("writes", 0.8, {"update": 100.0, "bulk_load": 1.0}),
    ], hw)
    rows.append(_hybrid_row("2 (+range region)", scenario2, t()))
    emit("fig9_designs", rows)

    # §5 question sequence on a B-tree design
    rows = []
    base = el.spec_btree()
    ans = whatif.what_if_hardware(base, W, hw1(), hw3())
    rows.append({"question": "move HW1 -> HW3?", "answer": ans.summary()})
    t = timer()
    better = complete_design((), W, hw3(), mix={"get": 100.0}, max_depth=2)
    rows.append({"question": "better design for HW3? (5-element pool)",
                 "answer": better.summary()})
    ans = whatif.what_if_design(base, whatif.add_bloom_filters(base), W,
                                hw3())
    rows.append({"question": "bloom filters in all leaves?",
                 "answer": ans.summary()})
    import dataclasses
    skewed = dataclasses.replace(W, zipf_alpha=2.0)
    ans = whatif.what_if_workload(base, W, skewed, hw3())
    rows.append({"question": "workload skews to 0.01% of keys?",
                 "answer": ans.summary()})
    emit("fig9_whatif_sequence", rows)


if __name__ == "__main__":
    run()
