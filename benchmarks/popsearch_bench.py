"""BENCH_search: population search over the relaxed continuum (PR 10).

The PR-10 scenario: a design space far too large to enumerate — every
template skeleton of up to three internal levels with *continuous*
knobs (fanouts/partition counts 2..65536 per level, terminal capacities
16..65536, optional bloom-filter bits 2^10..2^20) — searched by
:func:`repro.core.search.population_search`: tournament selection,
structural crossover, annealed log2 knob mutation, AdamW gradient
refinement through the fused engine's own parameter banks
(:mod:`repro.core.relax`), one fused ``cost_sweep`` call per
generation.

The comparison is deliberately symmetric: ``design_beam`` and the
population search are given the *same* start designs (the paper's B+,
Trie and CSB+ specs), the same engine, and the same designs-costed cap
through one :class:`repro.core.search.SearchBudget` class.  Beam's
knob moves are doublings/halvings, so it is confined to the pow2 grid
around its seeds — it converges (and stops spending) once that
neighborhood is exhausted, while the population search keeps spending
the cap on the continuum between the grid points.

The acceptance bar, asserted in-bench BEFORE the trajectory append:

* population search **beats** ``design_beam`` on best-found cost at an
  equal designs-costed budget cap;
* beam *converged*: it stopped short of the cap, so the gap is a
  search-space limitation, not starvation;
* the winner re-verifies against the scalar oracle within 1e-6;
* after a warmup run, a full repeat search triggers **zero** fused
  recompiles across all its generations (pow2 shape bucketing + the
  never-re-pack seen-set).

``run(smoke=True)`` executes the oracle-parity and budget-accounting
checks at tiny sizes without appending or asserting the perf-sensitive
beat-the-beam bar (``benchmarks/run.py --smoke``).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit_trajectory

#: the PR-10 bar: strictly cheaper than design_beam at equal budget
BEAT_MARGIN = 1.0

#: the shared designs-costed cap both searches run under
BUDGET_DESIGNS = 256


def _design_space_size() -> float:
    """Decodable discrete designs in the relaxed continuum (the space
    population search draws from) — the too-large-to-enumerate claim,
    computed rather than asserted."""
    from repro.core import relax
    fanouts = 2 ** int(relax.FANOUT_HI) - 2 ** int(relax.FANOUT_LO) + 1
    caps = 2 ** int(relax.CAPACITY_HI) - 2 ** int(relax.CAPACITY_LO) + 1
    blooms = 2 ** int(relax.BLOOM_HI) - 2 ** int(relax.BLOOM_LO) + 1
    internals = len(relax.INTERNAL_NAMES)
    terminals = len(relax.TERMINAL_NAMES)
    total = 0.0
    for depth in range(0, 4):            # 0..MAX_INTERNAL_LEVELS
        structures = (internals * fanouts) ** depth * terminals * caps
        total += structures
        if depth >= 1:                   # Hash-rooted bloom variants
            total += (fanouts * blooms) \
                * (internals * fanouts) ** (depth - 1) * terminals * caps
    return total


def _bench_population_search(workload, hw, mix, smoke: bool) -> Dict:
    from repro.core import devicecost, elements as el, search
    from repro.core.autocomplete import design_beam
    from repro.core.synthesis import cost_workload

    budget_designs = 48 if smoke else BUDGET_DESIGNS
    starts = [el.spec_btree(), el.spec_trie(), el.spec_csb_tree()]

    # -- the incumbent: beam search, same priors, same budget cap ---------
    beam_budget = search.SearchBudget(budget_designs)
    beam = design_beam(workload, hw, mix, start=starts,
                       beam_width=4 if smoke else 8,
                       max_rounds=64, budget=beam_budget)

    pop_kwargs = dict(
        population=8 if smoke else 16,
        generations=200,                  # budget, not rounds, terminates
        refine_top=2, refine_steps=2, seed=10, seeds=starts)

    def run_search() -> Dict:
        return search.population_search(
            workload, hw, mix,
            budget=search.SearchBudget(budget_designs), **pop_kwargs)

    # -- warmup run: pays every fused/surrogate compile exactly once ------
    t0 = time.perf_counter()
    warm = run_search()
    warm_s = time.perf_counter() - t0
    # -- measured run: identical seed, and ZERO recompiles allowed --------
    traces_before = devicecost.trace_count()
    t0 = time.perf_counter()
    pop = run_search()
    pop_s = time.perf_counter() - t0
    trace_delta = devicecost.trace_count() - traces_before
    assert trace_delta == 0, (
        f"population search retraced the fused kernel {trace_delta}x "
        f"across generations after warmup")
    assert pop["cost_s"] == warm["cost_s"], "search must be deterministic"

    # -- budget accounting: one shared cap, honestly enforced -------------
    assert pop["designs_costed"] <= budget_designs, \
        (pop["designs_costed"], budget_designs)
    assert beam_budget.spent <= budget_designs

    # -- the winner re-verifies against the scalar oracle (1e-6) ----------
    oracle = cost_workload(pop["design"], workload, hw, mix)
    oracle_rel_err = abs(oracle - pop["cost_s"]) / abs(oracle)
    assert oracle_rel_err <= 1e-6, \
        f"winner/oracle disagreement: {oracle_rel_err:.3e}"
    assert pop["oracle_cost_s"] is not None   # verified inside the loop too

    space = _design_space_size()
    return {
        "search": "population_search",
        "design": pop["design"].describe(),
        "template": pop["template"],
        "budget": budget_designs,                # the shared cap
        "space_designs": space,
        "beam_cost_s": beam["cost_s"],
        "beam_design": beam["design"],
        "beam_spent": beam_budget.spent,
        "pop_cost_s": pop["cost_s"],
        "pop_spent": pop["designs_costed"],
        "oracle_rel_err": oracle_rel_err,
        "improvement_vs_beam": beam["cost_s"] / pop["cost_s"],
        "generations": pop["generations"],
        "trace_delta_after_warmup": trace_delta,
        "fused_s": pop_s,
        "warmup_s": warm_s,
        "designs_per_s": pop["designs_costed"] / max(pop_s, 1e-12),
    }


def run(quick: bool = False, smoke: bool = False) -> None:
    from benchmarks.common import _print_table
    from repro.core import batchcost
    from repro.core.hardware import hw3
    from repro.core.synthesis import Workload

    hw = hw3()
    quick = quick or smoke
    n = 100_000 if smoke else 1_000_000
    workload = Workload(n_entries=n, n_queries=100)
    mix = {"get": 80.0, "update": 20.0}

    batchcost.clear_caches()
    rows: List[Dict] = [_bench_population_search(workload, hw, mix, smoke)]
    keys = ["search", "budget", "space_designs", "generations",
            "beam_cost_s", "beam_spent", "pop_cost_s", "pop_spent",
            "improvement_vs_beam", "oracle_rel_err",
            "trace_delta_after_warmup", "fused_s", "designs_per_s",
            "beam_design", "design"]
    row = rows[0]
    print(f"design space: {row['space_designs']:.2e} decodable designs; "
          f"shared cap: {row['budget']} designs costed "
          f"(beam spent {row['beam_spent']}, "
          f"population spent {row['pop_spent']})")
    if smoke:
        _print_table("BENCH_search popsearch [smoke — not persisted]",
                     rows, keys)
        print("smoke popsearch parity checks passed")
        return
    # the bar comes BEFORE the trajectory append: a run that fails to
    # beat the beam must not permanently write its entry
    print(f"population search vs design_beam at a shared cap of "
          f"{row['budget']} designs: "
          f"{row['pop_cost_s']:.4e}s vs {row['beam_cost_s']:.4e}s "
          f"({row['improvement_vs_beam']:.3f}x better), winner verified "
          f"to {row['oracle_rel_err']:.1e} vs the scalar oracle, "
          f"{row['trace_delta_after_warmup']} recompiles after warmup")
    assert row["pop_cost_s"] * BEAT_MARGIN < row["beam_cost_s"], (
        f"population search ({row['pop_cost_s']:.4e}s) failed to beat "
        f"design_beam ({row['beam_cost_s']:.4e}s) at an equal "
        f"designs-costed cap of {row['budget']}")
    # beam stopped short of the cap on its own: the gap above is beam
    # exhausting its pow2 move grid, not beam being starved of budget
    assert row["beam_spent"] < row["budget"], (
        f"beam spent the whole cap ({row['beam_spent']}) — the "
        f"convergence claim no longer holds; raise BUDGET_DESIGNS")
    emit_trajectory(
        "BENCH_search",
        "PR10 population search over the relaxed continuum",
        rows, keys=keys)


if __name__ == "__main__":
    run()
