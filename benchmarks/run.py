"""Benchmark driver: one module per paper table/figure + the TPU roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --smoke

``--smoke`` is the fast validation path: it runs the repro-lint static
checks (``python -m tools.analyze``), then the search-engine,
population-search, workload-sweep, what-if-serving, sharded-scoring
and fault-injection parity checks at tiny sizes (every
engine against the scalar oracle, grouped sweep grids bit-identical to
per-workload loops, zero-recompile probes, one injected shard failure
and one NaN-bank corruption both healed to oracle parity), writes
**no** artifacts and
appends nothing to the BENCH_search / BENCH_serving trajectories —
CI-friendly, seconds not minutes.  The full trajectory run stays one
command (no flags).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (chaos_bench, design_space, device_scaling,
                        fig6_accuracy, fig7_bulkload_training,
                        fig8_cache_skew, fig9_design_search, hillclimb,
                        kernels_bench, load_bench, popsearch_bench,
                        roofline, search_bench, serving_bench)

BENCHES = [
    ("design_space", design_space.run),
    ("fig6_accuracy", fig6_accuracy.run),
    ("fig7_bulkload_training", fig7_bulkload_training.run),
    ("fig8_cache_skew", fig8_cache_skew.run),
    ("fig9_design_search", fig9_design_search.run),
    # perf trajectory: designs-costed-per-second, scalar vs grouped vs
    # fused (appends an entry to experiments/bench/BENCH_search.json)
    ("BENCH_search", search_bench.run),
    # search-quality trajectory: population search over the relaxed
    # continuum vs design_beam at an equal designs-costed cap
    # (appends to BENCH_search.json as well)
    ("BENCH_popsearch", popsearch_bench.run),
    # perf trajectory: questions/sec through the concurrent what-if
    # server, serial loop vs coalesced (BENCH_serving.json)
    ("BENCH_serving", serving_bench.run),
    # robustness trajectory: sustained mixed load through the hardened
    # server — priority-lane latency, shedding, warm restart
    # (BENCH_load.json)
    ("BENCH_load", load_bench.run),
    # robustness trajectory: the same mixed load under an ~5% seeded
    # fault plan — self-healing shard pool, degraded-engine chain,
    # worker resurrection, oracle parity under chaos (BENCH_chaos.json)
    ("BENCH_chaos", chaos_bench.run),
    ("hillclimb_design", hillclimb.run),
    ("kernels", kernels_bench.run),
    ("roofline", roofline.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast parity-only pass: tiny sizes, no artifacts,"
                         " no trajectory append")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if args.smoke:
        t0 = time.perf_counter()
        print("### repro-lint (smoke)", flush=True)
        from tools.analyze import render_text, run_paths
        findings = run_paths()
        if findings:
            print(render_text(findings), flush=True)
            sys.exit(1)
        print("### benchmark: BENCH_search (smoke)", flush=True)
        search_bench.run(smoke=True)
        print("### benchmark: BENCH_popsearch (smoke)", flush=True)
        popsearch_bench.run(smoke=True)
        print("### benchmark: BENCH_serving (smoke)", flush=True)
        serving_bench.run(smoke=True)
        print("### benchmark: BENCH_load (smoke)", flush=True)
        load_bench.run(smoke=True)
        print("### benchmark: BENCH_chaos (smoke)", flush=True)
        chaos_bench.run(smoke=True)
        print("### benchmark: device_scaling (smoke)", flush=True)
        device_scaling.run(smoke=True)
        print(f"### smoke done in {time.perf_counter() - t0:.1f}s")
        return
    if args.only and args.only not in {name for name, _ in BENCHES}:
        ap.error(f"unknown benchmark {args.only!r}; choose from "
                 f"{[name for name, _ in BENCHES]}")
    failures = []
    for name, fn in BENCHES:
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"### benchmark: {name}", flush=True)
        try:
            fn(quick=args.quick)
            print(f"### {name} done in {time.perf_counter() - t0:.1f}s\n",
                  flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED benchmarks: {failures}")
        sys.exit(1)
    print("all benchmarks passed")


if __name__ == "__main__":
    main()
