"""Device-scaling measurements: sharded sweep scoring at 1 vs 4 devices.

JAX pins its device list at backend init, so one process cannot measure
two device counts — each measurement runs in a *child* process launched
under ``--xla_force_host_platform_device_count=N`` (see
:mod:`repro.testing.devices`).  The children print one machine-readable
JSON line; the parent computes the scaling ratios:

* ``--child sweep``   — steady-state sweep-grid scoring (cells/sec) on a
  >= 4096-cell workload x design grid, flat jit vs the sharded pmap path
  (parity asserted bit-for-bit before timing);
* ``--child serving`` — questions/sec through a
  ``DesignCalculatorService`` whose coalescing worker routes windows
  across the scoring-shard pool.

The acceptance bar (sharded >= 2x the single-device path at 4 devices)
is only physically meaningful when 4 forced host devices map onto >= 4
physical cores — XLA's host "devices" are threads, so on a 1-core
container they time-share the core and the ratio measures scheduler
overhead, not scaling.  ``_apply_bar`` therefore asserts the bar when
``os.cpu_count() >= BAR_MIN_CORES`` and otherwise records an explicit
waiver string in the emitted row, so the measured numbers still land in
the BENCH trajectory without pretending the bar was met or moving it.

``run(smoke=True)`` is the in-process sharded-parity pass wired into
``benchmarks/run.py --smoke``: no subprocesses, no timing bars.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Sequence

from benchmarks.common import _print_table

#: sharded-vs-single-device throughput bar, asserted at >= BAR_DEVICES
SCALING_TARGET = 2.0
#: the forced device count the bar is measured at
BAR_DEVICES = 4
#: physical cores needed for BAR_DEVICES forced devices to scale at all
BAR_MIN_CORES = 4

_JSON_PREFIX = "DEVICE_SCALING_JSON "


def _steady_state(fn: Callable, reps: int = 7) -> float:
    """Median wall-clock of ``fn`` after a warm call (compiles excluded)."""
    fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _sweep_inputs(n_designs: int, n_points: int):
    from repro.core.autocomplete import (default_candidates,
                                         default_terminals,
                                         enumerate_completions)
    from repro.core.synthesis import Workload
    frontier = list(enumerate_completions((), default_candidates(),
                                          default_terminals(), 2,
                                          "device-scaling"))
    while len(frontier) < n_designs:       # tile up to the design floor
        frontier = frontier + frontier
    frontier = frontier[:n_designs]
    base = Workload(n_entries=100_000, n_queries=100)
    workloads = [dataclasses.replace(base, zipf_alpha=0.25 * i)
                 for i in range(n_points)]
    mixes = [{"get": 60.0 + i, "range_get": 20.0, "update": 20.0 - i}
             for i in range(n_points)]
    return frontier, workloads, mixes


# ---------------------------------------------------------------------------
# children: one measurement per forced device count
# ---------------------------------------------------------------------------
def _child_sweep(quick: bool) -> Dict:
    import numpy as np

    import jax
    from repro.core import batchcost
    from repro.core.hardware import hw3

    hw = hw3()
    n_designs, n_points = (512, 8) if quick else (1024, 8)
    frontier, workloads, mixes = _sweep_inputs(n_designs, n_points)
    sweep = batchcost.pack_sweep(frontier, workloads, mixes)
    cells = n_designs * n_points

    flat = sweep.score(hw, shard=False)
    sharded = sweep.score(hw, shard=True)
    assert np.array_equal(sharded, flat), \
        "sharded sweep diverged from the flat jit path"
    flat_s = _steady_state(lambda: sweep.score(hw, shard=False))
    sharded_s = _steady_state(lambda: sweep.score(hw, shard=True))
    return {
        "devices": jax.device_count(),
        "cells": cells,
        "flat_cells_per_s": cells / max(flat_s, 1e-12),
        "sharded_cells_per_s": cells / max(sharded_s, 1e-12),
    }


def _child_serving(quick: bool) -> Dict:
    import jax
    from repro.core.hardware import hw1
    from repro.serving import DesignCalculatorService

    hw = hw1()
    n_designs, n_points = (128, 8) if quick else (256, 8)
    n_questions = 8
    frontier, workloads, mixes = _sweep_inputs(n_designs, n_points)
    # every question sweeps a slightly different workload continuum so
    # repeat submissions measure scoring throughput, not answer reuse
    variants = [[dataclasses.replace(w, n_queries=100 + q)
                 for w in workloads] for q in range(n_questions)]
    service = DesignCalculatorService(
        [hw], scoring_shards=jax.device_count(),
        shard_min_cells=max((n_designs * n_points) // 8, 1),
        window_s=0.005)
    try:
        service.submit_sweep(frontier, variants[0], hw,
                             mixes).result(timeout=300)   # warm + compile
        t0 = time.perf_counter()
        futures = [service.submit_sweep(frontier, v, hw, mixes)
                   for v in variants]
        for fut in futures:
            fut.result(timeout=300)
        wall = time.perf_counter() - t0
        stats = service.stats()
    finally:
        service.stop()
    return {
        "devices": jax.device_count(),
        "questions": n_questions,
        "questions_per_s": n_questions / max(wall, 1e-12),
        "shard_dispatches": stats["shard_dispatches"],
    }


_CHILDREN = {"sweep": _child_sweep, "serving": _child_serving}


def _run_child(mode: str, n_devices: int, quick: bool) -> Dict:
    from repro.testing.devices import run_under_devices
    argv = ["-m", "benchmarks.device_scaling", "--child", mode]
    if quick:
        argv.append("--quick")
    proc = run_under_devices(n_devices, argv)
    if proc.returncode != 0:
        raise RuntimeError(
            f"device-scaling child {mode!r} failed under {n_devices} "
            f"devices:\n{proc.stdout[-4000:]}\n{proc.stderr[-2000:]}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith(_JSON_PREFIX):
            return json.loads(line[len(_JSON_PREFIX):])
    raise RuntimeError(f"device-scaling child {mode!r} printed no "
                       f"measurement line:\n{proc.stdout[-2000:]}")


def _apply_bar(row: Dict, speedup_key: str) -> Dict:
    """Assert the >= 2x bar, or record a waiver on hardware where 4
    forced host devices cannot occupy 4 physical cores."""
    cores = os.cpu_count() or 1
    if cores >= BAR_MIN_CORES:
        row["scaling_bar"] = f"asserted >= {SCALING_TARGET:.0f}x"
        assert row[speedup_key] >= SCALING_TARGET, \
            (f"{speedup_key} = {row[speedup_key]:.2f}x is below the "
             f"{SCALING_TARGET:.0f}x device-scaling bar at "
             f"{BAR_DEVICES} devices on {cores} cores")
    else:
        row["scaling_bar"] = (
            f"waived: {cores} physical core(s) < {BAR_MIN_CORES}; "
            f"{BAR_DEVICES} forced host devices time-share the core(s), "
            f"so the >= {SCALING_TARGET:.0f}x bar is unattainable here "
            f"(measured ratio recorded unchanged)")
    return row


# ---------------------------------------------------------------------------
# parent rows, consumed by search_bench / load_bench trajectories
# ---------------------------------------------------------------------------
def sweep_scaling_row(quick: bool = False) -> Dict:
    """Sweep-grid cells/sec at 1 vs BAR_DEVICES forced devices — the
    BENCH_search device-scaling row."""
    base = _run_child("sweep", 1, quick)
    multi = _run_child("sweep", BAR_DEVICES, quick)
    speedup = multi["sharded_cells_per_s"] / max(
        base["flat_cells_per_s"], 1e-12)
    return _apply_bar({
        "search": "device_scaling",
        "designs": base["cells"] // 8,
        "workloads": 8,
        "cells": base["cells"],
        "sweep_cells_per_s": base["flat_cells_per_s"],
        "sharded_cells_per_s_4dev": multi["sharded_cells_per_s"],
        "speedup_sharded_4dev_vs_1dev": speedup,
    }, "speedup_sharded_4dev_vs_1dev")


def serving_scaling_row(quick: bool = False) -> Dict:
    """Service questions/sec at 1 vs BAR_DEVICES scoring shards — the
    BENCH_load device-scaling fields."""
    base = _run_child("serving", 1, quick)
    multi = _run_child("serving", BAR_DEVICES, quick)
    speedup = multi["questions_per_s"] / max(base["questions_per_s"],
                                             1e-12)
    return _apply_bar({
        "questions_per_s_1dev": base["questions_per_s"],
        "questions_per_s_4dev": multi["questions_per_s"],
        "shard_dispatches_4dev": multi["shard_dispatches"],
        "speedup_serving_4dev_vs_1dev": speedup,
    }, "speedup_serving_4dev_vs_1dev")


def _smoke() -> None:
    """In-process sharded-parity pass (the ``run.py --smoke`` hook):
    shard=True must be bit-identical to the flat jit path at whatever
    device count this process has, pool merge included."""
    import numpy as np

    import jax
    from repro.core import batchcost
    from repro.core.hardware import hw3
    from repro.serving import ScoringShardPool

    hw = hw3()
    frontier, workloads, mixes = _sweep_inputs(64, 4)
    sweep = batchcost.pack_sweep(frontier, workloads, mixes)
    flat = sweep.score(hw, shard=False)
    assert np.array_equal(sweep.score(hw, shard=True), flat), \
        "sharded sweep diverged from the flat jit path"
    packed = sweep.frontiers[0]
    assert np.array_equal(packed.score(hw, shard=True),
                          packed.score(hw, shard=False)), \
        "sharded frontier scoring diverged from the flat jit path"
    pool = ScoringShardPool(min_cells_per_shard=1)
    try:
        pooled, used = pool.score_sweep(sweep, hw)
        assert used >= 1 and np.array_equal(pooled, flat), \
            "shard-pool merge diverged from the flat grid"
    finally:
        pool.close()
    print(f"device-scaling smoke: sharded parity ok "
          f"({jax.device_count()} device(s), {used} pool shard(s))")


def run(quick: bool = False, smoke: bool = False) -> None:
    if smoke:
        _smoke()
        return
    rows: List[Dict] = [sweep_scaling_row(quick)]
    serving = serving_scaling_row(quick)
    rows.append({"search": "device_scaling_serving", **serving})
    _print_table("device_scaling [standalone — trajectory rows are "
                 "appended by search_bench/load_bench]", rows)


def main(argv: Sequence[str] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", choices=sorted(_CHILDREN))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        print(_JSON_PREFIX + json.dumps(_CHILDREN[args.child](args.quick)))
        return
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
