"""Kernel micro-benchmarks: per-kernel arithmetic intensity + oracle check.

Interpret-mode wall time on CPU is not TPU performance; what this harness
reports per kernel is (a) correctness vs the ref oracle at benchmark
shapes, and (b) the structural roofline terms — FLOPs, HBM bytes and
FLOPs/byte for the BlockSpec tiling — which is how we reason about the
kernels without hardware (same method as §Roofline).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit


def run(quick: bool = False) -> None:
    rows = []
    rng = np.random.default_rng(0)

    # flash attention: S=1024, H=8, D=128 block tiling
    b, h, s, d = 1, 8, 512 if quick else 1024, 128
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    got = np.asarray(flash_attention(q, k, v, True), np.float32)
    want = np.asarray(attention_ref(q, k, v, causal=True), np.float32)
    err = float(np.nanmax(np.abs(got - want)))
    flops = 4.0 * b * h * s * s * d
    bytes_ = 4.0 * (3 * b * h * s * d + b * h * s * d)
    rows.append({"kernel": "flash_attention", "max_err": err,
                 "flops": flops, "hbm_bytes": bytes_,
                 "flops_per_byte": flops / bytes_})

    # sorted search: N=64k keys, Q=4k queries
    from repro.kernels.sorted_search.ops import sorted_search
    from repro.kernels.sorted_search.ref import sorted_search_ref
    n, nq = (1 << 14, 1 << 10) if quick else (1 << 16, 1 << 12)
    keys = np.sort(rng.integers(0, 1 << 30, n)).astype(np.int32)
    queries = rng.integers(0, 1 << 30, nq).astype(np.int32)
    got = np.asarray(sorted_search(jnp.asarray(keys), jnp.asarray(queries)))
    want = np.asarray(sorted_search_ref(jnp.asarray(keys),
                                        jnp.asarray(queries)))
    cmps = float(n) * nq
    rows.append({"kernel": "sorted_search",
                 "max_err": float(np.abs(got - want).max()),
                 "flops": cmps, "hbm_bytes": 4.0 * (n + 2 * nq),
                 "flops_per_byte": cmps / (4.0 * (n + 2 * nq))})

    # scan filter
    from repro.kernels.scan_filter.ops import scan_filter
    from repro.kernels.scan_filter.ref import scan_filter_ref
    ukeys = rng.permutation(keys).astype(np.int32)
    lo, hi = queries - 1000, queries + 1000
    got = scan_filter(jnp.asarray(ukeys), jnp.asarray(queries),
                      jnp.asarray(lo), jnp.asarray(hi))
    want = scan_filter_ref(jnp.asarray(ukeys), jnp.asarray(queries),
                           jnp.asarray(lo), jnp.asarray(hi))
    err = float(np.abs(np.asarray(got[1]) - np.asarray(want[1])).max())
    rows.append({"kernel": "scan_filter", "max_err": err,
                 "flops": 3.0 * cmps, "hbm_bytes": 4.0 * (n + 4 * nq),
                 "flops_per_byte": 3.0 * cmps / (4.0 * (n + 4 * nq))})

    # hash probe
    from repro.kernels.hash_probe.ops import DEFAULT_A, hash_probe
    from repro.kernels.hash_probe.ref import build_table, hash_probe_ref
    s_bits, cap = 10, 16
    tkeys = rng.choice(1 << 24, 8000, replace=False).astype(np.int64)
    tvals = rng.integers(1, 1 << 30, 8000).astype(np.int32)
    tk, tv = build_table(tkeys, tvals, s_bits, DEFAULT_A, cap)
    found, val = hash_probe(jnp.asarray(tk), jnp.asarray(tv),
                            jnp.asarray(queries), s=s_bits)
    pos_r, val_r = hash_probe_ref(tk, tv, queries, DEFAULT_A, s_bits)
    err = float(np.abs(np.asarray(val) - val_r).max())
    work = float((1 << s_bits) * cap) * nq
    rows.append({"kernel": "hash_probe", "max_err": err, "flops": work,
                 "hbm_bytes": 8.0 * (1 << s_bits) * cap + 8.0 * nq,
                 "flops_per_byte": work / (8.0 * (1 << s_bits) * cap)})

    # bloom probe
    from repro.kernels.bloom_probe.ops import DEFAULT_COEFFS, bloom_probe
    from repro.kernels.bloom_probe.ref import bloom_probe_ref, build_filter
    sb = 16
    words = build_filter(tkeys, DEFAULT_COEFFS[:3], sb)
    got = np.asarray(bloom_probe(jnp.asarray(words), jnp.asarray(queries),
                                 s=sb, num_hashes=3))
    want = bloom_probe_ref(words, queries, DEFAULT_COEFFS[:3], sb)
    rows.append({"kernel": "bloom_probe",
                 "max_err": float((got != want).sum()),
                 "flops": 3.0 * nq * len(words),
                 "hbm_bytes": 4.0 * len(words) + 4.0 * nq,
                 "flops_per_byte": 3.0 * nq * len(words) /
                 (4.0 * len(words) + 4.0 * nq)})
    emit("kernels", rows)


if __name__ == "__main__":
    run()
