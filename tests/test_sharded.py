"""Multi-device sharded scoring + device-routed serving (PR 7).

The contract: sharded ``score_frontier``/``score_sweep`` are
bit-identical to the single-device jit path (padding/masking only ever
adds rows that are computed-and-dropped), argmins are identical through
``design_beam``/``whatif.workload_sweep``, repeat sharded scores and
hardware swaps recompile nothing, and the serving shard pool partitions
a window across >= 2 devices while keeping the PR 6 deadline semantics.

Multi-device cases carry ``@pytest.mark.devices(n)``: the
``device_count`` fixture re-invokes them in a subprocess under
``--xla_force_host_platform_device_count=n`` (2/8/48-way sharding in
one CI run, no hardware needed).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import batchcost, devicecost, elements as el, whatif
from repro.core.autocomplete import design_beam
from repro.core.batchcost import pack_frontier, pack_sweep
from repro.core.hardware import hw1, hw3
from repro.core.synthesis import Workload
from repro.serving import DesignCalculatorService, ScoringShardPool
from repro.serving.admission import DeadlineExceeded
from repro.testing.devices import (DEVICE_COUNT_FLAG, forced_device_count,
                                   forced_device_env)

BASE = Workload(n_entries=120_000, n_queries=100)
MIX = {"get": 60.0, "range_get": 20.0, "update": 20.0}


def _specs():
    return [el.spec_btree(), el.spec_array(1), el.spec_hash_table(),
            el.spec_skip_list(), el.spec_trie(), el.spec_linked_list(),
            el.spec_sorted_array(1), el.spec_csb_tree()]


def _workloads(n=5):
    return [dataclasses.replace(BASE, zipf_alpha=0.3 * i)
            for i in range(n)]


@pytest.fixture(autouse=True)
def _reset_threshold():
    yield
    devicecost.set_shard_threshold(None)


# ---------------------------------------------------------------------------
# Satellite 1: the shard threshold knob
# ---------------------------------------------------------------------------
def test_shard_threshold_override_wins():
    devicecost.set_shard_threshold(123)
    assert devicecost.shard_threshold() == 123
    devicecost.set_shard_threshold(None)
    assert devicecost.shard_threshold() != 123


def test_shard_threshold_env_var(monkeypatch):
    monkeypatch.setenv(devicecost.SHARD_THRESHOLD_ENV, "777")
    assert devicecost.shard_threshold() == 777
    devicecost.set_shard_threshold(55)   # explicit override beats env
    assert devicecost.shard_threshold() == 55
    monkeypatch.setenv(devicecost.SHARD_THRESHOLD_ENV, "not-a-number")
    devicecost.set_shard_threshold(None)
    assert devicecost.shard_threshold() >= 1   # bad env falls through


def test_single_device_calibration_never_shards(device_count):
    if device_count > 1:
        pytest.skip("calibration default is device-count dependent")
    assert devicecost._calibrate_shard_threshold() \
        == devicecost._MAX_FUSED_RECORDS


def test_forced_device_env_helpers():
    env = forced_device_env(8, {"XLA_FLAGS": f"{DEVICE_COUNT_FLAG}=2 "
                                             "--other=1"})
    assert forced_device_count(env) == 8
    assert "--other=1" in env["XLA_FLAGS"]
    assert env["XLA_FLAGS"].count(DEVICE_COUNT_FLAG) == 1
    assert forced_device_count({"XLA_FLAGS": ""}) is None


# ---------------------------------------------------------------------------
# Split/merge partitions (the shard pool's primitive) — any device count
# ---------------------------------------------------------------------------
def test_frontier_split_merge_bit_identical(hw_analytical):
    packed = pack_frontier(_specs(), BASE, MIX)
    whole = packed.score(hw_analytical, shard=False)
    for n_parts in (1, 2, 3, len(_specs()), 64):
        parts = packed.split(n_parts)
        assert sum(p.n_segments for p in parts) == packed.n_segments
        merged = np.concatenate(
            [p.score(hw_analytical, shard=False) for p in parts])
        assert np.array_equal(merged, whole)


def test_sweep_split_merge_bit_identical(hw_analytical):
    sweep = pack_sweep(_specs(), _workloads(), [MIX] * 5)
    whole = sweep.score(hw_analytical, shard=False)
    for n_parts in (2, 3, 64):
        parts = sweep.split(n_parts)
        assert all(p.rectangular for p in parts)   # ids stay shared
        merged = np.concatenate(
            [p.score(hw_analytical, shard=False) for p in parts], axis=1)
        assert np.array_equal(merged, whole)


def test_sharded_paths_bit_identical_here(hw_analytical):
    """shard=True (pmap, whatever the local device count) must match the
    flat jit path bit for bit — the 1-device leg of the parity matrix."""
    packed = pack_frontier(_specs(), BASE, MIX)
    assert np.array_equal(packed.score(hw_analytical, shard=True),
                          packed.score(hw_analytical, shard=False))
    sweep = pack_sweep(_specs(), _workloads(), [MIX] * 5)
    assert np.array_equal(sweep.score(hw_analytical, shard=True),
                          sweep.score(hw_analytical, shard=False))
    one_row = pack_sweep(_specs(), [BASE], [MIX])
    assert np.array_equal(one_row.score(hw_analytical, shard=True),
                          one_row.score(hw_analytical, shard=False))


def test_pool_degenerate_is_plain_score(hw_analytical):
    pool = ScoringShardPool(1)
    assert pool.n_shards == 1
    packed = pack_frontier(_specs(), BASE, MIX)
    totals, used = pool.score_frontier(packed, hw_analytical)
    assert used == 1
    assert np.array_equal(totals, packed.score(hw_analytical))
    sweep = pack_sweep(_specs(), _workloads(), [MIX] * 5)
    grid, used = pool.score_sweep(sweep, hw_analytical)
    assert used == 1
    assert np.array_equal(grid, sweep.score(hw_analytical))


def test_pool_abort_when_probe_reports_dead(hw_analytical):
    pool = ScoringShardPool(1)
    packed = pack_frontier(_specs(), BASE, MIX)
    totals, used = pool.score_frontier(packed, hw_analytical,
                                       before_dispatch=lambda i: False)
    assert totals is None and used == 0


# ---------------------------------------------------------------------------
# Satellite 3: the multi-device parity matrix (subprocess per count)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_devices", [
    pytest.param(2, marks=pytest.mark.devices(2)),
    pytest.param(8, marks=pytest.mark.devices(8)),
    pytest.param(48, marks=[pytest.mark.devices(48), pytest.mark.slow]),
])
def test_sharded_parity_under_devices(n_devices, device_count):
    assert device_count == n_devices
    hw = hw1()
    specs, workloads = _specs(), _workloads()
    devicecost.set_shard_threshold(1)   # every auto decision shards

    # frontier: sharded bit-identical to flat, and the auto path shards
    packed = pack_frontier(specs, BASE, MIX)
    flat = packed.score(hw, shard=False)
    assert np.array_equal(packed.score(hw, shard=True), flat)
    assert np.array_equal(packed.score(hw), flat)   # auto

    # sweep: workload rows pmap across devices, bit-identical grid
    sweep = pack_sweep(specs, workloads, [MIX] * len(workloads))
    grid = sweep.score(hw, shard=False)
    sharded = sweep.score(hw, shard=True)
    assert np.array_equal(sharded, grid)
    assert np.array_equal(np.argmin(sharded, axis=1),
                          np.argmin(grid, axis=1))
    # 1e-6 against the grouped oracle, like every other engine pairing
    np.testing.assert_allclose(sharded, sweep.score(hw, engine="grouped"),
                               rtol=1e-6)

    # zero recompiles on repeat sharded scores AND hardware swaps
    sweep.score(hw3(), shard=True)   # warm both tables
    before = devicecost.trace_count()
    for _ in range(3):
        assert np.array_equal(sweep.score(hw, shard=True), grid)
        sweep.score(hw3(), shard=True)
    packed.score(hw, shard=True)
    assert devicecost.trace_count() == before

    # single-row sweeps fall back to segment-range sharding, same grid
    one_row = pack_sweep(specs, [BASE], [MIX])
    assert np.array_equal(one_row.score(hw, shard=True),
                          one_row.score(hw, shard=False))

    # the shard pool partitions and merges bit-identically
    pool = ScoringShardPool(min_cells_per_shard=1)
    assert pool.n_shards == min(n_devices, len(pool.devices))
    totals, used = pool.score_frontier(packed, hw)
    assert used > 1
    assert np.array_equal(totals, flat)
    pooled, used = pool.score_sweep(sweep, hw)
    assert used > 1
    assert np.array_equal(pooled, grid)
    pool.close()

    # identical argmins through the public search/sweep surfaces
    devicecost.set_shard_threshold(devicecost._MAX_FUSED_RECORDS)
    answer_flat = whatif.workload_sweep(specs, workloads, hw,
                                        [MIX] * len(workloads))
    beam_flat = design_beam(BASE, hw, MIX, max_rounds=2)
    batchcost.clear_caches()
    devicecost.set_shard_threshold(1)
    answer_sharded = whatif.workload_sweep(specs, workloads, hw,
                                           [MIX] * len(workloads))
    beam_sharded = design_beam(BASE, hw, MIX, max_rounds=2)
    assert np.array_equal(answer_sharded.totals, answer_flat.totals)
    assert beam_sharded["design"] == beam_flat["design"]
    assert beam_sharded["cost_s"] == beam_flat["cost_s"]


@pytest.mark.devices(2)
def test_calibration_with_multiple_devices(device_count):
    assert device_count == 2
    threshold = devicecost._calibrate_shard_threshold()
    assert threshold >= devicecost._CALIBRATION_BUCKETS[0]
    # the lazily-memoized default resolves to some positive cut-over
    assert devicecost.shard_threshold() >= 1


@pytest.mark.devices(2)
def test_service_routes_across_scoring_shards(device_count):
    """A mixed window served across >= 2 scoring shards: bit-identical
    answers, shard dispatches counted, PR 6 deadlines intact."""
    assert device_count == 2
    hw = hw1()
    specs, workloads = _specs(), _workloads()
    mixes = [MIX] * len(workloads)
    oracle = whatif.workload_sweep(specs, workloads, hw, mixes)
    service = DesignCalculatorService(
        [hw], scoring_shards=2, shard_min_cells=1, window_s=0.02)
    try:
        futures = [service.submit_sweep(specs, workloads, hw, mixes)
                   for _ in range(2)]
        futures.append(service.submit_design(
            el.spec_btree(), el.spec_array(1), BASE, hw, MIX))
        answers = [f.result(timeout=60) for f in futures]
        for sweep_answer in answers[:2]:
            assert np.array_equal(sweep_answer.totals, oracle.totals)
        direct = whatif.what_if_design(
            el.spec_btree(), el.spec_array(1), BASE, hw, MIX)
        np.testing.assert_allclose(answers[2].baseline_seconds,
                                   direct.baseline_seconds, rtol=1e-12)
        stats = service.stats()
        assert stats["shard_dispatches"] >= 2
        # deadline composition: an already-expired request fails fast
        # with DeadlineExceeded instead of occupying a sharded call
        doomed = service.submit_sweep(specs, workloads, hw, mixes,
                                      deadline_s=1e-9)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=60)
    finally:
        service.stop()
