"""Fig. 6 claim: synthesized costs track a real implementation.

On this container we train the Level-2 models live (quick profile), then
compare synthesized Get latencies against the measured ground-truth
structures.  A busy CI box makes absolute latencies noisy, so we assert
*ranking* agreement (the paper's designs differ by orders of magnitude)
rather than tight relative error; benchmarks/fig6_accuracy.py reports the
full curves."""
import inspect

import numpy as np
import pytest

from repro.core import elements as el, structures as S, synthesis
from repro.core.synthesis import Workload

#: (spec name, ground truth class) pairs compared — the slow O(N)-scan
#: structures and the indexed ones must separate cleanly
PAIRS = [
    ("array", S.Array),
    ("sorted_array", S.SortedArray),
    ("linked_list", S.LinkedList),
    ("skip_list", S.SkipList),
    ("hash_table", S.HashTable),
    ("btree", S.BPlusTree),
]


@pytest.mark.slow
def test_synthesized_ranking_matches_measured(cpu_profile, rng):
    n = 50_000
    keys = rng.choice(np.arange(n * 4), size=n, replace=False).astype(np.int64)
    values = rng.integers(0, 1 << 30, size=n).astype(np.int64)
    queries = keys[rng.integers(0, n, size=200)]

    measured, predicted = {}, {}
    for name, cls in PAIRS:
        structure = cls()
        out = S.measure_workload(structure, keys, values, queries)
        measured[name] = out["per_query_s"]
        make = el.ALL_PAPER_SPECS[name]
        sig = inspect.signature(make)
        spec = make(n) if "n_puts" in sig.parameters else make()
        predicted[name] = synthesis.cost(
            "get", spec, Workload(n_entries=n, n_queries=200), cpu_profile)

    # the scan-bound structures must be predicted slowest, indexed fastest
    slow = {"array", "linked_list"}
    fast = {"sorted_array", "btree", "skip_list"}
    for s in slow:
        for f in fast:
            assert predicted[s] > predicted[f], (s, f, predicted)
            assert measured[s] > measured[f], (s, f, measured)

    # rank correlation between predicted and measured orderings
    names = [name for name, _ in PAIRS]
    pred_rank = np.argsort(np.argsort([predicted[n] for n in names]))
    meas_rank = np.argsort(np.argsort([measured[n] for n in names]))
    rho = np.corrcoef(pred_rank, meas_rank)[0, 1]
    assert rho > 0.6, (predicted, measured)


@pytest.mark.slow
def test_synthesized_cost_grows_with_data(cpu_profile):
    """Fig. 6 x-axis: latency grows as data grows from 1e5 to 1e7."""
    spec = el.spec_btree()
    costs = [synthesis.cost("get", spec, Workload(n_entries=n), cpu_profile)
             for n in (10**5, 10**6, 10**7)]
    assert costs[0] < costs[2]
