"""What-if design questions and Algorithm-1 auto-completion (paper §4)."""
import dataclasses

import pytest

from repro.core import autocomplete, elements as el, whatif
from repro.core.autocomplete import DomainRegion, complete_design, design_hybrid
from repro.core.hardware import hw1, hw3
from repro.core.synthesis import Workload


W = Workload(n_entries=1_000_000, n_queries=100)


def test_what_if_hardware_faster_machine_wins(hw_analytical):
    ans = whatif.what_if_hardware(el.spec_btree(), W, hw1(), hw3())
    assert ans.beneficial          # HW3 is strictly faster in every constant
    assert ans.elapsed_seconds < 30.0  # "in a matter of seconds" (§5)


def test_what_if_bloom_filter_point_queries(hw_analytical):
    """§5: 'Would it be beneficial to add a bloom filter in all leaves?'
    For point Gets over a hash table with multi-page buckets, skipping
    pages via bloom filters must at least not hurt by much; the answer is
    computed, not guessed — we assert the engine answers quickly and
    consistently."""
    base = el.spec_hash_table()
    varied = whatif.add_bloom_filters(base)
    ans = whatif.what_if_design(base, varied, W, hw1())
    assert ans.baseline_seconds > 0 and ans.variant_seconds > 0
    again = whatif.what_if_design(base, varied, W, hw1())
    assert ans.beneficial == again.beneficial


def test_what_if_workload_skew(hw_analytical):
    skewed = dataclasses.replace(W, zipf_alpha=1.5)
    ans = whatif.what_if_workload(el.spec_btree(), W, skewed, hw1())
    assert ans.beneficial  # skew improves B-tree gets (Fig. 8b)


def test_what_if_workload_fused_path_reuses_cached_segments(hw_analytical):
    """The workload question rides pack_frontier + concat_frontiers like
    the design/hardware kinds: a repeat question is pure cache hits, and
    a new variant against the same baseline re-packs only the variant
    (the baseline segment is spliced from the cache)."""
    from repro.core import batchcost
    spec = el.spec_btree()
    skew1 = dataclasses.replace(W, zipf_alpha=1.2)
    skew2 = dataclasses.replace(W, zipf_alpha=1.5)
    batchcost.clear_caches()
    first = whatif.what_if_workload(spec, W, skew1, hw_analytical)
    seg_misses = batchcost.cache_info()["packed_spec"].misses
    assert seg_misses == 2            # (chain, W) + (chain, skew1)
    again = whatif.what_if_workload(spec, W, skew1, hw_analytical)
    info = batchcost.cache_info()
    assert info["packed_spec"].misses == seg_misses
    assert info["frontier"].hits >= 2     # both one-spec frontiers reused
    assert again.baseline_seconds == pytest.approx(
        first.baseline_seconds, rel=1e-12)
    assert again.variant_seconds == pytest.approx(
        first.variant_seconds, rel=1e-12)
    # a different variant against the same baseline packs ONE new segment
    whatif.what_if_workload(spec, W, skew2, hw_analytical)
    assert batchcost.cache_info()["packed_spec"].misses == seg_misses + 1
    # and the spliced fused answer still matches the scalar oracle
    scalar = whatif.what_if_workload(spec, W, skew1, hw_analytical,
                                     engine="scalar")
    assert first.baseline_seconds == pytest.approx(
        scalar.baseline_seconds, rel=1e-6)
    assert first.variant_seconds == pytest.approx(
        scalar.variant_seconds, rel=1e-6)
    assert first.beneficial == scalar.beneficial


def test_whatif_fused_parity_with_scalar(hw_analytical):
    """All three what-if kinds ride the batched/fused path by default;
    their answers must match the scalar cost_workload oracle to the fused
    engine's documented 1e-6 tolerance, verdicts included."""
    spec = el.spec_btree()
    mix = {"get": 80.0, "update": 20.0}
    skewed = dataclasses.replace(W, zipf_alpha=1.2)
    questions = [
        lambda engine: whatif.what_if_design(
            spec, whatif.add_bloom_filters(el.spec_hash_table()), W, hw1(),
            mix, engine=engine),
        lambda engine: whatif.what_if_hardware(
            spec, W, hw1(), hw3(), mix, engine=engine),
        lambda engine: whatif.what_if_workload(
            spec, W, skewed, hw1(), mix, engine=engine),
    ]
    for ask in questions:
        fused = ask("fused")
        scalar = ask("scalar")
        assert fused.baseline_seconds == pytest.approx(
            scalar.baseline_seconds, rel=1e-6)
        assert fused.variant_seconds == pytest.approx(
            scalar.variant_seconds, rel=1e-6)
        assert fused.beneficial == scalar.beneficial
        assert fused.question == scalar.question


def test_autocomplete_point_read_workload_prefers_index(hw_analytical):
    """A point-get workload must not complete to a bare linked list."""
    result = complete_design((), W, hw1(), mix={"get": 100.0}, max_depth=2)
    names = [e.name for e in result.spec.chain]
    assert names[-1] in ("ODP", "UDP")
    assert result.spec.chain[0].name != "LL"
    assert result.explored > 5


def test_autocomplete_respects_partial_prefix(hw_analytical):
    prefix = (el.hash_element(100),)
    result = complete_design(prefix, W, hw1(), mix={"get": 100.0},
                             max_depth=2)
    assert result.spec.chain[0].name == "Hash"


def test_autocomplete_memoization_dedupes_prefixes(hw_analytical):
    """The paper's cachedSolution: identical (prefix, level) sub-searches
    are solved once — duplicating candidates must not grow exploration."""
    pool = autocomplete.default_candidates()
    r1 = complete_design((), W, hw1(), candidates=pool,
                         mix={"get": 50.0}, max_depth=2)
    r2 = complete_design((), W, hw1(), candidates=pool + pool,
                         mix={"get": 50.0}, max_depth=2)
    assert r2.explored == r1.explored
    assert r2.cost_seconds == pytest.approx(r1.cost_seconds, rel=1e-9)


def test_autocomplete_range_workload_gets_ordered_terminal(hw_analytical):
    result = complete_design((), W, hw1(), mix={"range_get": 100.0},
                             max_depth=2)
    assert result.spec.terminal.name == "ODP" or \
        result.spec.terminal.sorted_keys


def test_design_hybrid_two_scenarios(hw_analytical):
    """Fig. 9: mixed point/range/write regions produce per-region designs."""
    regions = [
        DomainRegion("reads", 0.2, {"get": 100.0}),
        DomainRegion("writes", 0.8, {"bulk_load": 1.0, "update": 100.0}),
    ]
    design = design_hybrid(W, regions, hw1())
    assert len(design.regions) == 2
    assert design.cost_seconds > 0
    text = design.describe()
    assert "reads" in text and "writes" in text
