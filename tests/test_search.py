"""Unit coverage for PR 10: the relaxed continuum + population search.

Complements the random-input differential suite in
``tests/test_properties.py`` with targeted checks of the search stack:
budget accounting, the relax encode/decode bridge, the evolutionary
operators' validity envelope, the search loop's contracts (oracle
verification, determinism, never-re-pack, budget truncation), the
budgeted ``design_hillclimb``/``design_beam`` rewiring, and
``DesignCalculatorService.submit_search``.
"""
import random
import threading

import numpy as np
import pytest

from repro.core import batchcost, elements as el, relax, search
from repro.core.hardware import hw1
from repro.core.relax import RelaxedDesign, RelaxTemplate
from repro.core.synthesis import Workload, cost_workload

WORKLOAD = Workload(n_entries=1 << 16, n_queries=100)
MIX = {"get": 80.0, "update": 20.0}


# ---------------------------------------------------------------------------
# SearchBudget
# ---------------------------------------------------------------------------
def test_budget_charges_and_truncates():
    b = search.SearchBudget(10)
    assert b.charge(4) == 4
    assert b.spent == 4 and b.remaining == 6 and not b.exhausted
    assert b.charge(8) == 6          # truncated to the remaining grant
    assert b.exhausted
    with pytest.raises(search.BudgetExhausted):
        b.charge(1)
    assert b.charge(0) == 0          # zero-charge probe never raises


def test_budget_rejects_bad_arguments():
    with pytest.raises(ValueError):
        search.SearchBudget(0)
    with pytest.raises(ValueError):
        search.SearchBudget(5).charge(-1)


def test_budget_thread_safe_exact_total():
    b = search.SearchBudget(1000)
    granted = []

    def worker():
        local = 0
        while True:
            try:
                local += b.charge(7)
            except search.BudgetExhausted:
                break
        granted.append(local)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(granted) == 1000 == b.spent


# ---------------------------------------------------------------------------
# relax: encode/decode bridge
# ---------------------------------------------------------------------------
def test_decode_encode_roundtrip_exact():
    rng = random.Random(7)
    for template in search.DEFAULT_TEMPLATES:
        for _ in range(16):
            design = search.random_design(rng, template)
            spec = relax.decode(design)
            back = relax.encode(spec)
            assert back is not None
            assert relax.decode(back).chain == spec.chain


def test_encode_rejects_foreign_chains():
    assert relax.encode(el.spec_skip_list()) is None


def test_template_validation():
    with pytest.raises(ValueError):
        RelaxTemplate(("UDP", "B+"))          # terminal must come last
    with pytest.raises(ValueError):
        RelaxTemplate(("B+", "ODP"), bloom=True)   # bloom needs Hash root


def test_decode_respects_knob_floors():
    d = RelaxedDesign(RelaxTemplate(("B+", "ODP")), (-3.0, -3.0)).clipped()
    spec = relax.decode(d)
    fanout, capacity = spec.chain[0].fanout, spec.chain[-1].capacity
    assert fanout >= 2 and capacity >= 16


# ---------------------------------------------------------------------------
# Evolutionary operators stay inside the decodable family.
# ---------------------------------------------------------------------------
def test_mutate_and_crossover_always_decodable():
    rng = random.Random(11)
    pool = [search.random_design(rng, t) for t in search.DEFAULT_TEMPLATES]
    for _ in range(300):
        a, b = rng.choice(pool), rng.choice(pool)
        child = search.mutate(rng, search.crossover(rng, a, b),
                              sigma=0.8, structural_p=0.5)
        spec = relax.decode(child)        # raises if structurally invalid
        internals = len(child.template.levels) - 1
        assert internals <= search.MAX_INTERNAL_LEVELS
        assert cost_workload(spec, WORKLOAD, hw1(), MIX) > 0.0
        pool.append(child)


# ---------------------------------------------------------------------------
# population_search contracts
# ---------------------------------------------------------------------------
def test_population_search_verifies_and_stays_in_budget():
    hw = hw1()
    result = search.population_search(
        WORKLOAD, hw, MIX, budget=search.SearchBudget(64),
        population=8, generations=50, refine_top=2, refine_steps=2,
        seed=3)
    assert result["designs_costed"] <= 64
    oracle = cost_workload(result["design"], WORKLOAD, hw, MIX)
    assert abs(oracle - result["cost_s"]) / oracle <= search.ORACLE_RTOL
    assert result["oracle_cost_s"] is not None
    # best-so-far history is monotone non-increasing
    assert all(a >= b for a, b in zip(result["history"],
                                      result["history"][1:]))


def test_population_search_deterministic():
    hw = hw1()
    runs = [search.population_search(
        WORKLOAD, hw, MIX, budget=search.SearchBudget(48),
        population=8, generations=50, seed=5) for _ in range(2)]
    assert runs[0]["cost_s"] == runs[1]["cost_s"]
    assert runs[0]["design"].chain == runs[1]["design"].chain


def test_population_search_charges_only_fresh_chains():
    """The seen-set dedups across generations: total designs charged
    equals the number of distinct chains that reached the engine."""
    hw = hw1()
    scored = []

    def spy(specs):
        scored.extend(s.chain for s in specs)
        grid = batchcost.cost_sweep(specs, [WORKLOAD], hw, MIX)
        return np.asarray(grid, np.float64).mean(axis=0)

    result = search.population_search(
        WORKLOAD, hw, MIX, budget=search.SearchBudget(64),
        population=8, generations=50, seed=3, score_fn=spy)
    assert len(scored) == len(set(scored)) == result["designs_costed"]


def test_population_search_tiny_budget_raises():
    # the budget dies mid-generation-0 scoring with nothing reported
    with pytest.raises(search.BudgetExhausted):
        search.population_search(
            WORKLOAD, hw1(), MIX, budget=search.SearchBudget(1),
            population=8, generations=2, seed=0,
            score_fn=lambda specs: (_ for _ in ()).throw(
                search.BudgetExhausted("no engine call allowed")))


def test_population_search_multi_point_axis():
    hw = hw1()
    wls = [Workload(n_entries=1 << 14, n_queries=100),
           Workload(n_entries=1 << 16, n_queries=100)]
    result = search.population_search(
        WORKLOAD, hw, MIX, budget=search.SearchBudget(48),
        population=8, generations=20, seed=2, workloads=wls)
    mean_oracle = float(np.mean([
        cost_workload(result["design"], w, hw, MIX) for w in wls]))
    assert abs(mean_oracle - result["cost_s"]) / mean_oracle \
        <= search.ORACLE_RTOL


# ---------------------------------------------------------------------------
# Budgeted hillclimb/beam rewiring
# ---------------------------------------------------------------------------
def test_beam_unconstrained_budget_matches_unbudgeted():
    from repro.core.autocomplete import design_beam
    hw = hw1()
    free = design_beam(WORKLOAD, hw, MIX, beam_width=2, max_rounds=4)
    budget = search.SearchBudget(10_000)
    capped = design_beam(WORKLOAD, hw, MIX, beam_width=2, max_rounds=4,
                         budget=budget)
    assert capped["cost_s"] == free["cost_s"]
    assert capped["design"] == free["design"]
    assert budget.spent == capped["designs_costed"] \
        == free["designs_costed"]


def test_hillclimb_budget_truncates_and_accounts():
    from repro.core.autocomplete import design_hillclimb
    hw = hw1()
    budget = search.SearchBudget(9)
    result = design_hillclimb(WORKLOAD, hw, MIX, max_steps=6,
                              budget=budget)
    assert budget.spent <= 9
    assert result["designs_costed"] == budget.spent
    assert np.isfinite(result["cost_s"]) and result["cost_s"] > 0.0


def test_hillclimb_unconstrained_budget_matches_unbudgeted():
    from repro.core.autocomplete import design_hillclimb
    hw = hw1()
    free = design_hillclimb(WORKLOAD, hw, MIX, max_steps=4)
    budget = search.SearchBudget(10_000)
    capped = design_hillclimb(WORKLOAD, hw, MIX, max_steps=4,
                              budget=budget)
    assert capped["cost_s"] == free["cost_s"]
    assert capped["design"] == free["design"]
    assert budget.spent == capped["designs_costed"]


# ---------------------------------------------------------------------------
# The serving tier's submit_search
# ---------------------------------------------------------------------------
def test_service_submit_search_matches_direct(hw_analytical):
    from repro.serving.service import DesignCalculatorService
    direct = search.population_search(
        WORKLOAD, hw_analytical, MIX, budget=search.SearchBudget(48),
        population=8, generations=20, seed=4)
    with DesignCalculatorService([hw_analytical]) as svc:
        answer = svc.submit_search(
            WORKLOAD, hw_analytical, MIX, budget_designs=48,
            population=8, generations=20, seed=4).result(timeout=120)
        assert svc.stats()["searches"] == 1
    assert answer["cost_s"] == direct["cost_s"]
    assert answer["design"].chain == direct["design"].chain
    oracle = cost_workload(answer["design"], WORKLOAD, hw_analytical, MIX)
    assert abs(oracle - answer["cost_s"]) / oracle <= search.ORACLE_RTOL


def test_service_submit_search_deadline(hw_analytical):
    from repro.serving.admission import DeadlineExceeded
    from repro.serving.service import DesignCalculatorService
    with DesignCalculatorService([hw_analytical]) as svc:
        fut = svc.submit_search(
            WORKLOAD, hw_analytical, MIX, budget_designs=512,
            population=16, generations=200, seed=0,
            deadline_s=1e-4)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=120)
