"""Distributed integration tests.

The SPMD paths need >1 device, and the rest of the suite must see exactly
one CPU device (per the assignment), so these run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.launch.mesh import make_mesh
from repro.models import build
from repro.parallel import ctx
from repro.parallel.sharding import (batch_sharding, cache_shardings,
                                     param_shardings, state_shardings)
from repro.train.loop import init_state, make_train_step

assert len(jax.devices()) == 8
out = {}

cfg = get_smoke_config("qwen2-1.5b")
model = build(cfg)

# ---- single-device reference --------------------------------------------
rngk = jax.random.PRNGKey(0)
state_ref = init_state(model, rngk)
batch = {
    "tokens": jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (8, 32)), jnp.int32),
    "labels": jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (8, 32)), jnp.int32)}
step_ref = jax.jit(make_train_step(model, RunConfig()))
_, m_ref = step_ref(state_ref, batch)
out["loss_ref"] = float(m_ref["loss"])

# ---- multi-pod SPMD run ---------------------------------------------------
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
abstract = jax.eval_shape(lambda k: init_state(model, k), rngk)
state_sh = state_shardings(abstract, mesh)
batch_sh = {k: batch_sharding(mesh, 8, ndim=2) for k in batch}

with mesh, ctx.mesh_context(mesh), ctx.options(seq_parallel=True):
    jitted = jax.jit(make_train_step(model, RunConfig()),
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, NamedSharding(mesh, P())))
    lowered = jitted.lower(abstract, {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype) for k, v in batch.items()})
    compiled = lowered.compile()

hlo = compiled.as_text()
out["has_collectives"] = any(tag in hlo for tag in
                             ("all-reduce", "all-gather", "reduce-scatter"))

# run it for real on the 8 fake devices
state_dist = jax.device_put(state_ref, state_sh)
batch_dist = {k: jax.device_put(v, batch_sh[k]) for k, v in batch.items()}
state2, m_dist = compiled(state_dist, batch_dist)
out["loss_dist"] = float(m_dist["loss"])

# params sharded: at least one leaf is split across devices
n_sharded = sum(
    1 for leaf in jax.tree.leaves(state2.params)
    if hasattr(leaf, "sharding") and not leaf.sharding.is_fully_replicated)
out["n_sharded_param_leaves"] = n_sharded

# ---- elastic restart: checkpoint from (2,2,2) -> restore on (4,2) ---------
import tempfile
from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
ckpt_dir = tempfile.mkdtemp()
save_checkpoint(ckpt_dir, 1, state2)
new_mesh = make_mesh((4, 2), ("data", "model"))   # one pod "lost"
new_sh = state_shardings(abstract, new_mesh)
_, restored = restore_checkpoint(ckpt_dir, abstract, shardings=new_sh)
with new_mesh, ctx.mesh_context(new_mesh):
    jit2 = jax.jit(make_train_step(model, RunConfig()),
                   in_shardings=(new_sh, {k: batch_sharding(new_mesh, 8,
                                                            ndim=2)
                                          for k in batch}),
                   out_shardings=(new_sh, NamedSharding(new_mesh, P())))
    state3, m_remesh = jit2(restored, batch)
out["loss_remesh"] = float(m_remesh["loss"])

# ---- decode path with KV cache sharding -----------------------------------
params_sh = param_shardings(jax.eval_shape(lambda k: model.init(k), rngk),
                            mesh)
cache = jax.eval_shape(lambda: model.init_cache(8, 64))
c_sh = cache_shardings(cache, mesh, 8, cfg)
with mesh, ctx.mesh_context(mesh):
    serve = jax.jit(lambda p, c, t, q: model.decode_step(p, c, t, q),
                    in_shardings=(params_sh, c_sh,
                                  batch_sharding(mesh, 8, ndim=1),
                                  batch_sharding(mesh, 8, ndim=1)))
    lowered = serve.lower(
        jax.eval_shape(lambda k: model.init(k), rngk), cache,
        jax.ShapeDtypeStruct((8,), jnp.int32),
        jax.ShapeDtypeStruct((8,), jnp.int32))
    lowered.compile()
out["decode_compiles"] = True

print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_spmd_training_matches_single_device(tmp_path):
    script = tmp_path / "spmd_test.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["has_collectives"]
    assert out["n_sharded_param_leaves"] > 0
    assert out["decode_compiles"]
    assert abs(out["loss_dist"] - out["loss_ref"]) < 5e-3, out
    # elastic restart on a different mesh keeps the trajectory: the step-2
    # loss after remesh equals the step-2 loss the 3-axis mesh would see
    # (same state, same batch), i.e. close to the single-device trajectory
    assert abs(out["loss_remesh"] - out["loss_ref"]) < 0.5, out
