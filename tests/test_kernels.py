"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (deliverable c)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.bloom_probe.ops import DEFAULT_COEFFS, bloom_probe
from repro.kernels.bloom_probe.ref import bloom_probe_ref, build_filter
from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_bshd)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_probe.ops import DEFAULT_A, hash_probe
from repro.kernels.hash_probe.ref import build_table, hash_probe_ref
from repro.kernels.scan_filter.kernel import NOT_FOUND
from repro.kernels.scan_filter.ops import scan_filter, scan_get
from repro.kernels.sorted_search.ops import sorted_get, sorted_search
from repro.kernels.sorted_search.ref import sorted_search_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kh,sq,skv,d", [
    (1, 1, 1, 128, 128, 32),
    (2, 4, 2, 256, 256, 64),     # GQA group 2
    (1, 8, 1, 128, 512, 16),     # MQA
    (2, 4, 4, 200, 300, 24),     # ragged (padding path)
    (1, 2, 2, 384, 128, 128),    # q longer than kv
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, kh, sq, skv, d, causal, dtype,
                                     rng):
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kh, skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kh, skv, d)), dtype)
    got = np.asarray(flash_attention(q, k, v, causal), np.float32)
    want = np.asarray(attention_ref(q, k, v, causal=causal), np.float32)
    # causal rows with no visible keys are NaN in the ref (all -inf); the
    # kernel returns 0 there — compare only defined rows
    mask = np.isfinite(want)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got[mask], want[mask], rtol=tol, atol=tol)


def test_flash_attention_bshd_layout(rng):
    q = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    got = flash_attention_bshd(q, k, v, causal=True)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_runs(rng):
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 16)), jnp.float32)

    def loss(q, k, v):
        return flash_attention(q, k, v, True).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    ref_grads = jax.grad(
        lambda q, k, v: attention_ref(q, k, v, causal=True).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for g, r in zip(grads, ref_grads):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# sorted search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,q", [(512, 256), (1000, 300), (64, 1000),
                                 (4096, 512)])
@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.float32])
def test_sorted_search_matches_ref(n, q, dtype, rng):
    keys = np.sort(rng.integers(0, 1 << 20, n)).astype(dtype)
    queries = rng.integers(-5, 1 << 20, q).astype(dtype)
    got = sorted_search(jnp.asarray(keys), jnp.asarray(queries))
    want = sorted_search_ref(jnp.asarray(keys), jnp.asarray(queries))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sorted_get_point_lookup(rng):
    keys = np.sort(rng.choice(1 << 16, 700, replace=False)).astype(np.int32)
    values = (keys * 3 + 1).astype(np.int32)
    hits = keys[rng.integers(0, len(keys), 100)]
    found, val = sorted_get(jnp.asarray(keys), jnp.asarray(values),
                            jnp.asarray(hits))
    assert bool(np.asarray(found).all())
    np.testing.assert_array_equal(np.asarray(val), hits * 3 + 1)
    found, _ = sorted_get(jnp.asarray(keys), jnp.asarray(values),
                          jnp.asarray(np.asarray([1 << 20], np.int32)))
    assert not bool(np.asarray(found).any())


# ---------------------------------------------------------------------------
# scan filter
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,q", [(512, 256), (1500, 100), (128, 770)])
def test_scan_filter_matches_ref(n, q, rng):
    from repro.kernels.scan_filter.ref import scan_filter_ref
    keys = rng.integers(0, 1 << 16, n).astype(np.int32)
    queries = rng.integers(0, 1 << 16, q).astype(np.int32)
    lo, hi = queries - 64, queries + 64
    got = scan_filter(jnp.asarray(keys), jnp.asarray(queries),
                      jnp.asarray(lo), jnp.asarray(hi))
    want = scan_filter_ref(jnp.asarray(keys), jnp.asarray(queries),
                           jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_scan_get_finds_first_duplicate(rng):
    keys = np.asarray([5, 3, 5, 7, 3, 9] * 100, np.int32)
    values = np.arange(len(keys), dtype=np.int32)
    found, val = scan_get(jnp.asarray(keys), jnp.asarray(values),
                          jnp.asarray(np.asarray([5, 3, 11], np.int32)))
    assert np.asarray(found).tolist() == [True, True, False]
    assert np.asarray(val).tolist()[:2] == [0, 1]   # first occurrences


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,cap,n", [(6, 32, 500), (8, 16, 1000),
                                     (10, 8, 2000)])
def test_hash_probe_matches_ref(s, cap, n, rng):
    keys = rng.choice(1 << 20, n, replace=False).astype(np.int64)
    values = rng.integers(1, 1 << 30, n).astype(np.int32)
    tk, tv = build_table(keys, values, s, DEFAULT_A, cap)
    queries = np.concatenate([keys[: n // 2],
                              rng.integers(1 << 21, 1 << 22, 100)])
    found, val = hash_probe(jnp.asarray(tk), jnp.asarray(tv),
                            jnp.asarray(queries.astype(np.int32)), s=s)
    pos_r, val_r = hash_probe_ref(tk, tv, queries.astype(np.int32),
                                  DEFAULT_A, s)
    np.testing.assert_array_equal(np.asarray(found), pos_r != 2147483647)
    np.testing.assert_array_equal(np.asarray(val), val_r)


# ---------------------------------------------------------------------------
# bloom probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,k", [(13, 1), (15, 2), (16, 4)])
def test_bloom_probe_matches_ref(s, k, rng):
    keys = rng.choice(1 << 24, 2000, replace=False).astype(np.int64)
    words = build_filter(keys, DEFAULT_COEFFS[:k], s)
    queries = np.concatenate([keys[:500],
                              rng.integers(1 << 25, 1 << 26, 500)])
    got = bloom_probe(jnp.asarray(words),
                      jnp.asarray(queries.astype(np.int32)), s=s,
                      num_hashes=k)
    want = bloom_probe_ref(words, queries.astype(np.int32),
                           DEFAULT_COEFFS[:k], s)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_bloom_no_false_negatives(rng):
    """The defining bloom filter property, end to end through the kernel."""
    keys = rng.choice(1 << 22, 3000, replace=False).astype(np.int64)
    words = build_filter(keys, DEFAULT_COEFFS[:3], 16)
    member = bloom_probe(jnp.asarray(words),
                         jnp.asarray(keys.astype(np.int32)), s=16,
                         num_hashes=3)
    assert bool(np.asarray(member).all())
