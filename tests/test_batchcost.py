"""Batch cost-synthesis engine: scalar equivalence, memo invalidation,
and batched-search parity (the PR's tentpole acceptance checks)."""
import dataclasses
import inspect

import numpy as np
import pytest

from repro.core import batchcost, elements as el, synthesis
from repro.core.autocomplete import (complete_design, default_candidates,
                                     design_hillclimb)
from repro.core.batchcost import (compiled_operation, cost_many,
                                  cost_workload_batched)
from repro.core.synthesis import Workload, cost_workload, instantiate


def _grid_specs():
    specs = []
    for name, make in sorted(el.ALL_PAPER_SPECS.items()):
        sig = inspect.signature(make)
        specs.append(make(10_000) if "n_puts" in sig.parameters else make())
    return specs


GRID_WORKLOADS = [
    Workload(n_entries=10_000),                          # uniform
    Workload(n_entries=250_000, zipf_alpha=1.5),         # skewed
    Workload(n_entries=1_000_000, selectivity=0.01),     # wide ranges
]
GRID_MIXES = [
    None,
    {"get": 100.0},
    {"get": 50.0, "range_get": 25.0, "update": 25.0, "bulk_load": 1.0},
]


@pytest.mark.parametrize("workload", GRID_WORKLOADS,
                         ids=["uniform", "zipf", "ranges"])
@pytest.mark.parametrize("mix", GRID_MIXES, ids=["default", "get", "mixed"])
def test_cost_many_matches_scalar_grid(workload, mix, hw_analytical):
    """Engine contract on the full paper spec library x workload x mix
    grid: the grouped oracle == scalar cost_workload to 1e-9 relative
    (identical per-record predictions, only summation order differs); the
    fused device-resident engine matches the oracle to 1e-6 relative (its
    float32 banked evaluation is documented in repro.core.devicecost) with
    the identical argmin design."""
    specs = _grid_specs()
    grouped = cost_many(specs, workload, hw_analytical, mix,
                        engine="grouped")
    fused = cost_many(specs, workload, hw_analytical, mix)
    scalar = np.array([cost_workload(s, workload, hw_analytical, mix)
                       for s in specs])
    assert grouped.shape == fused.shape == (len(specs),)
    np.testing.assert_allclose(grouped, scalar, rtol=1e-9)
    np.testing.assert_allclose(fused, grouped, rtol=1e-6)
    assert int(np.argmin(fused)) == int(np.argmin(grouped))


def test_cost_workload_batched_single_spec(hw_analytical):
    w = Workload(n_entries=500_000)
    spec = el.spec_btree()
    assert cost_workload_batched(spec, w, hw_analytical) == pytest.approx(
        cost_workload(spec, w, hw_analytical), rel=1e-9)


def test_instantiate_memoized_and_invalidates_on_workload_change():
    from repro.core.synthesis import _instantiate_levels
    spec = el.spec_btree(fanout=20, page=250)
    w1 = Workload(n_entries=100_000)
    w2 = Workload(n_entries=100_000, zipf_alpha=1.5)
    w3 = Workload(n_entries=400_000)
    synthesis.clear_synthesis_caches()
    i1a = instantiate(spec, w1)
    misses = _instantiate_levels.cache_info().misses
    i1b = instantiate(spec, w1)
    # same workload -> served from the memo, not re-simulated
    assert _instantiate_levels.cache_info().misses == misses
    assert _instantiate_levels.cache_info().hits >= 1
    # ... but as caller-owned copies: mutations must not poison the cache
    i1b.levels[0].region_bytes *= 100.0
    assert instantiate(spec, w1).levels[0].region_bytes == \
        i1a.levels[0].region_bytes
    # workload change -> fresh simulation (zipf is part of the key even
    # though it does not alter geometry; n_entries does alter it)
    assert _instantiate_levels.cache_info().misses == misses
    instantiate(spec, w2)
    assert _instantiate_levels.cache_info().misses == misses + 1
    assert instantiate(spec, w3).terminal.n_nodes != i1a.terminal.n_nodes


def test_instantiate_name_insensitive():
    """Chains are the fingerprint; the spec *name* must not split the cache
    (searches relabel identical chains per region)."""
    from repro.core.synthesis import _instantiate_levels
    w = Workload(n_entries=100_000)
    instantiate(el.spec_btree(), w)
    misses = _instantiate_levels.cache_info().misses
    chain = el.spec_btree().chain
    instantiate(el.DataStructureSpec("renamed", chain), w)
    assert _instantiate_levels.cache_info().misses == misses


def test_compiled_operation_cached_and_workload_keyed():
    spec = el.spec_hash_table()
    w1 = Workload(n_entries=50_000)
    w2 = Workload(n_entries=50_000, n_queries=1000)
    c1 = compiled_operation("get", spec, w1)
    assert compiled_operation("get", spec, w1) is c1
    assert compiled_operation("get", spec, w2) is not c1


def test_compiled_breakdown_matches_breakdown_total(hw_analytical):
    w = Workload(n_entries=200_000)
    for op in ("get", "range_get", "update", "bulk_load"):
        cb = synthesis.synthesize_operation(op, el.spec_btree(), w)
        comp = batchcost.compile_breakdown(cb)
        assert comp.n_records == len(cb.records)
        assert comp.total(hw_analytical) == pytest.approx(
            cb.total(hw_analytical), rel=1e-9)


def test_batched_search_equals_scalar_search(hw_analytical):
    """complete_design returns the identical argmin design through every
    costing path — fused (default), grouped oracle, and scalar — with
    totals to the engines' documented tolerances."""
    w = Workload(n_entries=1_000_000)
    mix = {"get": 80.0, "update": 20.0}
    rf = complete_design((), w, hw_analytical, mix=mix, max_depth=2)
    rg = complete_design((), w, hw_analytical, mix=mix, max_depth=2,
                         engine="grouped")
    rs = complete_design((), w, hw_analytical, mix=mix, max_depth=2,
                         batched=False)
    assert rf.spec.describe() == rg.spec.describe() == rs.spec.describe()
    assert rf.explored == rg.explored == rs.explored
    assert rg.cost_seconds == pytest.approx(rs.cost_seconds, rel=1e-9)
    assert rf.cost_seconds == pytest.approx(rs.cost_seconds, rel=1e-6)


def test_batched_search_respects_prefix_and_pool_duplicates(hw_analytical):
    w = Workload(n_entries=1_000_000)
    pool = default_candidates()
    r1 = complete_design((el.hash_element(100),), w, hw_analytical,
                         candidates=pool, mix={"get": 50.0}, max_depth=2)
    r2 = complete_design((el.hash_element(100),), w, hw_analytical,
                         candidates=pool + pool, mix={"get": 50.0},
                         max_depth=2)
    assert r1.spec.chain[0].name == "Hash"
    assert r2.explored == r1.explored
    assert r2.cost_seconds == pytest.approx(r1.cost_seconds, rel=1e-9)


def test_design_hillclimb_batched_equals_scalar(hw_analytical):
    """The greedy climb takes the identical path through every costing
    path and improves (or matches) its starting design."""
    w = Workload(n_entries=200_000)
    mix = {"get": 60.0, "update": 40.0}
    start_cost = cost_workload(el.spec_btree(), w, hw_analytical, mix)
    f = design_hillclimb(w, hw_analytical, mix, max_steps=10)
    g = design_hillclimb(w, hw_analytical, mix, max_steps=10,
                         engine="grouped")
    s = design_hillclimb(w, hw_analytical, mix, max_steps=10, batched=False)
    assert (f["design"], f["fanouts"]) == (s["design"], s["fanouts"])
    assert (g["design"], g["fanouts"]) == (s["design"], s["fanouts"])
    assert g["cost_s"] == pytest.approx(s["cost_s"], rel=1e-9)
    assert f["cost_s"] == pytest.approx(s["cost_s"], rel=1e-6)
    assert f["cost_s"] <= start_cost * (1 + 1e-6)
    assert f["designs_costed"] > 1


def test_cost_many_empty_frontier(hw_analytical):
    out = cost_many([], Workload(n_entries=1000), hw_analytical)
    assert out.shape == (0,)


def test_cost_many_trained_profile_equivalence(cpu_profile):
    """Equivalence also holds on a *trained* (non-analytical) profile,
    through both engines."""
    w = Workload(n_entries=100_000, zipf_alpha=0.8)
    mix = {"get": 10.0, "update": 5.0}
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list()]
    grouped = cost_many(specs, w, cpu_profile, mix, engine="grouped")
    fused = cost_many(specs, w, cpu_profile, mix)
    scalar = [cost_workload(s, w, cpu_profile, mix) for s in specs]
    np.testing.assert_allclose(grouped, scalar, rtol=1e-9)
    np.testing.assert_allclose(fused, grouped, rtol=1e-6)


def test_cache_hits_grow_across_cost_many_calls(hw_analytical):
    """Smoke check for the cache keys: repeated cost_many calls over the
    same frontier must be served from the packing/synthesis memos — a hit
    count that stops growing means a cache key regressed (e.g. an unhashed
    field sneaking into the key, or a cache cleared per call)."""
    batchcost.clear_caches()
    w = Workload(n_entries=77_000)
    mix = {"get": 10.0, "update": 2.0}
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list()]
    cost_many(specs, w, hw_analytical, mix)
    cold = batchcost.cache_info()
    # the cold call exercised every layer of the vectorized packer: one
    # statics resolution and one packed segment per spec, one frontier
    assert cold["chain_statics"].misses == len(specs)
    assert cold["packed_spec"].misses == len(specs)
    assert cold["frontier"].misses == 1
    before_hits = cold["frontier"].hits
    before_misses = {k: v.misses for k, v in cold.items()}
    for i in range(3):
        cost_many(specs, w, hw_analytical, mix)
        info = batchcost.cache_info()
        # every repeat is served whole from the frontier memo...
        assert info["frontier"].hits == before_hits + (i + 1)
        # ... with zero new misses anywhere beneath it
        assert {k: v.misses for k, v in info.items()} == before_misses
    # a changed frontier reuses the retained per-spec segments: only the
    # new chain synthesizes (incremental packing)
    cost_many(specs + [el.spec_trie()], w, hw_analytical, mix)
    info = batchcost.cache_info()
    assert info["packed_spec"].misses == before_misses["packed_spec"] + 1
    assert info["packed_spec"].hits >= len(specs)
    assert info["chain_statics"].misses == \
        before_misses["chain_statics"] + 1


def test_clear_caches_empties_every_memo(hw_analytical):
    """clear_caches must drain every layer of the synthesis/packing cache
    stack — template, segment, frontier, schema and enumeration memos
    included (a stale layer would survive element-library edits)."""
    from repro.core.autocomplete import complete_design
    w = Workload(n_entries=33_000)
    complete_design((), w, hw_analytical, mix={"get": 5.0}, max_depth=2)
    cost_many([el.spec_btree()], w, hw_analytical,
              {"get": 1.0, "bulk_load": 1.0}, engine="grouped")
    batchcost.cost_one("get", el.spec_btree(), w, hw_analytical)
    info = batchcost.cache_info()
    for layer in ("chain_statics", "segment_statics", "packed_spec",
                  "frontier", "symbolic_breakdown", "enumerate",
                  "compiled_operation", "instantiate"):
        assert info[layer].misses + info[layer].hits > 0, layer
    batchcost.clear_caches()
    for layer, stats in batchcost.cache_info().items():
        assert stats.hits == 0 and stats.misses == 0, layer
        assert stats.currsize == 0, layer


def test_hardware_not_in_any_synthesis_key(hw_analytical, cpu_profile):
    """The paper's what-if-hardware contract: scoring one packed frontier
    on a second profile must touch no synthesis/packing code at all."""
    from repro.core.batchcost import pack_frontier
    batchcost.clear_caches()
    w = Workload(n_entries=120_000)
    mix = {"get": 8.0, "update": 2.0}
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_trie()]
    packed = pack_frontier(specs, w, mix)
    before = {k: (v.hits, v.misses) for k, v in batchcost.cache_info().items()}
    a = packed.score(hw_analytical)
    b = packed.score(cpu_profile)
    assert {k: (v.hits, v.misses) for k, v in
            batchcost.cache_info().items()} == before
    assert a.shape == b.shape == (len(specs),)
    # and re-packing for the other profile is pure cache hits
    assert pack_frontier(specs, w, mix) is packed


def test_empty_and_degenerate_frontiers(hw_analytical):
    """cost_many([]) / pack_frontier([]) / concat_frontiers([]) return
    empty results instead of crashing inside packing or the fused scorer
    — the serving engine must tolerate windows whose evaluations are all
    empty, and splicing empty parts must be the identity."""
    w = Workload(n_entries=10_000)
    assert cost_many([], w, hw_analytical).shape == (0,)
    empty = batchcost.pack_frontier([], w)
    assert empty.n_segments == 0 and len(empty.ids) == 0
    for engine in ("fused", "grouped"):
        assert empty.score(hw_analytical, engine=engine).shape == (0,)
    assert batchcost.concat_frontiers([]).n_segments == 0
    assert batchcost.concat_frontiers([empty, empty]).n_segments == 0
    assert batchcost.concat_frontiers(
        [empty, empty]).score(hw_analytical).shape == (0,)
    # empty parts splice away without disturbing real designs
    packed = batchcost.pack_frontier([el.spec_btree()], w)
    spliced = batchcost.concat_frontiers([empty, packed, empty])
    assert spliced.n_segments == 1
    np.testing.assert_allclose(spliced.score(hw_analytical),
                               packed.score(hw_analytical), rtol=0)


def test_memo_layer_consistent_under_threads(hw_analytical):
    """The module-level memos (segment/frontier dict caches, lru layers,
    device-table and interning state) are shared by every serving thread;
    concurrent scoring racing cache_info()/clear_caches() must neither
    raise nor corrupt the hit/miss accounting."""
    import threading

    batchcost.clear_caches()
    w = Workload(n_entries=50_000)
    mix = {"get": 10.0, "update": 2.0}
    specs = _grid_specs()
    errors = []

    def score_loop():
        try:
            for _ in range(12):
                totals = cost_many(specs, w, hw_analytical, mix)
                assert totals.shape == (len(specs),)
                batchcost.cache_info()
        except Exception as exc:    # pragma: no cover - failure path
            errors.append(exc)

    def churn_loop():
        try:
            for _ in range(6):
                batchcost.clear_caches()
                info = batchcost.cache_info()
                assert all(v.hits >= 0 and v.misses >= 0
                           for v in info.values())
        except Exception as exc:    # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=score_loop) for _ in range(6)]
    threads.append(threading.Thread(target=churn_loop))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # the storm must leave values correct and counters coherent
    scalar = np.array([cost_workload(s, w, hw_analytical, mix)
                       for s in specs])
    np.testing.assert_allclose(cost_many(specs, w, hw_analytical, mix),
                               scalar, rtol=1e-6)
    info = batchcost.cache_info()
    assert info["packed_spec"].currsize <= len(specs)
    batchcost.clear_caches()
    for layer, stats in batchcost.cache_info().items():
        assert stats.hits == 0 and stats.misses == 0, layer
        assert stats.currsize == 0, layer
