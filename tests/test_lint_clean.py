"""The live tree must lint clean: repro-lint runs as part of tier-1, so
a new concurrency/cache-key/jit-safety violation fails CI here."""
import pytest

pytestmark = pytest.mark.lint


def test_repo_lints_clean():
    from tools.analyze import DEFAULT_PATHS, run_paths
    findings = run_paths(DEFAULT_PATHS)
    assert findings == [], \
        "repro-lint found new violations:\n" + \
        "\n".join(f.format() for f in findings)
