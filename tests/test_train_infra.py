"""Training infrastructure: optimizer, grad accumulation, chunked CE,
checkpointing, data pipeline, fault tolerance, distcalc."""
import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.base import RunConfig, SHAPES
from repro.core import distcalc
from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,
                                         restore_checkpoint,
                                         save_checkpoint)
from repro.data.pipeline import DataPipeline, make_batch, synthetic_batch
from repro.models import build
from repro.models import layers as L
from repro.optim.adamw import (adamw_init, adamw_update, apply_updates,
                               clip_by_global_norm, cosine_schedule)
from repro.train import ft
from repro.train.loop import (chunked_cross_entropy, cross_entropy_loss,
                              init_state, make_train_step)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    run = RunConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                    weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        updates, state = adamw_update(grads, state, params, run)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    got = float(jnp.sqrt((clipped["a"] ** 2).sum()))
    assert got == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_warmup_and_decay():
    run = RunConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr0 = float(cosine_schedule(jnp.asarray(1), run))
    lr_peak = float(cosine_schedule(jnp.asarray(10), run))
    lr_end = float(cosine_schedule(jnp.asarray(100), run))
    assert lr0 < lr_peak
    assert lr_end < lr_peak
    assert lr_end >= 0.09 * run.learning_rate  # 10% floor


# ---------------------------------------------------------------------------
# chunked CE + gradient accumulation equivalences
# ---------------------------------------------------------------------------
def test_chunked_ce_matches_naive():
    cfg = get_smoke_config("qwen2-1.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 64
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    x, _ = model.forward(params, tokens, hidden=True)
    naive = cross_entropy_loss(L.unembed(params["embed"], x, cfg), labels)
    import repro.train.loop as loop
    old = loop.CE_CHUNK
    loop.CE_CHUNK = 16
    try:
        chunked = chunked_cross_entropy(x, params["embed"], labels, cfg)
    finally:
        loop.CE_CHUNK = old
    np.testing.assert_allclose(float(chunked), float(naive), rtol=1e-5)


def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("qwen2-1.5b")
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32)}
    full = jax.jit(make_train_step(model, RunConfig()))
    accum = jax.jit(make_train_step(model, RunConfig(microbatch=2)))
    s1, m1 = full(state, batch)
    s2, m2 = accum(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-3)
    leaves1 = jax.tree.leaves(s1.params)
    leaves2 = jax.tree.leaves(s2.params)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"loss": 1.5})
    assert latest_step(str(tmp_path)) == 7
    step, restored = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(8.0))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory must never be visible as a checkpoint."""
    os.makedirs(tmp_path / "step_00000003.tmp")
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 4, {"x": jnp.zeros(2)})
    assert latest_step(str(tmp_path)) == 4


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, {"x": jnp.full((4,), float(step))})
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    _, restored = restore_checkpoint(str(tmp_path), {"x": jnp.zeros(4)})
    assert float(restored["x"][0]) == 4.0


def test_restart_resumes_training(tmp_path):
    """Kill-and-restart: restore reproduces the exact state."""
    cfg = get_smoke_config("qwen2-1.5b")
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, RunConfig()))
    batch = {k: jnp.asarray(v) for k, v in {
        "tokens": np.ones((2, 16), np.int32),
        "labels": np.ones((2, 16), np.int32)}.items()}
    state, _ = step_fn(state, batch)
    save_checkpoint(str(tmp_path), 1, state)
    # "crash"; restart from disk
    template = jax.eval_shape(lambda: init_state(model,
                                                 jax.random.PRNGKey(0)))
    step, restored = restore_checkpoint(str(tmp_path), template)
    state2, m2 = step_fn(restored, batch)
    state1, m1 = step_fn(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_batch_deterministic_and_sharded():
    full = synthetic_batch(step=3, batch=8, seq_len=16, vocab=100)
    again = synthetic_batch(step=3, batch=8, seq_len=16, vocab=100)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    other_step = synthetic_batch(step=4, batch=8, seq_len=16, vocab=100)
    assert not np.array_equal(full["tokens"], other_step["tokens"])
    s0 = synthetic_batch(step=3, batch=8, seq_len=16, vocab=100,
                         shard=0, n_shards=2)
    s1 = synthetic_batch(step=3, batch=8, seq_len=16, vocab=100,
                         shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    assert (full["tokens"] < 100).all()


def test_pipeline_prefetch_and_restart_safety():
    cfg = get_smoke_config("qwen2-1.5b")
    shape = dataclasses.replace(SHAPES["train_4k"], global_batch=2,
                                seq_len=16)
    pipe = DataPipeline(cfg, shape, start_step=5)
    step, batch = next(pipe)
    pipe.close()
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"],
                                  make_batch(cfg, shape, 5)["tokens"])


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_straggler_detection():
    det = ft.StragglerDetector(threshold=2.0)
    for _ in range(20):
        for w in ("w0", "w1", "w2", "w3"):
            det.observe(w, 1.0)
        det.observe("slow", 5.0)
    assert det.stragglers() == ["slow"]


def test_heartbeat_dead_workers(tmp_path):
    mon = ft.HeartbeatMonitor(str(tmp_path), timeout_seconds=60)
    mon.beat("w0")
    assert mon.dead_workers(["w0", "w1"]) == ["w1"]


def test_elastic_remesh_plan():
    plan = ft.plan_elastic_remesh(available_pods=3, pod_shape=(16, 16),
                                  global_batch=256, old_pods=4)
    assert plan.new_pods == 2 and plan.valid()
    assert plan.mesh_shape == (2, 16, 16)
    assert plan.per_pod_batch == 128
    single = ft.plan_elastic_remesh(1, (16, 16), 256, 2)
    assert single.mesh_shape == (16, 16)


def test_ft_manager_restart_decision(tmp_path):
    mgr = ft.FaultToleranceManager(
        heartbeat=ft.HeartbeatMonitor(str(tmp_path), timeout_seconds=60),
        stragglers=ft.StragglerDetector(),
        checkpoint_dir=str(tmp_path), workers=("w0", "w1"))
    mgr.on_step("w0", 1.0)
    assert mgr.should_restart()          # w1 never reported
    mgr.on_step("w1", 1.0)
    assert not mgr.should_restart()


# ---------------------------------------------------------------------------
# distributed data calculator
# ---------------------------------------------------------------------------
def test_distcalc_invalidation_rules():
    cfg = get_config("qwen2-1.5b")
    shape = SHAPES["train_4k"]
    mesh = distcalc.MeshSpec()
    bad_tp = distcalc.Strategy(tp=32)
    assert distcalc.invalid_reasons(cfg, shape, mesh, bad_tp)
    bad_ep = distcalc.Strategy(tp=1, ep=True)
    assert any("MoE" in e for e in
               distcalc.invalid_reasons(cfg, shape, mesh, bad_ep))


def test_distcalc_terms_positive_and_fsdp_saves_memory():
    cfg = get_config("llama3-405b")
    shape = SHAPES["train_4k"]
    mesh = distcalc.MeshSpec()
    fsdp = distcalc.synthesize(cfg, shape, mesh,
                               distcalc.Strategy(tp=16, fsdp=True, ep=False))
    dp = distcalc.synthesize(cfg, shape, mesh,
                             distcalc.Strategy(tp=16, fsdp=False, ep=False))
    for terms in (fsdp, dp):
        assert terms.compute_s > 0 and terms.memory_s > 0
    assert fsdp.hbm_bytes_per_chip < dp.hbm_bytes_per_chip


def test_distcalc_autocomplete_returns_fitting_strategy():
    cfg = get_config("llama3-405b")
    shape = SHAPES["train_4k"]
    mesh = distcalc.MeshSpec(pods=2)
    strat, terms = distcalc.complete_strategy(cfg, shape, mesh)
    assert distcalc.fits_memory(cfg, shape, mesh, strat)
    assert terms.step_seconds > 0


def test_distcalc_what_if_more_pods_speeds_up_compute_bound():
    cfg = get_config("qwen1.5-32b")
    shape = SHAPES["train_4k"]
    out = distcalc.what_if_mesh(cfg, shape, distcalc.MeshSpec(pods=1),
                                distcalc.MeshSpec(pods=2))
    assert out["variant_step_s"] <= out["base_step_s"] * 1.05


def test_distcalc_moe_uses_ep():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    shape = SHAPES["train_4k"]
    strat, _ = distcalc.complete_strategy(cfg, shape, distcalc.MeshSpec())
    assert strat.ep


def test_grad_compression_close_to_fp32():
    """bf16 gradient reduction tracks the fp32 path within bf16 tolerance."""
    cfg = get_smoke_config("qwen2-1.5b")
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32)}
    full = jax.jit(make_train_step(model, RunConfig()))
    comp = jax.jit(make_train_step(model, RunConfig(grad_compression=True)))
    _, m1 = full(state, batch)
    _, m2 = comp(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=2e-2)


def test_bf16_moments_train_step_finite():
    cfg = get_smoke_config("qwen2-1.5b")
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0), jnp.bfloat16)
    assert jax.tree.leaves(state.opt.mu)[0].dtype == jnp.bfloat16
    step = jax.jit(make_train_step(model, RunConfig()))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert jax.tree.leaves(state.opt.mu)[0].dtype == jnp.bfloat16
