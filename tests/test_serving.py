"""Concurrent what-if serving engine (repro.serving) — correctness under
coalescing, sessions, threads, and degenerate traffic."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import batchcost, devicecost, elements as el, whatif
from repro.core.hardware import analytical_profile, hw1, hw2, hw3
from repro.core.synthesis import Workload, cost_workload
from repro.serving import DesignCalculatorService

W = Workload(n_entries=200_000, n_queries=100)
SKEWED = dataclasses.replace(W, zipf_alpha=1.5)
GROWN = dataclasses.replace(W, n_entries=800_000)


@pytest.fixture()
def profiles():
    return hw1(), hw2(), hw3()


def _service(profiles, **kwargs):
    kwargs.setdefault("window_s", 0.002)
    return DesignCalculatorService(list(profiles), **kwargs)


def _mixed_questions(h1, h2, h3):
    """(kind, *args) tuples covering all three what-if kinds, several
    specs, two workload variants and two hardware swaps."""
    specs = [el.spec_btree(), el.spec_hash_table(), el.spec_skip_list(),
             el.spec_trie()]
    bloomed = whatif.add_bloom_filters(el.spec_hash_table())
    qs = []
    for i, spec in enumerate(specs):
        qs.append(("design", spec, bloomed, W, h1))
        qs.append(("hardware", spec, W, h1, (h2, h3)[i % 2]))
        qs.append(("workload", spec, W, (SKEWED, GROWN)[i % 2], h2))
    return qs


def _ask(service, q):
    kind = q[0]
    if kind == "design":
        return service.what_if_design(*q[1:])
    if kind == "hardware":
        return service.what_if_hardware(*q[1:])
    return service.what_if_workload(*q[1:])


def _scalar(q):
    kind = q[0]
    fn = {"design": whatif.what_if_design,
          "hardware": whatif.what_if_hardware,
          "workload": whatif.what_if_workload}[kind]
    return fn(*q[1:], engine="scalar")


def _assert_matches(got, oracle):
    assert got.baseline_seconds == pytest.approx(
        oracle.baseline_seconds, rel=1e-6)
    assert got.variant_seconds == pytest.approx(
        oracle.variant_seconds, rel=1e-6)
    assert got.beneficial == oracle.beneficial
    assert got.question == oracle.question


def test_service_answers_match_scalar_oracle(profiles):
    h1, h2, h3 = profiles
    with _service(profiles) as svc:
        for q in _mixed_questions(h1, h2, h3):
            _assert_matches(_ask(svc, q), _scalar(q))


def test_service_grouped_engine_parity(profiles):
    h1, h2, h3 = profiles
    with _service(profiles, engine="grouped") as svc:
        q = ("design", el.spec_btree(), el.spec_csb_tree(), W, h1)
        got = _ask(svc, q)
        oracle = _scalar(q)
        assert got.baseline_seconds == pytest.approx(
            oracle.baseline_seconds, rel=1e-9)
        assert got.variant_seconds == pytest.approx(
            oracle.variant_seconds, rel=1e-9)


def test_service_complete_design_matches_direct(profiles):
    from repro.core.autocomplete import complete_design
    h1, _, _ = profiles
    with _service(profiles) as svc:
        got = svc.complete_design((), W, h1, mix={"get": 100.0},
                                  max_depth=2)
    direct = complete_design((), W, h1, mix={"get": 100.0}, max_depth=2)
    assert got.cost_seconds == pytest.approx(direct.cost_seconds, rel=1e-6)
    assert got.explored == direct.explored


def test_service_complete_design_no_completion_fails_future(profiles):
    h1, _, _ = profiles
    with _service(profiles) as svc:
        # a non-terminal element as the only "terminal" admits no chain
        fut = svc.submit_complete((), W, h1,
                                  terminals=[el.hash_element(100)],
                                  max_depth=1)
        with pytest.raises(RuntimeError, match="no valid completion"):
            fut.result()


def test_concurrent_mixed_questions_match_scalar_oracle(profiles):
    """The ISSUE acceptance test: N threads issuing mixed design /
    hardware / workload questions through the service all match the
    serial scalar oracle to 1e-6, and the fused scorer never retraces —
    hardware-swap requests included (``max_batch=1`` keeps every batch
    shape identical to the single-threaded warm pass)."""
    h1, h2, h3 = profiles
    questions = _mixed_questions(h1, h2, h3)
    oracles = [_scalar(q) for q in questions]
    with _service(profiles, window_s=0.0, max_batch=1) as svc:
        for q in questions:            # warm pass compiles every shape
            _ask(svc, q)
        traces_before = devicecost.trace_count()
        n_threads = 4
        results = [[None] * len(questions) for _ in range(n_threads)]
        errors = []

        def worker(slot):
            try:
                for i, q in enumerate(questions):
                    results[slot][i] = _ask(svc, q)
            except Exception as exc:   # pragma: no cover - failure path
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        # zero recompiles across the whole threaded phase (which includes
        # every hardware-swap request)
        assert devicecost.trace_count() == traces_before
        for per_thread in results:
            for got, oracle in zip(per_thread, oracles):
                _assert_matches(got, oracle)
        stats = svc.stats()
        assert stats["answered"] == (n_threads + 1) * len(questions)
        assert stats["failed"] == 0


def test_burst_coalesces_into_few_batches(profiles):
    h1, h2, h3 = profiles
    questions = _mixed_questions(h1, h2, h3) * 3
    with _service(profiles, window_s=0.25,
                  max_batch=len(questions)) as svc:
        _ask(svc, questions[0])        # warm so the batch serves quickly
        futures = [getattr(svc, f"submit_{q[0]}")(*q[1:])
                   for q in questions]
        for f in futures:
            f.result()
        stats = svc.stats()
    # the burst must actually coalesce: far fewer batches and scoring
    # calls than questions (one scoring call per profile per batch)
    assert stats["coalesced"] >= len(questions)
    assert stats["batches"] <= 1 + len(questions) // 4
    assert stats["score_calls"] < len(questions)
    assert stats["max_batch"] > 1


def test_session_pins_frontiers_across_global_cache_clears(profiles):
    """A designer iterating on one baseline never re-packs it: even after
    the global segment/frontier caches are dropped, the session's pinned
    packed frontier answers the repeat question with zero packing."""
    h1, _, _ = profiles
    spec, variant = el.spec_btree(), el.spec_csb_tree()
    with _service(profiles) as svc:
        sess = svc.session("designer-1")
        first = sess.what_if_design(spec, variant, W, h1)
        assert svc.stats()["session_frontier_hits"] == 0
        batchcost.clear_caches()       # simulate eviction by other traffic
        again = sess.what_if_design(spec, variant, W, h1)
        assert svc.stats()["session_frontier_hits"] == 1
        # nothing was re-synthesized or re-packed for the repeat ask
        assert batchcost.cache_info()["packed_spec"].misses == 0
        assert again.baseline_seconds == pytest.approx(
            first.baseline_seconds, rel=1e-12)
        # distinct sessions do not share pins
        other = svc.session("designer-2")
        other.what_if_design(spec, variant, W, h1)
        assert svc.stats()["session_frontier_hits"] == 1


def test_empty_window_and_empty_frontier_tolerated(profiles):
    h1, _, _ = profiles
    with _service(profiles) as svc:
        svc._serve_batch([])           # an empty coalescing window
        assert svc.stats()["empty_windows"] == 1
        # a degenerate evaluation (no specs) resolves, not crashes
        from repro.serving.service import _Evaluation, _Request
        from concurrent.futures import Future
        ev = _Evaluation((), W, None, h1.name)
        fut = Future()
        svc._serve_batch([_Request([ev], lambda el_: ev.totals, fut, 0.0)])
        assert fut.result().shape == (0,)


def test_failed_question_does_not_poison_the_batch(profiles):
    h1, _, _ = profiles
    bad_hw = analytical_profile("HW-bad")
    del bad_hw.models["random_memory_access"]
    with _service(profiles) as svc:
        svc.register_hardware(bad_hw)
        good = svc.submit_design(el.spec_btree(), el.spec_csb_tree(), W, h1)
        bad = svc.submit_design(el.spec_btree(), el.spec_csb_tree(), W,
                                bad_hw)
        with pytest.raises(KeyError, match="no fitted"):
            bad.result()
        assert good.result().baseline_seconds > 0
        assert svc.stats()["failed"] == 1


def test_submit_after_stop_raises(profiles):
    h1, _, _ = profiles
    svc = _service(profiles)
    svc.stop()
    with pytest.raises(RuntimeError, match="not running"):
        svc.submit_hardware(el.spec_btree(), W, h1, h1)


def test_unregistered_profile_name_raises(profiles):
    with _service(profiles) as svc:
        with pytest.raises(KeyError, match="unregistered"):
            svc.submit_hardware(el.spec_btree(), W, "HW1", "HW-unknown")


def test_stop_drains_pending_requests(profiles):
    h1, h2, _ = profiles
    svc = _service(profiles, window_s=0.05)
    futures = [svc.submit_hardware(el.spec_btree(), W, h1, h2)
               for _ in range(8)]
    svc.stop(timeout=30.0)
    for f in futures:
        assert f.result().baseline_seconds > 0


def test_session_state_lru_safe_under_concurrent_threads():
    """Session pin state is touched from non-worker threads (warm-restart
    plumbing, tests): hammered get/put/evict must never tear the LRU
    bookkeeping (KeyError out of ``move_to_end`` racing an eviction) and
    must respect ``maxsize`` throughout."""
    from repro.serving.service import _SessionState

    state = _SessionState(maxsize=8)
    errors = []
    stop = threading.Event()

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                key = int(rng.integers(0, 32))
                if rng.random() < 0.5:
                    state.put(key, object())
                else:
                    state.get(key)
                assert len(state.frontiers) <= state.maxsize
        except Exception as exc:   # pragma: no cover - the regression
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    import time as _time
    _time.sleep(0.5)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors
    assert len(state.frontiers) <= state.maxsize
