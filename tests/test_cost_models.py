"""Learned cost models (paper Appendix D): fit each family on synthetic
data drawn from that family and check recovery; fitting runs in JAX."""
import numpy as np
import pytest

from repro.core import models


def _r2(model, x, y):
    return models.r2_score(y, model.predict(x))


def test_linear_fit_recovers():
    x = np.logspace(1, 6, 24)
    y = 3e-9 * x + 2e-7
    m = models.fit("linear", x, y)
    assert _r2(m, x, y) > 0.999


def test_log_linear_fit_recovers():
    x = np.logspace(1, 6, 24)
    y = 5e-8 * np.log(x) + 1e-7
    m = models.fit("log_linear", x, y)
    assert _r2(m, x, y) > 0.99


def test_nlogn_fit_recovers():
    x = np.logspace(1, 6, 24)
    y = 2e-9 * x * np.log(x) + 5e-9 * x
    m = models.fit("nlogn", x, y)
    assert _r2(m, x, y) > 0.99


def test_sigmoids_fit_recovers_step_positions():
    """The paper's random-access model: cache steps at known boundaries."""
    x = np.logspace(2, 8, 60)
    logx = np.log(x + 1.0)
    def step(c, x0):
        return c / (1 + np.exp(-8.0 * (logx - np.log(x0))))
    y = 1e-9 + step(4e-9, 4e3) + step(2e-8, 2e5) + step(7e-8, 2e7)
    m = models.fit("sigmoids", x, y)
    assert _r2(m, x, y) > 0.98
    # prediction is monotone non-decreasing (a step function)
    pred = m.predict(x)
    assert np.all(np.diff(pred) >= -1e-12)


def test_knn_interpolates():
    x = np.logspace(1, 5, 20)
    y = 1e-8 * np.sqrt(x)
    m = models.fit("knn", x, y)
    assert _r2(m, x, y) > 0.95


def test_2d_sigmoids_bloom_model():
    """f(x, m) = S1(x) + (m-1) S2(x) — Table 1 'sum of sum of sigmoids'."""
    x = np.tile(np.logspace(2, 6, 20), 4)
    m_in = np.repeat([1, 2, 3, 4], 20)
    logx = np.log(x + 1.0)
    base = 1e-8 / (1 + np.exp(-(logx - 8.0)))
    y = base * m_in
    fm = models.fit2d_sigmoids(x, m_in, y)
    pred = models.predict2d(fm, x, m_in)
    assert models.r2_score(y, pred) > 0.9


def test_predictions_are_nonnegative_and_clipped():
    x = np.logspace(1, 4, 10)
    y = 1e-9 * x
    m = models.fit("linear", x, y)
    assert float(m.predict(np.asarray([1e12]))[0]) <= \
        float(m.predict(np.asarray([x.max()]))[0]) * 1.001
    assert np.all(m.predict(x) >= 0.0)


def test_json_roundtrip():
    x = np.logspace(1, 5, 16)
    y = 2e-9 * x + 1e-8 * np.log(x)
    m = models.fit("log_linear", x, y)
    m2 = models.FittedModel.from_json(m.to_json())
    np.testing.assert_allclose(m.predict(x), m2.predict(x), rtol=1e-6)
