"""Learned cost models (paper Appendix D): fit each family on synthetic
data drawn from that family and check recovery; fitting runs in JAX."""
import numpy as np
import pytest

from repro.core import models


def _r2(model, x, y):
    return models.r2_score(y, model.predict(x))


def test_linear_fit_recovers():
    x = np.logspace(1, 6, 24)
    y = 3e-9 * x + 2e-7
    m = models.fit("linear", x, y)
    assert _r2(m, x, y) > 0.999


def test_log_linear_fit_recovers():
    x = np.logspace(1, 6, 24)
    y = 5e-8 * np.log(x) + 1e-7
    m = models.fit("log_linear", x, y)
    assert _r2(m, x, y) > 0.99


def test_nlogn_fit_recovers():
    x = np.logspace(1, 6, 24)
    y = 2e-9 * x * np.log(x) + 5e-9 * x
    m = models.fit("nlogn", x, y)
    assert _r2(m, x, y) > 0.99


def test_sigmoids_fit_recovers_step_positions():
    """The paper's random-access model: cache steps at known boundaries."""
    x = np.logspace(2, 8, 60)
    logx = np.log(x + 1.0)
    def step(c, x0):
        return c / (1 + np.exp(-8.0 * (logx - np.log(x0))))
    y = 1e-9 + step(4e-9, 4e3) + step(2e-8, 2e5) + step(7e-8, 2e7)
    m = models.fit("sigmoids", x, y)
    assert _r2(m, x, y) > 0.98
    # prediction is monotone non-decreasing (a step function)
    pred = m.predict(x)
    assert np.all(np.diff(pred) >= -1e-12)


def test_knn_interpolates():
    x = np.logspace(1, 5, 20)
    y = 1e-8 * np.sqrt(x)
    m = models.fit("knn", x, y)
    assert _r2(m, x, y) > 0.95


def test_2d_sigmoids_bloom_model():
    """f(x, m) = S1(x) + (m-1) S2(x) — Table 1 'sum of sum of sigmoids'."""
    x = np.tile(np.logspace(2, 6, 20), 4)
    m_in = np.repeat([1, 2, 3, 4], 20)
    logx = np.log(x + 1.0)
    base = 1e-8 / (1 + np.exp(-(logx - 8.0)))
    y = base * m_in
    fm = models.fit2d_sigmoids(x, m_in, y)
    pred = models.predict2d(fm, x, m_in)
    assert models.r2_score(y, pred) > 0.9


def test_predict2d_is_pure():
    """predict2d(model, x, m) must not mutate the model: parameter state
    would break device-param caching and thread-safety (the fused engine
    banks every model once per profile)."""
    x = np.tile(np.logspace(2, 6, 20), 4)
    m_in = np.repeat([1, 2, 3, 4], 20)
    y = (1e-8 / (1 + np.exp(-(np.log(x + 1.0) - 8.0)))) * m_in
    fm = models.fit2d_sigmoids(x, m_in, y)
    params_before = {k: v.copy() for k, v in fm.params.items()}
    base = fm.predict(x).copy()           # the m=1 slice, S1(x)
    p4 = models.predict2d(fm, x, np.full_like(x, 4.0))
    p1 = models.predict2d(fm, x, np.ones_like(x))
    assert set(fm.params) == set(params_before)       # no state smuggled in
    for k, v in fm.params.items():
        np.testing.assert_array_equal(v, params_before[k])
    np.testing.assert_allclose(p1, base, rtol=1e-6)   # m=1 == plain predict
    assert (p4 >= p1 - 1e-12).all() and p4.max() > p1.max()
    # interleaving m values must not change earlier answers (statefulness
    # regression: the old _m param made call order observable)
    np.testing.assert_array_equal(
        models.predict2d(fm, x, np.full_like(x, 4.0)), p4)


def test_knn_numpy_fallback_below_four_points():
    """len(xs) < 4 cannot feed the fixed k=4 top-k: the numpy path with
    k=min(4, n) serves those models, matching a hand inverse-log-distance
    interpolation."""
    xs = np.array([10.0, 100.0, 1000.0])
    ys = np.array([1e-8, 2e-8, 4e-8])
    m = models.fit("knn", xs, ys)
    q = np.array([30.0, 500.0])
    d = np.abs(np.log(q.astype(np.float32) + 1.0)[:, None] -
               np.log(xs.astype(np.float32) + 1.0)[None, :]) + 1e-6
    w = 1.0 / d
    expected = (w * ys).sum(1) / w.sum(1)
    np.testing.assert_allclose(m.predict(q), expected, rtol=1e-6)
    # interior support points reproduce their own y (distance ~ 0 wins)
    np.testing.assert_allclose(m.predict(xs[1:2]), ys[1:2], rtol=1e-3)


def test_knn_jax_path_matches_numpy_reference():
    """n >= 4 runs the jitted fixed-k top-k; it must agree with the plain
    numpy argpartition formulation it replaced."""
    rng = np.random.default_rng(7)
    xs = np.logspace(1, 6, 24)
    ys = (1e-8 * np.sqrt(xs) * (1 + 0.05 * rng.standard_normal(24)))
    m = models.fit("knn", xs, ys)
    q = np.logspace(1.2, 5.8, 50).astype(np.float32)
    lx = np.log(q + 1.0)
    lxs = np.log(xs.astype(np.float32) + 1.0)
    d = np.abs(lx[:, None] - lxs[None, :]) + 1e-6
    idx = np.argpartition(d, 3, axis=1)[:, :4]
    wk = 1.0 / np.take_along_axis(d, idx, axis=1)
    expected = (wk * ys[idx]).sum(1) / wk.sum(1)
    np.testing.assert_allclose(m.predict(q), expected, rtol=1e-5)


def test_predictions_are_nonnegative_and_clipped():
    x = np.logspace(1, 4, 10)
    y = 1e-9 * x
    m = models.fit("linear", x, y)
    assert float(m.predict(np.asarray([1e12]))[0]) <= \
        float(m.predict(np.asarray([x.max()]))[0]) * 1.001
    assert np.all(m.predict(x) >= 0.0)


def test_json_roundtrip():
    x = np.logspace(1, 5, 16)
    y = 2e-9 * x + 1e-8 * np.log(x)
    m = models.fit("log_linear", x, y)
    m2 = models.FittedModel.from_json(m.to_json())
    np.testing.assert_allclose(m.predict(x), m2.predict(x), rtol=1e-6)
